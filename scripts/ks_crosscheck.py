"""Nightly KS cross-check: our KS statistic / p-value vs scipy oracles
over a dense grid (far beyond the unit-test pins).

Sweeps:
  * p-values against ``scipy.special.kolmogorov`` across (n, d) including
    the small-lambda region, where the asymptotic series used to collapse
    to 0 (the sum of its first 40 terms is ~0 for lambda < 0.1) -- there
    the implementations must return exactly 1.0;
  * two-sample statistics against ``scipy.stats.ks_2samp`` on random
    pairs, plus end-to-end p-values via ``method="asymp"`` for identical
    samples (d == 0 must accept with p == 1.0 at every n).

Exits nonzero on any mismatch.  Usage:

  PYTHONPATH=src python scripts/ks_crosscheck.py [--trials 200]
"""
import argparse
import sys

import numpy as np
import scipy.special
import scipy.stats

from repro.core.ks import critical_distance, ks_pvalue, ks_statistic
from repro.core.npref import ks_pvalue_np, ks_statistic_np

_SMALL_LAM = 0.1  # must match repro.core.ks._SMALL_LAM


def check_pvalue_grid() -> int:
    bad = 0
    ns = [2, 4, 8, 16, 32, 64, 128, 255, 1024]
    for n in ns:
        en = np.sqrt(n / 2.0)  # sqrt(n1*n2/(n1+n2)) for n1 == n2 == n
        for d in np.concatenate([[0.0], np.geomspace(1e-8, 1.0, 120)]):
            lam = en * d
            ours = ks_pvalue_np(d, n, n)
            ours_jax = float(ks_pvalue(d, n, n))
            if lam < _SMALL_LAM:
                ok = ours == 1.0 and ours_jax == 1.0
                ref = 1.0
            else:
                ref = float(scipy.special.kolmogorov(lam))
                ok = (abs(ours - ref) <= 1e-9
                      and abs(ours_jax - ref) <= 1e-6)
            if not ok:
                bad += 1
                print(f"FAIL pvalue n={n} d={d:.3e} lam={lam:.3e} "
                      f"np={ours!r} jax={ours_jax!r} ref={ref!r}")
    print(f"pvalue grid: {len(ns) * 121} points, {bad} failures")
    return bad


def check_statistic_random(trials: int, seed: int = 0) -> int:
    bad = 0
    rng = np.random.default_rng(seed)
    for t in range(trials):
        n1, n2 = int(rng.integers(4, 256)), int(rng.integers(4, 256))
        x = rng.normal(size=n1)
        y = rng.normal(rng.normal(0, 0.5), float(rng.uniform(0.5, 2)),
                       size=n2)
        ref = scipy.stats.ks_2samp(x, y).statistic
        if abs(ks_statistic_np(x, y) - ref) > 1e-12:
            bad += 1
            print(f"FAIL statistic trial={t} n1={n1} n2={n2}")
        if abs(float(ks_statistic(x, y)) - ref) > 1e-6:
            bad += 1
            print(f"FAIL statistic(jax) trial={t} n1={n1} n2={n2}")
    print(f"statistic random: {trials} trials, {bad} failures")
    return bad


def check_identical_accept() -> int:
    bad = 0
    rng = np.random.default_rng(1)
    for n in [4, 8, 16, 32, 64, 128, 255]:
        x = rng.normal(size=n)
        ref = scipy.stats.ks_2samp(x, x, method="asymp").pvalue
        p = ks_pvalue_np(ks_statistic_np(x, x), n, n)
        if not (p == 1.0 and abs(p - ref) <= 1e-12):
            bad += 1
            print(f"FAIL identical n={n} p={p!r} ref={ref!r}")
        # the decision boundary stays invertible around every alpha
        for alpha in [0.01, 0.05, 0.1, 0.2]:
            dc = critical_distance(alpha, n, n)
            if abs(ks_pvalue_np(dc, n, n) - alpha) > 1e-6:
                bad += 1
                print(f"FAIL critical_distance n={n} alpha={alpha}")
    print(f"identical/critical: {bad} failures")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=200)
    args = ap.parse_args(argv)
    bad = (check_pvalue_grid() + check_statistic_random(args.trials)
           + check_identical_accept())
    print("ks_crosscheck:", "PASS" if bad == 0 else f"FAIL ({bad})")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
