"""CLI over the decode-backend autotuner (repro.core.decode, DESIGN.md
Sec. 9): probe the measured-best backend per (mode, dtype, size-bucket)
and validate a persisted ``decode_autotune.json`` cache.

  probe      [--out decode_autotune.json] [--modes std,res,delta]
             [--dtypes f8] [--buckets 64,1024,16384] [--block-size 32]
             time numpy vs jax vs pallas for every combination and
             persist the versioned choice table
  selfcheck  cache.json
             the nightly round-trip: a persisted cache must (1) strictly
             reload with every entry intact, (2) survive a save/load
             round trip bit-identically, and (3) be REJECTED -- strict
             load raises, lenient load discards and leaves the table cold
             -- when corrupted or carrying a stale version field

Exit status: 0 clean, 1 failed check, 2 usage.
"""
import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import decode as decode_mod  # noqa: E402

MODES = {"std": decode_mod.MODE_STD, "res": decode_mod.MODE_RESIDUAL,
         "delta": decode_mod.MODE_DELTA}


def cmd_probe(args) -> int:
    decode_mod.reset_autotune()
    buckets = [int(b) for b in args.buckets.split(",")]
    for mode_name in args.modes.split(","):
        mode = MODES[mode_name]
        for dt in args.dtypes.split(","):
            for nb in buckets:
                decode_mod.resolve_backend("auto", mode, dt, nb,
                                           block_size=args.block_size)
    decode_mod.save_autotune(args.out)
    for key, backend in decode_mod.autotune_choices().items():
        print(f"  {key} -> {backend}")
    stats = decode_mod.decode_stats()
    print(f"probed {stats['autotune_probes']} combination(s) -> {args.out}")
    return 0


def _expect_raise(path, what) -> int:
    """Strict load must raise; lenient load must discard (0 entries)."""
    try:
        decode_mod.load_autotune(path, strict=True)
    except decode_mod.AutotuneCacheError as e:
        print(f"  {what}: strict load rejected as expected ({e})")
    else:
        print(f"FAIL {what}: strict load accepted an invalid cache")
        return 1
    decode_mod.reset_autotune()
    n = decode_mod.load_autotune(path, strict=False)
    if n != 0 or decode_mod.autotune_choices():
        print(f"FAIL {what}: lenient load kept {n} entries from an "
              f"invalid cache")
        return 1
    print(f"  {what}: lenient load discarded it (cold table, will re-probe)")
    return 0


def cmd_selfcheck(args) -> int:
    # 1. the persisted cache strictly reloads
    decode_mod.reset_autotune()
    n = decode_mod.load_autotune(args.cache, strict=True)
    if n == 0:
        print(f"FAIL {args.cache}: no entries")
        return 1
    choices = decode_mod.autotune_choices()
    print(f"  loaded {n} entrie(s): {choices}")

    with tempfile.TemporaryDirectory() as td:
        # 2. save -> load round trip preserves every choice
        rt = os.path.join(td, "roundtrip.json")
        decode_mod.save_autotune(rt)
        decode_mod.reset_autotune()
        if decode_mod.load_autotune(rt, strict=True) != n \
                or decode_mod.autotune_choices() != choices:
            print("FAIL round trip changed the choice table")
            return 1
        print("  round trip: identical choice table")

        with open(args.cache, "r", encoding="utf-8") as f:
            doc = json.load(f)

        # 3a. stale version field -> rejected, re-probe path
        stale = os.path.join(td, "stale.json")
        with open(stale, "w", encoding="utf-8") as f:
            json.dump({**doc, "version": doc["version"] + 1}, f)
        if _expect_raise(stale, "stale version"):
            return 1

        # 3b. corrupted bytes -> rejected, re-probe path
        corrupt = os.path.join(td, "corrupt.json")
        with open(args.cache, "rb") as f:
            blob = f.read()
        with open(corrupt, "wb") as f:
            f.write(blob[: max(1, len(blob) // 2)] + b"\xff{garbage")
        if _expect_raise(corrupt, "corrupted file"):
            return 1

        # 3c. structurally wrong entries -> rejected
        malformed = os.path.join(td, "malformed.json")
        with open(malformed, "w", encoding="utf-8") as f:
            json.dump({"version": doc["version"],
                       "entries": {"k": {"backend": "not-a-backend"}}}, f)
        if _expect_raise(malformed, "malformed entry"):
            return 1

    print(f"selfcheck OK: {args.cache}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="autotune_tool.py")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("probe", help="measure + persist backend choices")
    p.add_argument("--out", default="decode_autotune.json")
    p.add_argument("--modes", default="std,res,delta")
    p.add_argument("--dtypes", default="f8")
    p.add_argument("--buckets", default="64,1024,16384")
    p.add_argument("--block-size", type=int, default=32)
    p.set_defaults(fn=cmd_probe)

    p = sub.add_parser("selfcheck", help="validate a persisted cache")
    p.add_argument("cache")
    p.set_defaults(fn=cmd_selfcheck)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
