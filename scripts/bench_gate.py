"""CI perf gate: compare a ``benchmarks/run.py --json`` result against a
committed baseline and fail when any bench regresses beyond tolerance.

  bench_gate.py CURRENT.json BASELINE.json [--tolerance 0.25]
                [--override NAME=TOL ...] [--absolute] [--allow-missing]

Two comparison modes:

* **normalized** (default): each bench's ``current/baseline`` time ratio
  is compared against the MEDIAN ratio across all shared benches.  A
  uniformly slower machine (a cold CI runner vs the laptop that produced
  the baseline) shifts every ratio equally and trips nothing; a single
  bench whose ratio exceeds ``median * (1 + tol)`` is a real relative
  regression and fails the gate.  Needs a handful of benches to be
  meaningful -- below ``--min-normalize`` shared rows the gate falls back
  to absolute comparison (warned).
* **absolute** (``--absolute``): fail when ``current > baseline * (1 +
  tol)``.  Right for trajectories measured on pinned hardware (the
  nightly archive), wrong across heterogeneous runners.

Tolerance resolution, most specific wins: ``--override NAME=TOL``
(longest matching name prefix), then the baseline document's optional
``"tolerances": {prefix: tol}`` map, then ``--tolerance`` (default 0.25
-- the noise floor of shared CI runners).

Benches present in the baseline but missing from the current run fail the
gate (a silently deleted bench must not pass; ``--allow-missing`` for
intentional removals); new benches are reported and pass.

Exit status: 0 clean, 1 regression/missing, 2 usage or unreadable input.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, Optional, Tuple


def load_results(path: str) -> Tuple[Dict[str, float], dict]:
    """Read a run.py --json document; returns (name -> us_per_call, doc)."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("results"), dict):
        raise ValueError(f"{path}: not a benchmarks/run.py --json document")
    out = {}
    for name, ent in doc["results"].items():
        us = float(ent["us_per_call"])
        if us > 0.0:  # zero-time rows are derived-only reports, not gates
            out[name] = us
    return out, doc


def pick_tolerance(name: str, default: float,
                   overrides: Dict[str, float]) -> float:
    """Longest-prefix tolerance override for one bench name."""
    best: Optional[Tuple[int, float]] = None
    for prefix, tol in overrides.items():
        if name.startswith(prefix) and (best is None or len(prefix) > best[0]):
            best = (len(prefix), tol)
    return best[1] if best is not None else default


def gate(current: Dict[str, float], baseline: Dict[str, float],
         tolerance: float = 0.25,
         overrides: Optional[Dict[str, float]] = None,
         absolute: bool = False, allow_missing: bool = False,
         min_normalize: int = 4) -> Tuple[bool, list]:
    """Returns (ok, report_lines)."""
    overrides = overrides or {}
    shared = sorted(set(current) & set(baseline))
    missing = sorted(set(baseline) - set(current))
    new = sorted(set(current) - set(baseline))
    lines = []
    ok = True

    mode = "absolute" if absolute else "normalized"
    norm = 1.0
    if not absolute:
        if len(shared) < min_normalize:
            lines.append(f"WARN only {len(shared)} shared benches: "
                         f"falling back to absolute comparison")
            mode = "absolute"
        else:
            norm = statistics.median(current[n] / baseline[n]
                                     for n in shared)
            lines.append(f"normalizing by median ratio {norm:.3f} "
                         f"over {len(shared)} benches")

    for name in shared:
        ratio = current[name] / baseline[name]
        rel = ratio / norm if mode == "normalized" else ratio
        tol = pick_tolerance(name, tolerance, overrides)
        verdict = "ok"
        if rel > 1.0 + tol:
            verdict = "REGRESSION"
            ok = False
        lines.append(
            f"{verdict:>10}  {name}: {current[name]:.1f}us vs "
            f"{baseline[name]:.1f}us  (x{ratio:.2f}"
            + (f", x{rel:.2f} normalized" if mode == "normalized" else "")
            + f", tol {tol:.0%})")
    for name in missing:
        if allow_missing:
            lines.append(f"   missing  {name} (allowed)")
        else:
            lines.append(f"   MISSING  {name}: in baseline, not in current "
                         f"run")
            ok = False
    for name in new:
        lines.append(f"       new  {name}: {current[name]:.1f}us "
                     f"(no baseline yet)")
    return ok, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_gate.py",
        description="fail when a benchmark regresses vs the baseline")
    ap.add_argument("current", help="run.py --json output of this build")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="default noise tolerance (fraction, default 0.25)")
    ap.add_argument("--override", action="append", default=[],
                    metavar="NAME=TOL",
                    help="per-bench tolerance, longest name-prefix wins "
                         "(repeatable)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw times instead of median-normalized "
                         "ratios")
    ap.add_argument("--allow-missing", action="store_true",
                    help="do not fail on benches absent from the current run")
    ap.add_argument("--min-normalize", type=int, default=4,
                    help="min shared benches for normalized mode (else "
                         "absolute)")
    args = ap.parse_args(argv)

    overrides = {}
    for spec in args.override:
        name, _, tol = spec.rpartition("=")
        if not name:
            ap.error(f"--override must be NAME=TOL, got {spec!r}")
        overrides[name] = float(tol)

    try:
        current, _ = load_results(args.current)
        baseline, base_doc = load_results(args.baseline)
    except (OSError, ValueError, KeyError) as e:
        print(f"bench_gate: {e}", file=sys.stderr)
        return 2
    # the baseline may embed per-bench tolerances; CLI overrides win
    embedded = base_doc.get("tolerances", {})
    if isinstance(embedded, dict):
        overrides = {**{k: float(v) for k, v in embedded.items()},
                     **overrides}

    ok, lines = gate(current, baseline, tolerance=args.tolerance,
                     overrides=overrides, absolute=args.absolute,
                     allow_missing=args.allow_missing,
                     min_normalize=args.min_normalize)
    for line in lines:
        print(line)
    print("bench_gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
