"""CLI over the indexed decode store (repro.store): pack raw ``.idlm``
streams into random-access containers, inspect their index, extract
decoded ranges, and self-check range-decode equivalence.

  pack      out.idlmc stream.idlm [stream2.idlm ...]   (file i -> channel i)
  inspect   container.idlmc [--chunks]
  extract   container.idlmc [--channel C] [--blocks i:j] [-o out.npy]
  selfcheck stream.idlm [...]   pack each stream, then verify decode_range
            equals the matching slice of the sequential full decode for a
            sweep of ranges (the ISSUE 3 random-access criterion)

``make store-check`` runs selfcheck over the golden corpus.
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.stream import decode_stream  # noqa: E402
from repro.store import (Container, decode_channels, decode_range,  # noqa: E402
                         pack)


def cmd_pack(args) -> int:
    streams = {}
    for ch, path in enumerate(args.streams):
        with open(path, "rb") as f:
            streams[ch] = f.read()
    pack(streams, path=args.out)
    store = Container.open(args.out)
    print(f"packed {len(streams)} stream(s) -> {args.out} "
          f"({store.n_chunks} chunks, {os.path.getsize(args.out)} bytes)")
    return 0


def cmd_inspect(args) -> int:
    store = Container.open(args.container)
    info = store.describe()
    print(f"container: {args.container}")
    print(f"  chunks={info['chunks']} data_bytes={info['data_bytes']} "
          f"index_bytes={info['index_bytes']}")
    for c, ci in sorted(info["channels"].items()):
        print(f"  channel {c}: segments={ci['segments']} "
              f"blocks={ci['blocks']} tail={ci['tail_samples']} "
              f"mode={ci['mode']} B={ci['block_size']} D={ci['num_dict']} "
              f"dtype={ci['dtype']} finished={ci['finished']}")
    if args.chunks:
        cols = store._cols
        print("  chunk channel offset length blocks blocks_before fill "
              "flags restart")
        for k in range(store.n_chunks):
            print("  " + " ".join(
                str(int(cols[name][k]))
                for name in ("channel", "offset", "length", "n_blocks",
                             "blocks_before", "fill_in", "flags", "restart")))
    return 0


def _parse_range(spec, total):
    if spec is None:
        return 0, total
    lo, _, hi = spec.partition(":")
    return int(lo or 0), int(hi or total)


def cmd_extract(args) -> int:
    store = Container.open(args.container)
    if args.blocks is None:
        # whole channel(s), tail included
        chans = store.channels if args.channel is None else [args.channel]
        out = decode_channels(store, chans)
        arr = (np.stack([out[c] for c in chans]) if len(chans) > 1
               else out[chans[0]])
    else:
        channel = args.channel or 0
        i, j = _parse_range(args.blocks, store.total_blocks(channel))
        arr = decode_range(store, i, j, channel=channel)
    if args.output:
        np.save(args.output, arr)
        print(f"wrote {arr.shape} {arr.dtype} -> {args.output}")
    else:
        np.savetxt(sys.stdout, np.atleast_2d(arr), fmt="%.17g")
    return 0


def cmd_selfcheck(args) -> int:
    failures = 0
    for path in args.streams:
        with open(path, "rb") as f:
            data = f.read()
        y = decode_stream(data)
        store = Container(pack(data))
        nb = store.total_blocks(0)
        B = store.header_of(0).block_size
        ranges = {(0, nb), (0, 1), (nb - 1, nb), (nb // 3, 2 * nb // 3 + 1)}
        ranges |= {(i, min(i + 7, nb)) for i in range(0, nb, max(nb // 5, 1))}
        ranges = sorted(r for r in ranges if 0 <= r[0] < r[1] <= nb)
        bad = 0
        for i, j in ranges:
            got = decode_range(store, i, j)
            if not np.array_equal(got, y[i * B:j * B]):
                bad += 1
                print(f"  MISMATCH {path} blocks [{i}, {j})")
        tag = "ok" if not bad else f"{bad} FAILED"
        print(f"{os.path.basename(path)}: blocks={nb} "
              f"ranges={len(ranges)} {tag}")
        failures += bad
    if failures:
        print(f"selfcheck FAILED ({failures} mismatching ranges)")
        return 1
    print("selfcheck passed: every range matches the sequential decode")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="store_tool",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("pack", help="wrap .idlm streams in a container")
    p.add_argument("out")
    p.add_argument("streams", nargs="+")
    p.set_defaults(fn=cmd_pack)

    p = sub.add_parser("inspect", help="print the container index summary")
    p.add_argument("container")
    p.add_argument("--chunks", action="store_true",
                   help="also dump the per-chunk index records")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("extract", help="decode a channel/range")
    p.add_argument("container")
    p.add_argument("--channel", type=int, default=None)
    p.add_argument("--blocks", default=None, metavar="I:J",
                   help="block range (default: whole channel incl. tail)")
    p.add_argument("-o", "--output", default=None, help="write .npy here")
    p.set_defaults(fn=cmd_extract)

    p = sub.add_parser("selfcheck",
                       help="verify range-decode == full-decode slices")
    p.add_argument("streams", nargs="+")
    p.set_defaults(fn=cmd_selfcheck)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
