"""CLI over the indexed decode store (repro.store): pack raw ``.idlm``
streams into random-access containers, inspect their index, extract
decoded ranges, and self-check range-decode equivalence.

  pack      out.idlmc stream.idlm [stream2.idlm ...]   (file i -> channel i)
  inspect   container.idlmc [--chunks] [--mmap]
  extract   container.idlmc [--channel C] [--blocks i:j] [-o out.npy]
            [--mmap] [--backend numpy|jax|pallas]
  selfcheck stream.idlm [...] [--mmap] [--backend ...]   pack each stream,
            then verify decode_range equals the matching slice of the
            sequential full decode for a sweep of ranges (the ISSUE 3
            random-access criterion); --mmap round-trips through a
            file-backed memory-mapped open
  bigcheck  [--mb N] [--mmap/--no-mmap] [--out path]   generate a synthetic
            multi-channel archive of ~N MB on disk, open it memory-mapped
            and verify sampled channels/ranges -- the ">RAM-budget archive"
            exercise (per-channel verification stays small no matter how
            big the file is)

``make store-check`` runs selfcheck over the golden corpus plus a
size-capped bigcheck.
"""
import argparse
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.stream import decode_stream  # noqa: E402
from repro.store import (Container, ContainerWriter, decode_channels,  # noqa: E402
                         decode_range, pack)


def _open(path, use_mmap):
    return Container.open(path, mmap=use_mmap)


def cmd_pack(args) -> int:
    streams = {}
    for ch, path in enumerate(args.streams):
        with open(path, "rb") as f:
            streams[ch] = f.read()
    pack(streams, path=args.out)
    store = Container.open(args.out)
    print(f"packed {len(streams)} stream(s) -> {args.out} "
          f"({store.n_chunks} chunks, {os.path.getsize(args.out)} bytes)")
    return 0


def cmd_inspect(args) -> int:
    store = _open(args.container, args.mmap)
    info = store.describe()
    print(f"container: {args.container}" + (" (mmap)" if args.mmap else ""))
    print(f"  chunks={info['chunks']} data_bytes={info['data_bytes']} "
          f"index_bytes={info['index_bytes']} "
          f"snapshot_deltas={info['snapshot_delta_entries']}"
          f"/{info['snapshot_entries']}")
    for c, ci in sorted(info["channels"].items()):
        print(f"  channel {c}: segments={ci['segments']} "
              f"blocks={ci['blocks']} tail={ci['tail_samples']} "
              f"mode={ci['mode']} B={ci['block_size']} D={ci['num_dict']} "
              f"dtype={ci['dtype']} finished={ci['finished']}")
    if args.chunks:
        cols = store._cols
        print("  chunk channel offset length blocks blocks_before fill "
              "flags restart snap_delta")
        for k in range(store.n_chunks):
            print("  " + " ".join(
                str(int(cols[name][k]))
                for name in ("channel", "offset", "length", "n_blocks",
                             "blocks_before", "fill_in", "flags", "restart",
                             "snap_delta")))
    store.close()
    return 0


def _parse_range(spec, total):
    if spec is None:
        return 0, total
    lo, _, hi = spec.partition(":")
    return int(lo or 0), int(hi or total)


def cmd_extract(args) -> int:
    store = _open(args.container, args.mmap)
    if args.blocks is None:
        # whole channel(s), tail included
        chans = store.channels if args.channel is None else [args.channel]
        out = decode_channels(store, chans, backend=args.backend)
        arr = (np.stack([out[c] for c in chans]) if len(chans) > 1
               else out[chans[0]])
    else:
        channel = args.channel or 0
        i, j = _parse_range(args.blocks, store.total_blocks(channel))
        arr = decode_range(store, i, j, channel=channel,
                           backend=args.backend)
    store.close()
    if args.output:
        np.save(args.output, arr)
        print(f"wrote {arr.shape} {arr.dtype} -> {args.output}")
    else:
        np.savetxt(sys.stdout, np.atleast_2d(arr), fmt="%.17g")
    return 0


def _check_ranges(store, y, ranges, path, backend, channel=0) -> int:
    B = store.header_of(int(store.chunks_of(channel)[0])).block_size
    bad = 0
    for i, j in ranges:
        got = decode_range(store, i, j, channel=channel, backend=backend)
        if not np.array_equal(got, y[i * B:j * B]):
            bad += 1
            print(f"  MISMATCH {path} channel {channel} blocks [{i}, {j})")
    return bad


def cmd_selfcheck(args) -> int:
    failures = 0
    for path in args.streams:
        with open(path, "rb") as f:
            data = f.read()
        y = decode_stream(data)
        if args.mmap:
            with tempfile.NamedTemporaryFile(suffix=".idlmc",
                                             delete=False) as tf:
                tmp = tf.name
            try:
                pack(data, path=tmp)
                store = Container.open(tmp, mmap=True)
                bad = _run_selfcheck(store, y, path, args.backend)
                store.close()
            finally:
                os.unlink(tmp)
        else:
            bad = _run_selfcheck(Container(pack(data)), y, path, args.backend)
        failures += bad
    if failures:
        print(f"selfcheck FAILED ({failures} mismatching ranges)")
        return 1
    print("selfcheck passed: every range matches the sequential decode")
    return 0


def _run_selfcheck(store, y, path, backend) -> int:
    nb = store.total_blocks(0)
    ranges = {(0, nb), (0, 1), (nb - 1, nb), (nb // 3, 2 * nb // 3 + 1)}
    ranges |= {(i, min(i + 7, nb)) for i in range(0, nb, max(nb // 5, 1))}
    ranges = sorted(r for r in ranges if 0 <= r[0] < r[1] <= nb)
    bad = _check_ranges(store, y, ranges, path, backend)
    tag = "ok" if not bad else f"{bad} FAILED"
    print(f"{os.path.basename(path)}: blocks={nb} "
          f"ranges={len(ranges)} {tag}")
    return bad


def cmd_bigcheck(args) -> int:
    """Generate a >RAM-budget synthetic archive (size-capped via --mb) and
    verify it through a memory-mapped open.

    One modest session stream is encoded once and appended under MANY
    channels until the file reaches the target size, so the archive can be
    arbitrarily large while each verification step (per channel) stays
    small -- the point is exercising ``Container.open(mmap=True)`` and the
    zero-copy chunk reads on a file that need never fit in memory at once.
    """
    from repro.core import IdealemCodec
    codec = IdealemCodec(mode="std", block_size=32, num_dict=32, alpha=0.05,
                         rel_tol=0.5, backend="numpy")
    rng = np.random.default_rng(0)
    levels = rng.normal(0, 2, size=6)
    n = args.channel_blocks * 32
    # wandering level + drift: plenty of misses so each channel carries
    # real payload bytes (a near-all-hit stream would need tens of
    # thousands of channels to reach the size target)
    x = (rng.normal(0, 1, size=n)
         + levels[rng.integers(0, 6, size=args.channel_blocks).repeat(32)]
         + np.arange(n) * (4.0 / 32))
    sess = codec.session()
    feed = 64 * 32
    segs = [sess.feed(x[lo:lo + feed]) for lo in range(0, n, feed)]
    segs.append(sess.finish())
    stream = b"".join(segs)
    y = decode_stream(stream)

    out = args.out
    if out is None:
        fd, out = tempfile.mkstemp(suffix=".idlmc")
        os.close(fd)
        cleanup = True
    else:
        cleanup = False
    try:
        target = int(args.mb * 1e6)
        w = ContainerWriter(out)
        ch = 0
        while ch == 0 or ch * len(stream) < target:
            w.append(stream, channel=ch)
            ch += 1
        w.finalize()
        size = os.path.getsize(out)
        store = Container.open(out, mmap=args.mmap)
        info = store.describe()
        print(f"bigcheck archive: {size / 1e6:.1f} MB, {ch} channels, "
              f"{info['chunks']} chunks, index={info['index_bytes']} B "
              f"({'mmap' if args.mmap else 'in-memory'})")
        assert isinstance(store.chunk_bytes(0), memoryview)  # zero-copy read
        nb = store.total_blocks(0)
        check = sorted({0, ch // 2, ch - 1})
        bad = 0
        for c in check:
            ranges = [(0, nb), (nb // 2, nb // 2 + 3), (nb - 1, nb)]
            ranges += [(int(i), min(int(i) + 5, nb))
                       for i in rng.integers(0, nb - 1, size=8)]
            bad += _check_ranges(store, y, ranges, out, args.backend,
                                 channel=c)
            got = decode_channels(store, [c], backend=args.backend)[c]
            if not np.array_equal(got, y):  # y carries the tail already
                bad += 1
                print(f"  MISMATCH full channel {c}")
        store.close()
        if bad:
            print(f"bigcheck FAILED ({bad} mismatches)")
            return 1
        print(f"bigcheck passed: {len(check)} channels verified via "
              f"{'mmap' if args.mmap else 'bytes'}")
        return 0
    finally:
        if cleanup and os.path.exists(out):
            os.unlink(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="store_tool",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("pack", help="wrap .idlm streams in a container")
    p.add_argument("out")
    p.add_argument("streams", nargs="+")
    p.set_defaults(fn=cmd_pack)

    p = sub.add_parser("inspect", help="print the container index summary")
    p.add_argument("container")
    p.add_argument("--chunks", action="store_true",
                   help="also dump the per-chunk index records")
    p.add_argument("--mmap", action="store_true",
                   help="open the container memory-mapped")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("extract", help="decode a channel/range")
    p.add_argument("container")
    p.add_argument("--channel", type=int, default=None)
    p.add_argument("--blocks", default=None, metavar="I:J",
                   help="block range (default: whole channel incl. tail)")
    p.add_argument("-o", "--output", default=None, help="write .npy here")
    p.add_argument("--mmap", action="store_true",
                   help="open the container memory-mapped")
    p.add_argument("--backend", default="numpy",
                   choices=["numpy", "jax", "pallas"],
                   help="reconstruction backend (repro.core.decode)")
    p.set_defaults(fn=cmd_extract)

    p = sub.add_parser("selfcheck",
                       help="verify range-decode == full-decode slices")
    p.add_argument("streams", nargs="+")
    p.add_argument("--mmap", action="store_true",
                   help="round-trip through a mmap-backed file open")
    p.add_argument("--backend", default="numpy",
                   choices=["numpy", "jax", "pallas"],
                   help="reconstruction backend (repro.core.decode)")
    p.set_defaults(fn=cmd_selfcheck)

    p = sub.add_parser("bigcheck",
                       help="generate + verify a large mmap-backed archive")
    p.add_argument("--mb", type=float, default=64.0,
                   help="approximate archive size in MB (CI caps this)")
    p.add_argument("--channel-blocks", type=int, default=2048,
                   help="blocks per synthetic channel")
    p.add_argument("--mmap", action=argparse.BooleanOptionalAction,
                   default=True, help="open the archive memory-mapped")
    p.add_argument("--out", default=None,
                   help="write the archive here (default: temp file)")
    p.add_argument("--backend", default="numpy",
                   choices=["numpy", "jax", "pallas"],
                   help="reconstruction backend (repro.core.decode)")
    p.set_defaults(fn=cmd_bigcheck)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
