"""Re-run the trip-count-aware HLO analysis over saved dry-run artifacts
(no recompilation; reads <tag>.hlo.zst next to each <tag>.json)."""
import glob
import json
import os
import sys

import zstandard as zstd

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.hlo_cost import analyze  # noqa: E402


def main(art_dir: str) -> None:
    for jf in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        hf = jf.replace(".json", ".hlo.zst")
        if not os.path.exists(hf):
            continue
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        text = zstd.ZstdDecompressor().decompress(open(hf, "rb").read()).decode()
        cost = analyze(text)
        rec["flops_per_chip"] = cost["flops"]
        rec["bytes_per_chip"] = cost["bytes"]
        rec["collectives"] = cost["collectives"]
        rec["collective_wire_bytes_per_chip"] = cost["collective_wire_bytes"]
        json.dump(rec, open(jf, "w"), indent=1)
        print("reanalyzed", os.path.basename(jf))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
