"""Closed-loop load generator for the serving front end (DESIGN.md Sec. 14).

Replays synthetic-but-shaped traces against a :class:`repro.serve.ServeFrontend`
over the real wire protocol -- every tenant is its own keep-alive connection
driving its own streams, closed loop (a client issues the next chunk only
after the previous response lands, honouring ``Retry-After`` on 429/503).

Two trace families, matching the paper's target data:

* **power-grid**: a 60 Hz fundamental with 3rd/5th harmonics, slow
  amplitude modulation and measurement noise -- the periodic signals
  IDEALEM's dictionary loves.
* **bursty sensor**: a level random walk with Poisson-arriving activity
  bursts -- the quiet/loud alternation that exercises deadline flushes
  and the control loop's batch sizing.

Verification is end to end:

* every **direct** stream's concatenated wire segments must be
  **byte-identical** to a shadow ``IdealemSession`` fed exactly the same
  chunks (``byte_diffs`` in the report must be 0);
* every **coalesced** stream must be **decode-exact**: the decoded wire
  bytes equal the one-shot codec decode of the full trace (the coalescer's
  contract -- segment framing differs across flush cohorts, samples never);
* a decode phase packs each direct stream's bytes into a container,
  attaches it, and range-reads through the batched decode mux, comparing
  against the codec's own decode;
* finally the front end's ``/metrics`` is scraped, parsed with
  ``repro.obs.parse_prometheus``, and the p99 SLOs asserted with
  ``repro.obs.evaluate_slos`` -- the same math ``obs_tool slo`` runs.

Exit status: 0 all checks green, 1 any byte diff / SLO breach / missing
rejection observability, 2 usage.  ``--json PATH`` writes the full report
(the nightly soak artifact); ``--smoke`` is the CI profile
(``make serve-check``).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api, obs  # noqa: E402
from repro.core import IdealemCodec  # noqa: E402
from repro.errors import RateLimitedError, ReproError  # noqa: E402
from repro.serve import (FlushPolicy, FrontendClient,  # noqa: E402
                         ServeFrontend, TenantQuota)
from repro.store import pack  # noqa: E402


# ------------------------------------------------------------------ traces
def power_grid_trace(n: int, seed: int) -> np.ndarray:
    """60 Hz + harmonics + drifting amplitude + noise, 1.92 kHz sampling."""
    rng = np.random.default_rng(seed)
    t = np.arange(n) / 1920.0
    amp = 1.0 + 0.05 * np.sin(2 * np.pi * 0.3 * t + rng.uniform(0, 6.28))
    x = amp * (np.sin(2 * np.pi * 60 * t + rng.uniform(0, 6.28))
               + 0.08 * np.sin(2 * np.pi * 180 * t)
               + 0.03 * np.sin(2 * np.pi * 300 * t))
    return (x + rng.normal(0, 0.01, size=n)).astype(np.float64)


def bursty_sensor_trace(n: int, seed: int) -> np.ndarray:
    """Level random walk with Poisson-arriving activity bursts."""
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.normal(0, 0.02, size=n))
    i = 0
    while i < n:
        i += int(rng.exponential(n / 6)) + 1
        width = int(rng.integers(32, 256))
        burst = rng.normal(0, 1.0, size=width) * np.hanning(width) * 3.0
        end = min(n, i + width)
        x[i:end] += burst[:end - i]
    return x.astype(np.float64)


def arrival_chunks(trace: np.ndarray, kind: str, seed: int):
    """Cut a trace into per-request chunks: periodic traces arrive in a
    fixed cadence, bursty traces in ragged bursts."""
    rng = np.random.default_rng(seed)
    i = 0
    while i < len(trace):
        if kind == "grid":
            step = 256
        else:
            step = int(rng.integers(64, 512))
        yield trace[i:i + step]
        i += step


# ------------------------------------------------------------------ tenants
async def run_tenant(host: str, port: int, tenant_id: str, idx: int,
                     samples: int, cfg_direct: api.CodecConfig,
                     cfg_coal: api.CodecConfig, report: dict) -> None:
    """One tenant's closed loop: a direct power-grid stream (byte-diffed
    against a shadow session) and a coalesced bursty-sensor stream
    (decode-diffed), then a decode phase through the batched mux."""
    t = {"tenant": tenant_id, "feeds": 0, "bytes_in": 0, "bytes_out": 0,
         "byte_diffs": 0, "decode_diffs": 0, "retries": 0, "decodes": 0}
    report["tenants"].append(t)
    grid = power_grid_trace(samples, seed=1000 + idx)
    sensor = bursty_sensor_trace(samples, seed=2000 + idx)
    shadow = IdealemCodec.from_config(cfg_direct).session()
    coal_codec = IdealemCodec.from_config(cfg_coal)

    async with FrontendClient(host, port, tenant_id) as c:
        await c.open("grid", cfg_direct, coalesce=False)
        await c.open("sensor", cfg_coal, coalesce=True)
        wire_direct, wire_coal = [], []

        async def feed(stream: str, chunk: np.ndarray) -> bytes:
            while True:
                try:
                    r = await c.feed(stream, chunk)
                except (RateLimitedError, ReproError) as exc:
                    retry = getattr(exc, "retry_after_s", None)
                    if retry is None:
                        raise
                    t["retries"] += 1
                    await asyncio.sleep(min(retry, 0.5))
                    continue
                t["feeds"] += 1
                t["bytes_in"] += chunk.nbytes
                t["bytes_out"] += len(r.segment)
                return r.segment

        shadow_segments = []
        g_iter = arrival_chunks(grid, "grid", seed=idx)
        s_iter = arrival_chunks(sensor, "burst", seed=idx)
        g_chunk, s_chunk = next(g_iter, None), next(s_iter, None)
        while g_chunk is not None or s_chunk is not None:
            if g_chunk is not None:
                wire_direct.append(await feed("grid", g_chunk))
                shadow_segments.append(shadow.feed(g_chunk))
                g_chunk = next(g_iter, None)
            if s_chunk is not None:
                wire_coal.append(await feed("sensor", s_chunk))
                s_chunk = next(s_iter, None)
        wire_direct.append((await c.close_stream("grid")).segment)
        wire_coal.append((await c.close_stream("sensor")).segment)
        shadow_segments.append(shadow.finish())

        direct_bytes = b"".join(wire_direct)
        if direct_bytes != b"".join(shadow_segments):
            t["byte_diffs"] += 1
        got = coal_codec.decode(b"".join(wire_coal))
        want = coal_codec.decode(coal_codec.encode(sensor))
        if not np.array_equal(got, want):
            t["decode_diffs"] += 1

        # decode phase: serve the direct stream's bytes back through the mux
        await c.attach("store", pack(direct_bytes))
        ref = IdealemCodec.from_config(cfg_direct).decode(direct_bytes)
        B = cfg_direct.block_size
        total_blocks = len(ref) // B
        rng = np.random.default_rng(3000 + idx)
        for k in range(8):
            start = int(rng.integers(0, max(1, total_blocks - 4)))
            stop = min(total_blocks, start + int(rng.integers(1, 16)))
            rr = await c.decode("store", start, stop,
                                request_id=f"{tenant_id}-d{k}")
            t["decodes"] += 1
            vals = np.asarray(rr.values).ravel()
            if not np.allclose(vals, ref[start * B:stop * B]):
                t["decode_diffs"] += 1


async def run_noisy_tenant(host: str, port: int, report: dict) -> None:
    """A tenant behind a deliberately tight bytes/s quota: its rejections
    prove admission control is live and observable in /metrics."""
    t = {"tenant": "noisy", "feeds": 0, "rejections_seen": 0}
    report["tenants"].append(t)
    cfg = api.CodecConfig(mode="std", block_size=32, backend="numpy")
    data = power_grid_trace(4096, seed=77)
    async with FrontendClient(host, port, "noisy") as c:
        await c.open("g", cfg)
        for i in range(0, len(data), 1024):
            try:
                await c.feed("g", data[i:i + 1024])
                t["feeds"] += 1
            except (RateLimitedError, ReproError) as exc:
                if getattr(exc, "code", "") in ("rate_limited",
                                                "quota_exceeded"):
                    t["rejections_seen"] += 1
                else:
                    raise
        await c.close_stream("g")


# -------------------------------------------------------------------- main
async def run(args) -> dict:
    report = {"config": {k: getattr(args, k) for k in
                         ("tenants", "samples", "slo_feed_p99_s",
                          "slo_decode_p99_s", "smoke")},
              "tenants": [], "slos": [], "ok": True, "problems": []}
    policy = FlushPolicy(max_batch_blocks=2048, max_batch_streams=32,
                         max_age_s=0.01)
    quotas = {"noisy": TenantQuota(max_bytes_per_s=64_000,
                                   burst_bytes=16_384)}
    cfg_direct = api.CodecConfig(mode="std", block_size=32, num_dict=63,
                                 backend="numpy")
    cfg_coal = api.CodecConfig(mode="residual", block_size=32, num_dict=63,
                               alpha=0.05, rel_tol=0.5)

    fe = await ServeFrontend(policy=policy, quotas=quotas,
                             decode_backend="numpy").start()
    t0 = time.perf_counter()
    try:
        jobs = [run_tenant(fe.host, fe.port, f"tenant-{i:02d}", i,
                           args.samples, cfg_direct, cfg_coal, report)
                for i in range(args.tenants)]
        jobs.append(run_noisy_tenant(fe.host, fe.port, report))
        await asyncio.gather(*jobs)

        async with FrontendClient(fe.host, fe.port, "probe") as c:
            metrics_text = await c.metrics()
            report["control"] = await c.control()
    finally:
        await fe.close()
    report["wall_s"] = time.perf_counter() - t0

    # ---------------------------------------------------------- verdicts
    byte_diffs = sum(t.get("byte_diffs", 0) for t in report["tenants"])
    decode_diffs = sum(t.get("decode_diffs", 0) for t in report["tenants"])
    rejections_seen = sum(t.get("rejections_seen", 0)
                          for t in report["tenants"])
    report["byte_diffs"] = byte_diffs
    report["decode_diffs"] = decode_diffs
    report["rejections_seen"] = rejections_seen
    if byte_diffs:
        report["problems"].append(f"{byte_diffs} direct stream(s) were not "
                                  "byte-identical to the shadow session")
    if decode_diffs:
        report["problems"].append(f"{decode_diffs} decode mismatch(es)")
    if not rejections_seen:
        report["problems"].append(
            "the rate-limited tenant saw no typed rejection")

    parsed = obs.parse_prometheus(metrics_text)
    rej = sum(v for (name, items), v in parsed.items()
              if name == "repro_frontend_rejections_total")
    report["metrics_rejections_total"] = rej
    if rej <= 0:
        report["problems"].append(
            "repro_frontend_rejections_total absent from /metrics")

    specs = [
        obs.SloSpec("repro_frontend_request_seconds", 0.99,
                    args.slo_feed_p99_s, {"route": "POST /v1/feed"}),
        obs.SloSpec("repro_frontend_request_seconds", 0.99,
                    args.slo_decode_p99_s, {"route": "POST /v1/decode"}),
    ]
    for res in obs.evaluate_slos(specs, parsed=parsed):
        report["slos"].append({"slo": res.spec.describe(),
                               "value": res.value, "ok": res.ok})
        if not res.ok:
            report["problems"].append(f"SLO breach: {res.describe()}")
        if res.value is None:
            report["problems"].append(
                f"no traffic recorded for {res.spec.describe()}")

    report["ok"] = not report["problems"]
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="loadgen", description=__doc__)
    ap.add_argument("--tenants", type=int, default=8,
                    help="concurrent verified tenants (>= 8 for the "
                    "acceptance profile)")
    ap.add_argument("--samples", type=int, default=8192,
                    help="trace length per stream")
    ap.add_argument("--slo-feed-p99-s", type=float, default=0.5)
    ap.add_argument("--slo-decode-p99-s", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: small traces, same checks")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report as JSON (soak artifact)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.samples = min(args.samples, 4096)

    report = asyncio.run(run(args))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
        print(f"report -> {args.json}")
    feeds = sum(t.get("feeds", 0) for t in report["tenants"])
    print(f"{len(report['tenants'])} tenants, {feeds} feeds, "
          f"{report['byte_diffs']} byte diffs, "
          f"{report['decode_diffs']} decode diffs, "
          f"{report['rejections_seen']} typed rejections, "
          f"{report['wall_s']:.1f}s")
    for s in report["slos"]:
        v = "n/a" if s["value"] is None else f"{s['value']:.4f}s"
        print(f"  {s['slo']} = {v} {'ok' if s['ok'] else 'BREACH'}")
    for p in report["problems"]:
        print(f"FAIL: {p}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
