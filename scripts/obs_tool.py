"""CLI over the unified telemetry layer (repro.obs, DESIGN.md Sec. 12).

  dump       [--format prom|json] [--no-workload]
             exercise a small end-to-end workload (coalesced encode ->
             packed container -> pipelined range decode) against the
             process-default registry and print the resulting snapshot
             as Prometheus text exposition (default) or JSON.
  slo        SCRAPE NAME:QUANTILE:MAX[:k=v,...] ...
             evaluate latency objectives against a scraped exposition
             file (``-`` reads stdin) -- the gate the serving soak runs
             on the loadgen's /metrics snapshot.
  selfcheck  the CI round trip (``make obs-check``): (1) the exporter
             round trip on a scratch registry covering all three
             instrument kinds, awkward label escapes included; (2) the
             live end-to-end: the workload above must populate the
             expected ``repro_<layer>_<name>`` metric families across
             encode, decode, store and serving from ONE registry
             snapshot, the exposition must parse back value-exact, and
             the span ring must hold all four serve stages.

Exit status: 0 clean, 1 failed check, 2 usage.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs  # noqa: E402

# one metric family per wired layer: the acceptance shape of ISSUE 8
EXPECTED_FAMILIES = (
    "repro_encode_bytes_in_total",        # session ingest
    "repro_encode_bytes_out_total",
    "repro_encode_blocks_total",
    "repro_encode_hits_total",
    "repro_encode_flushes_total",         # coalescer device batches
    "repro_encode_flush_seconds",
    "repro_decode_host_calls_total",      # unified decode engine
    "repro_decode_backend_calls_total",
    "repro_store_chunk_walks_total",      # container read path
    "repro_store_range_requests_total",
    "repro_serve_requests_total",         # serving
    "repro_serve_stage_seconds",
    "repro_serve_cache_hits_total",
)
EXPECTED_STAGES = ("plan", "gather", "reconstruct", "emit")


def run_workload() -> None:
    """Small but complete traffic: many coalesced streams flushed as one
    device batch, packed into a container, range-decoded through a
    pipelined ``DecompressionService``."""
    import numpy as np

    from repro.serve import (DecompressionService, FlushPolicy,
                             StreamCoalescer)
    from repro.store import Container, pack

    rng = np.random.default_rng(0)
    coal = StreamCoalescer(
        policy=FlushPolicy(max_batch_blocks=64, max_batch_streams=4),
        mode="std", block_size=16, num_dict=8)
    blobs = {}
    for sid in ("a", "b", "c"):
        coal.open_stream(sid)
        blobs[sid] = b""
    for _ in range(4):
        for sid in blobs:
            out = coal.submit(sid, rng.normal(0, 1, size=64)) or {}
            for k, seg in out.items():
                blobs[k] += seg
    for sid in list(blobs):
        blobs[sid] += coal.close_stream(sid)

    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=4, pipeline_depth=2),
        backend="numpy")
    svc.attach("s", Container(pack(blobs["a"])))
    for i, (start, stop) in enumerate([(0, 4), (4, 8), (2, 10), (0, 16)]):
        svc.submit(f"r{i}", "s", start, stop)
    svc.close()


def check_live() -> list:
    problems = []
    reg = obs.registry()
    run_workload()
    snap = reg.snapshot()
    for fam in EXPECTED_FAMILIES:
        if fam not in snap:
            problems.append(f"metric family missing after workload: {fam}")
    stage_hist = snap.get("repro_serve_stage_seconds", {"values": []})
    seen = {v["labels"].get("stage") for v in stage_hist["values"]
            if v.get("count", 0) > 0}
    for stage in EXPECTED_STAGES:
        if stage not in seen:
            problems.append(f"stage histogram never observed: {stage}")
    span_names = {s.name for s in obs.tracer().records(kind="span")}
    for stage in EXPECTED_STAGES:
        if f"serve.{stage}" not in span_names:
            problems.append(f"span ring missing serve.{stage}")
    if "encode.flush" not in span_names:
        problems.append("span ring missing encode.flush")
    problems.extend(obs.selfcheck(reg))
    return problems


def cmd_dump(args) -> int:
    if not args.no_workload:
        run_workload()
    if args.format == "json":
        import json
        json.dump(obs.to_json(), sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        sys.stdout.write(obs.to_prometheus())
    return 0


def cmd_selfcheck(args) -> int:
    problems = obs.selfcheck()  # scratch registry: exporter round trip
    if not problems:
        print("exporter round trip: OK")
    problems += check_live()
    if problems:
        for p in problems:
            print(f"FAIL: {p}")
        return 1
    print(f"live end-to-end: OK ({len(EXPECTED_FAMILIES)} families, "
          f"{len(EXPECTED_STAGES)} stage histograms, spans present)")
    return 0


def cmd_slo(args) -> int:
    """Evaluate ``NAME:QUANTILE:MAX[:k=v,...]`` specs against a scraped
    exposition file (``-`` = stdin) -- the same estimator the loadgen and
    the front end's control loop use."""
    text = (sys.stdin.read() if args.scrape == "-"
            else open(args.scrape).read())
    parsed = obs.parse_prometheus(text)
    specs = []
    for raw in args.spec:
        parts = raw.split(":")
        if len(parts) not in (3, 4):
            print(f"bad spec {raw!r}: NAME:QUANTILE:MAX[:k=v,...]",
                  file=sys.stderr)
            return 2
        labels = {}
        if len(parts) == 4 and parts[3]:
            for kv in parts[3].split(","):
                k, _, v = kv.partition("=")
                labels[k] = v
        specs.append(obs.SloSpec(parts[0], float(parts[1]), float(parts[2]),
                                 labels))
    failed = 0
    for res in obs.evaluate_slos(specs, parsed=parsed):
        print(res.describe())
        if not res.ok or (args.require_traffic and res.value is None):
            failed += 1
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="obs_tool")
    sub = ap.add_subparsers(dest="cmd")
    d = sub.add_parser("dump", help="exercise a workload and print metrics")
    d.add_argument("--format", choices=("prom", "json"), default="prom")
    d.add_argument("--no-workload", action="store_true",
                   help="dump the registry as-is, without traffic")
    sub.add_parser("selfcheck", help="exporter round trip + live e2e check")
    s = sub.add_parser("slo", help="evaluate SLO specs against a scrape")
    s.add_argument("scrape", help="Prometheus exposition file, or - (stdin)")
    s.add_argument("spec", nargs="+",
                   help="NAME:QUANTILE:MAX[:k=v,...], e.g. "
                   "repro_frontend_request_seconds:0.99:0.5:"
                   "route=POST /v1/feed")
    s.add_argument("--require-traffic", action="store_true",
                   help="an absent/empty histogram fails instead of "
                   "passing vacuously")
    args = ap.parse_args(argv)
    if args.cmd == "dump":
        return cmd_dump(args)
    if args.cmd == "selfcheck":
        return cmd_selfcheck(args)
    if args.cmd == "slo":
        return cmd_slo(args)
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
