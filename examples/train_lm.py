"""End-to-end training driver: LM training with fault tolerance, checkpoint
compression, and IDEALEM gradient compression.

Default is a CPU-sized model for a quick demo; the production path is the
same code jitted on the mesh (see repro/launch/train.py and dryrun.py).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 50 --gradcomp \
      --inject-crash 20
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import synthetic
from repro.runtime import FaultInjector, FaultTolerantTrainer
from repro.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gradcomp", action="store_true")
    ap.add_argument("--inject-crash", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True).replace(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    print(f"training {cfg.name}-smoke ({cfg.param_count() / 1e6:.2f}M params) "
          f"for {args.steps} steps")
    state = init_train_state(jax.random.key(0), cfg,
                             use_gradcomp=args.gradcomp)
    step = jax.jit(make_train_step(cfg, lr=3e-3, microbatches=2,
                                   use_gradcomp=args.gradcomp))
    injector = (FaultInjector({args.inject_crash: "crash"})
                if args.inject_crash is not None else None)
    trainer = FaultTolerantTrainer(
        train_step=step, state=state, ckpt_dir=args.ckpt_dir,
        ckpt_every=25, ckpt_codec="zstd", injector=injector)
    batches = list(synthetic.token_stream(args.steps, args.batch, args.seq,
                                          cfg.vocab_size))
    t0 = time.time()
    trainer.run(batches, args.steps)
    dt = time.time() - t0

    losses = [e["loss"] for e in trainer.log if "loss" in e]
    events = [e for e in trainer.log if "event" in e]
    toks = args.steps * args.batch * args.seq
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"({toks / dt:.0f} tok/s)")
    if events:
        print("fault-tolerance events:", events)
    assert np.mean(losses[-10:]) < losses[0], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
