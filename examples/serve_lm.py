"""Batched serving demo: prefill + decode across heterogeneous architectures
(attention KV caches, Mamba2 states, RWKV states behind one cache API).

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --gen 48
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = lm.init_params(jax.random.key(0), cfg)
    mem_len = (cfg.num_image_tokens if cfg.family == "vlm"
               else cfg.encoder_seq if cfg.family == "audio" else 0)
    engine = ServeEngine(cfg, params, max_seq=args.prompt_len + args.gen + 8,
                         memory_len=mem_len, temperature=args.temperature)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"{cfg.name}-smoke: {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  request {i}: {out[i][:12].tolist()} ...")


if __name__ == "__main__":
    main()
