"""Quickstart: compress a power-grid-like stream with IDEALEM.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IdealemCodec, quality_measures, spectral_band_error
from repro.data import synthetic


def main() -> None:
    n = 64 * 2048
    mag = synthetic.pmu_magnitude(n, seed=7)         # stationary + tap changes
    ang = synthetic.pmu_angle(n, seed=7)             # wrapping ramp [0,360)

    # --- standard mode on magnitude data (paper Table I: B=32, D=255) ---
    codec = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                         rel_tol=0.5)
    blob = codec.encode(mag)
    recon = codec.decode(blob)
    print(f"[std]      ratio={codec.compression_ratio(mag, blob):8.2f}  "
          f"(limit {8 * 32})")
    q0, q1 = quality_measures(mag), quality_measures(recon)
    print(f"           peaks {q0['m1_num_peaks']:.0f} -> {q1['m1_num_peaks']:.0f}, "
          f"outliers {q0['m6_pct_outliers']:.2f}% -> {q1['m6_pct_outliers']:.2f}%")
    print(f"           spectra: {spectral_band_error(mag, recon)}")

    # --- residual mode on phase angles (B=112, bounded range) ---
    codec = IdealemCodec(mode="residual", block_size=112, num_dict=255,
                         alpha=0.01, rel_tol=0.5, value_range=(0.0, 360.0))
    blob = codec.encode(ang)
    recon = codec.decode(blob)
    err = np.abs(recon - ang)
    circ = np.minimum(err, 360.0 - err)
    print(f"[residual] ratio={codec.compression_ratio(ang, blob):8.2f}  "
          f"(limit {8 * 112 / 9:.2f})")
    print(f"           circular err p95 = {np.percentile(circ, 95):.3f} deg")

    # --- min/max check preserves brief tap changes (paper Sec. VII-D) ---
    with_mm = IdealemCodec(mode="std", block_size=32, num_dict=255,
                           alpha=0.01, rel_tol=0.3)
    without = IdealemCodec(mode="std", block_size=32, num_dict=255,
                           alpha=0.01, use_minmax=False)
    jumps = lambda x: quality_measures(x)["m5_num_big_jumps"]
    y_mm = with_mm.decode(with_mm.encode(mag))
    y_no = without.decode(without.encode(mag))
    print(f"[minmax]   big jumps: orig={jumps(mag):.0f} "
          f"with={jumps(y_mm):.0f} without={jumps(y_no):.0f}")


if __name__ == "__main__":
    main()
