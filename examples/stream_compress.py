"""Streaming telemetry pipeline: a fleet of sensor channels compressed
*online* with IDEALEM -- the paper's deployment scenario (Sec. I, Fig. 15)
on the streaming session architecture (DESIGN.md Sec. 3).

Chunks arrive continuously; a batched ``IdealemSession`` keeps one FIFO
dictionary per channel alive across chunks, so the hit rate matches offline
one-shot compression.  For contrast we also run the naive approach (one-shot
encode per chunk, dictionary rebuilt every time) and show the hit rate it
throws away.

  PYTHONPATH=src python examples/stream_compress.py --channels 16
"""
import argparse
import time

import numpy as np

from repro.core import IdealemCodec
from repro.core.stream import decode_stream
from repro.data import synthetic
from repro.serve import CompressionService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", type=int, default=16)
    ap.add_argument("--samples", type=int, default=32 * 512)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=1024,
                    help="samples per channel per feed() call; a multiple of "
                         "--block keeps the device scan shape fixed "
                         "(one compile, steady-state throughput)")
    args = ap.parse_args()

    B = args.block
    chans = np.stack([
        synthetic.pmu_magnitude(args.samples, level=100 + 5 * i, noise=1.0,
                                seed=i) for i in range(args.channels)
    ])
    codec = IdealemCodec(mode="std", block_size=B, num_dict=255, alpha=0.01,
                         rel_tol=0.5)

    # --- streaming path: chunked feed through a batched session ---
    svc = CompressionService(mode="std", block_size=B, num_dict=255,
                             alpha=0.01, rel_tol=0.5)
    svc.open_stream("pmu-fleet", channels=args.channels)
    segments = [[] for _ in range(args.channels)]
    t0 = time.time()
    for lo in range(0, args.samples, args.chunk):
        segs = svc.feed("pmu-fleet", chans[:, lo:lo + args.chunk])
        for ci, s in enumerate(segs):
            segments[ci].append(s)
    final = svc.close_stream("pmu-fleet")
    dt = time.time() - t0
    for ci, s in enumerate(final):
        segments[ci].append(s)
    stats = svc.stats("pmu-fleet")["channels"]
    rate = args.channels * args.samples / dt / 1e6
    hit_rate = sum(s["hits"] for s in stats) / sum(s["blocks"] for s in stats)
    ratio = (sum(s["bytes_in"] for s in stats)
             / sum(s["bytes_out"] for s in stats))
    print(f"session (chunk={args.chunk}): {args.channels} ch x "
          f"{args.samples} samples in {dt:.2f}s ({rate:.1f} Msamples/s), "
          f"hit rate {hit_rate:.2%}, ratio {ratio:.1f}")

    # --- naive chunked path: one-shot encode per chunk (state discarded) ---
    naive_hits = naive_blocks = naive_bytes = 0
    for ci in range(min(args.channels, 4)):
        for lo in range(0, args.samples, args.chunk):
            st = codec.encode_stats(chans[ci, lo:lo + args.chunk])
            naive_hits += st["hits"]
            naive_blocks += st["blocks"]
            naive_bytes += st["bytes"]
    naive_in = min(args.channels, 4) * args.samples * chans.itemsize
    print(f"naive per-chunk one-shot: hit rate "
          f"{naive_hits / max(naive_blocks, 1):.2%}, ratio "
          f"{naive_in / max(naive_bytes, 1):.1f} "
          f"(dictionary rebuilt every chunk)")

    # --- verification: chunked output decodes exactly like one-shot ---
    for ci in range(min(args.channels, 4)):
        blob = b"".join(segments[ci])
        y = decode_stream(blob, seed=codec.decode_seed)
        y_ref = codec.decode(codec.encode(chans[ci]))
        assert len(y) == args.samples
        assert np.array_equal(y, y_ref), f"channel {ci} decode mismatch"
    print("chunked segments decode identically to one-shot encode "
          f"(verified on {min(args.channels, 4)} channels)")


if __name__ == "__main__":
    main()
