"""Streaming telemetry pipeline: a fleet of sensor channels compressed
online with IDEALEM (vmap-batched device encoder), with decode verification
-- the paper's deployment scenario as a data-pipeline substrate.

  PYTHONPATH=src python examples/stream_compress.py --channels 16
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import IdealemCodec
from repro.core.encoder import encode_decisions_batched
from repro.core.ks import critical_distance
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", type=int, default=16)
    ap.add_argument("--samples", type=int, default=32 * 512)
    ap.add_argument("--block", type=int, default=32)
    args = ap.parse_args()

    B = args.block
    chans = np.stack([
        synthetic.pmu_magnitude(args.samples, level=100 + 5 * i, noise=1.0,
                                seed=i) for i in range(args.channels)
    ])

    # --- device path: all channels encoded in one vmapped scan ---
    blocks = jnp.asarray(
        chans.reshape(args.channels, -1, B), dtype=jnp.float32)
    d_crit = float(critical_distance(0.01, B, B))
    t0 = time.time()
    is_hit, slot, ovw = encode_decisions_batched(
        blocks, num_dict=255, d_crit=d_crit, rel_tol=0.5)
    is_hit = np.asarray(is_hit)
    dt = time.time() - t0
    rate = args.channels * args.samples / dt / 1e6
    print(f"device encoder: {args.channels} channels x {args.samples} samples "
          f"in {dt:.2f}s ({rate:.1f} Msamples/s), "
          f"hit rate {is_hit.mean():.2%}")

    # --- host path: full byte-stream roundtrip per channel ---
    codec = IdealemCodec(mode="std", block_size=B, num_dict=255, alpha=0.01,
                         rel_tol=0.5)
    ratios = []
    for ch in chans[:4]:
        blob = codec.encode(ch)
        y = codec.decode(blob)
        assert len(y) == len(ch)
        ratios.append(codec.compression_ratio(ch, blob))
    print(f"stream ratios (first 4 channels): "
          f"{[round(r, 1) for r in ratios]}")


if __name__ == "__main__":
    main()
