"""Multi-tenant serving front end, end to end (DESIGN.md Sec. 14).

Starts a :class:`repro.serve.ServeFrontend` on localhost, then drives it
the way a fleet of clients would -- over the real wire protocol:

1. several tenants compress uPMU-like traces concurrently, mixing
   *direct* streams (per-feed dispatch, byte-identical to a local
   ``IdealemSession``) and *coalesced* streams (staged host-side, cut as
   one padded device batch when the ``FlushPolicy`` trips);
2. one tenant sits behind a tight bytes/s quota and shows the typed 429
   ``Retry-After`` dance;
3. the compressed container is attached back and range-decoded through
   the batched decode mux;
4. finally ``/metrics`` is scraped and the p99s printed -- the numbers
   the control loop (``repro.serve.control``) steers on.

  PYTHONPATH=src python examples/serve_frontend.py --tenants 4
"""
import argparse
import asyncio

import numpy as np

from repro import api, obs
from repro.core import IdealemCodec
from repro.data import synthetic
from repro.errors import RateLimitedError
from repro.serve import (FlushPolicy, FrontendClient, ServeFrontend,
                         TenantQuota)
from repro.store import pack


async def compress_tenant(fe, i: int, samples: int) -> None:
    cfg = api.CodecConfig(mode="std", block_size=32, num_dict=127,
                          backend="numpy")
    x = synthetic.pmu_magnitude(samples, level=100 + 5 * i, noise=1.0,
                                seed=i)
    shadow = IdealemCodec.from_config(cfg).session()
    async with FrontendClient(fe.host, fe.port, f"tenant-{i}") as c:
        await c.open("pmu", cfg)
        wire, ref = [], []
        for lo in range(0, samples, 1024):
            wire.append((await c.feed("pmu", x[lo:lo + 1024])).segment)
            ref.append(shadow.feed(x[lo:lo + 1024]))
        wire.append((await c.close_stream("pmu")).segment)
        ref.append(shadow.finish())
        blob, local = b"".join(wire), b"".join(ref)
        print(f"  tenant-{i}: {samples * 8} B -> {len(blob)} B over the "
              f"wire ({samples * 8 / len(blob):.1f}x), byte-identical to "
              f"the local session: {blob == local}")

        # decode it back through the batched mux
        await c.attach("pmu-store", pack(blob))
        got = await c.decode("pmu-store", 0, 16)
        want = IdealemCodec.from_config(cfg).decode(blob)[:16 * 32]
        ok = np.allclose(np.asarray(got.values).ravel(), want)
        print(f"  tenant-{i}: range decode of 16 blocks round-trips: {ok}")


async def throttled_tenant(fe, samples: int) -> None:
    cfg = api.CodecConfig(mode="std", block_size=32, backend="numpy")
    x = synthetic.pmu_magnitude(samples, level=120.0, noise=0.5, seed=99)
    rejected = 0
    async with FrontendClient(fe.host, fe.port, "throttled") as c:
        await c.open("pmu", cfg)
        for lo in range(0, samples, 2048):
            while True:
                try:
                    await c.feed("pmu", x[lo:lo + 2048])
                    break
                except RateLimitedError as exc:
                    rejected += 1
                    await asyncio.sleep(exc.retry_after_s or 0.05)
        await c.close_stream("pmu")
    print(f"  throttled: finished after {rejected} typed 429s "
          "(each carried Retry-After)")


async def main(args) -> None:
    policy = FlushPolicy(max_batch_blocks=2048, max_batch_streams=32,
                         max_age_s=0.01)
    quotas = {"throttled": TenantQuota(max_bytes_per_s=200_000,
                                       burst_bytes=32_768)}
    async with ServeFrontend(policy=policy, quotas=quotas,
                             control_interval_s=0.05,
                             decode_backend="numpy") as fe:
        print(f"front end on {fe.host}:{fe.port}, "
              f"policy={policy.as_dict()}")
        await asyncio.gather(
            *(compress_tenant(fe, i, args.samples)
              for i in range(args.tenants)),
            throttled_tenant(fe, args.samples))

        async with FrontendClient(fe.host, fe.port, "probe") as c:
            parsed = obs.parse_prometheus(await c.metrics())
            ctl = await c.control()
    for route in ("POST /v1/feed", "POST /v1/decode"):
        p99 = obs.quantile_from_parsed(
            parsed, "repro_frontend_request_seconds", 0.99,
            {"route": route})
        if p99 is not None:
            print(f"p99 {route}: {p99 * 1e3:.2f} ms")
    print(f"control loop: {ctl['control']['ticks']} ticks, "
          f"policy now {ctl['policy']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--samples", type=int, default=32 * 512)
    args = ap.parse_args()
    asyncio.run(main(args))
