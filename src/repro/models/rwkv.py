"""RWKV6 ("Finch") time-mix + channel-mix, attention-free (data-dependent
per-channel decay).

Training/prefill uses chunked linear attention: within a small chunk the
pairwise decay products are computed EXACTLY in log space (a (Q,Q,hd)
broadcast, numerically safe because log-decays are <= 0 and only s<t terms
are used); across chunks a ``lax.scan`` carries the per-head (hd x hd) wkv
state with bounded factors exp(LW_end - LW_s) <= 1.  Decode is the O(1)
recurrence.  This avoids the exp(-LW) overflow of the naive factorized GLA
form without giving up the matmul formulation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, logical, split_keys
from .layers import init_rmsnorm, rmsnorm

_LORA_RANK = 64


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.ssm_head_dim or 64
    H = d // hd
    return d, H, hd


def init_time_mix(key, cfg: ModelConfig):
    d, H, hd = _dims(cfg)
    ks = split_keys(key, ["r", "k", "v", "g", "o", "wa", "wb", "mu", "w0", "u"])
    return {
        "wr": dense_init(ks["r"], (d, d), 0, cfg.param_dtype),
        "wk": dense_init(ks["k"], (d, d), 0, cfg.param_dtype),
        "wv": dense_init(ks["v"], (d, d), 0, cfg.param_dtype),
        "wg": dense_init(ks["g"], (d, d), 0, cfg.param_dtype),
        "wo": dense_init(ks["o"], (d, d), 0, cfg.param_dtype),
        "w_lora_a": dense_init(ks["wa"], (d, _LORA_RANK), 0, cfg.param_dtype),
        "w_lora_b": dense_init(ks["wb"], (_LORA_RANK, d), 0, cfg.param_dtype),
        "mu": 0.5 * jnp.ones((5, d), cfg.param_dtype),  # r,k,v,w,g shift mix
        "w0": jnp.full((d,), -0.6, cfg.param_dtype),    # base log-log decay
        "u": jnp.zeros((H, hd), cfg.param_dtype),       # bonus
        "ln_out": init_rmsnorm(d, cfg.param_dtype),
    }


def init_channel_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = split_keys(key, ["k", "v", "r", "mu"])
    return {
        "wk": dense_init(ks["k"], (d, cfg.d_ff), 0, cfg.param_dtype),
        "wv": dense_init(ks["v"], (cfg.d_ff, d), 0, cfg.param_dtype),
        "wr": dense_init(ks["r"], (d, d), 0, cfg.param_dtype),
        "mu": 0.5 * jnp.ones((2, d), cfg.param_dtype),  # k,r shift mix
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` for t=0). x (B,S,d)."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def _projections(p, x, xprev, cfg: ModelConfig):
    d, H, hd = _dims(cfg)
    B, S, _ = x.shape
    dt = x.dtype
    mu = p["mu"]
    r = _mix(x, xprev, mu[0]) @ p["wr"].astype(dt)
    k = _mix(x, xprev, mu[1]) @ p["wk"].astype(dt)
    v = _mix(x, xprev, mu[2]) @ p["wv"].astype(dt)
    xw = _mix(x, xprev, mu[3])
    g = _mix(x, xprev, mu[4]) @ p["wg"].astype(dt)
    wl = jnp.tanh(xw @ p["w_lora_a"].astype(dt)) @ p["w_lora_b"].astype(dt)
    logw = -jnp.exp(
        jnp.clip(p["w0"].astype(jnp.float32) + wl.astype(jnp.float32), -8.0, 4.0)
    )  # (B,S,d) <= 0: per-channel log decay
    rs = r.reshape(B, S, H, hd)
    ks_ = k.reshape(B, S, H, hd)
    vs = v.reshape(B, S, H, hd)
    lw = logw.reshape(B, S, H, hd)
    return rs, ks_, vs, lw, g


class RwkvCache(NamedTuple):
    state: jax.Array    # (B, H, hd, hd) wkv state (k-dim x v-dim), f32
    last_tm: jax.Array  # (B, d) last input of time-mix
    last_cm: jax.Array  # (B, d) last input of channel-mix
    length: jax.Array


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=None) -> RwkvCache:
    d, H, hd = _dims(cfg)
    dt = dtype or cfg.dtype
    return RwkvCache(
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, d), dt),
        jnp.zeros((batch, d), dt),
        jnp.zeros((), jnp.int32),
    )


def time_mix_forward(p, x, cfg: ModelConfig):
    """x (B,S,d) -> (B,S,d); chunked scan over the wkv state."""
    d, H, hd = _dims(cfg)
    B, S, _ = x.shape
    dt_c = x.dtype
    r, k, v, lw, g = _projections(p, x, _shift(x), cfg)
    u = p["u"].astype(jnp.float32)

    Q = min(cfg.rwkv_chunk, S)
    pad = (-S) % Q
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, z4) for t in (r, k, v))
        lw = jnp.pad(lw, z4)
    nc = r.shape[1] // Q

    def to_chunks(t):
        return t.reshape(B, nc, Q, H, hd).transpose(1, 0, 3, 2, 4)  # (nc,B,H,Q,hd)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))

    def body(state, inp):
        rq, kq, vq, lwq = (t.astype(jnp.float32) for t in inp)  # (B,H,Q,hd)
        cum = jnp.cumsum(lwq, axis=2)                 # LW_t inclusive
        cum_in = cum - lwq                            # LW_{t-1} (decay from start to t-1)
        # inter: y_t = (r_t . exp(cum_in_t)) @ state
        y = jnp.einsum("bhqc,bhcv->bhqv", rq * jnp.exp(cum_in), state)
        # intra (exact, s<t): A[t,s] = sum_c r_tc k_sc exp(cum_in_t - cum_s)
        dec = jnp.exp(cum_in[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,H,t,s,hd)
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        a = jnp.einsum("bhtc,bhsc,bhtsc->bhts",
                       rq, kq, jnp.where(mask[None, None, :, :, None], dec, 0.0))
        y += jnp.einsum("bhts,bhsv->bhtv", a, vq)
        # bonus diagonal: r_t . diag(u) k_t v_t
        diag = jnp.sum(rq * u[None, :, None, :] * kq, axis=-1)  # (B,H,Q)
        y += diag[..., None] * vq
        # state update: S' = diag(exp(LW_end)) S + sum_s exp(LW_end - LW_s) k_s v_s
        tot = cum[:, :, -1:, :]                        # (B,H,1,hd)
        kd = kq * jnp.exp(tot - cum)                   # bounded <= 1 factors
        state = state * jnp.exp(tot[:, :, 0, :])[..., None] + jnp.einsum(
            "bhsc,bhsv->bhcv", kd, vq)
        return state, y

    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, yc = jax.lax.scan(body, state0, (rc, kc, vc, lwc))
    # yc: (nc, B, H, Q, hd) -> (B, nc, Q, H, hd) -> (B, S, d)
    y = yc.transpose(1, 0, 3, 2, 4).reshape(B, nc * Q, H * hd)[:, :S]
    y = rmsnorm(p["ln_out"], y.astype(dt_c), cfg.norm_eps)
    y = y * jax.nn.silu(g)
    return y @ p["wo"].astype(dt_c)


def time_mix_decode(p, x, cache: RwkvCache, cfg: ModelConfig):
    """x (B,1,d) one-token decode."""
    d, H, hd = _dims(cfg)
    B = x.shape[0]
    dt_c = x.dtype
    r, k, v, lw, g = _projections(p, x, cache.last_tm[:, None, :].astype(dt_c), cfg)
    rq, kq, vq = (t[:, 0].astype(jnp.float32) for t in (r, k, v))  # (B,H,hd)
    lwq = lw[:, 0].astype(jnp.float32)
    u = p["u"].astype(jnp.float32)
    # y = r . (state + diag(u) k^T v)
    y = jnp.einsum("bhc,bhcv->bhv", rq, cache.state)
    y += jnp.sum(rq * u[None] * kq, axis=-1)[..., None] * vq
    state = cache.state * jnp.exp(lwq)[..., None] + kq[..., None] * vq[:, :, None, :]
    y = y.reshape(B, 1, d).astype(dt_c)
    y = rmsnorm(p["ln_out"], y, cfg.norm_eps) * jax.nn.silu(g)
    out = y @ p["wo"].astype(dt_c)
    return out, RwkvCache(state, x[:, 0], cache.last_cm, cache.length + 1)


def channel_mix_forward(p, x, cfg: ModelConfig, last=None):
    dt = x.dtype
    xprev = _shift(x, last)
    mu = p["mu"]
    k = _mix(x, xprev, mu[0]) @ p["wk"].astype(dt)
    r = _mix(x, xprev, mu[1]) @ p["wr"].astype(dt)
    h = jnp.square(jax.nn.relu(k))
    h = logical(h, "batch", None, "ff")
    return jax.nn.sigmoid(r) * (h @ p["wv"].astype(dt))


def channel_mix_decode(p, x, cache: RwkvCache, cfg: ModelConfig):
    out = channel_mix_forward(p, x, cfg, last=cache.last_cm.astype(x.dtype))
    return out, cache._replace(last_cm=x[:, 0])
