"""Primitive layers: norms, MLPs, embeddings (pure pytrees + apply fns)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, logical, split_keys


# ------------------------------------------------------------------- RMSNorm
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, ["gate", "up", "down"])
    p = {
        "up": dense_init(ks["up"], (d, f), 0, cfg.param_dtype),
        "down": dense_init(ks["down"], (f, d), 0, cfg.param_dtype),
    }
    if cfg.act == "swiglu":
        p["gate"] = dense_init(ks["gate"], (d, f), 0, cfg.param_dtype)
    return p


def mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    up = x @ p["up"].astype(dt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["gate"].astype(dt)) * up
    else:
        h = jax.nn.gelu(up)
    h = logical(h, "batch", None, "ff")
    return h @ p["down"].astype(dt)


# ----------------------------------------------------------------- embedding
def init_embed(key, cfg: ModelConfig):
    ks = split_keys(key, ["table", "unembed"])
    p = {"table": dense_init(ks["table"], (cfg.vocab_size, cfg.d_model), 1,
                             cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks["unembed"], (cfg.d_model, cfg.vocab_size),
                                  0, cfg.param_dtype)
    return p


def embed(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["table"], tokens, axis=0).astype(cfg.dtype)
    return logical(x, "batch", None, None)


def unembed(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = p["table"].T
    else:
        w = p["unembed"]
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return logical(logits, "batch", None, "vocab")
