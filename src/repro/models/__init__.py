from .common import ModelConfig, set_sharding_rules
from . import lm

__all__ = ["ModelConfig", "set_sharding_rules", "lm"]
