"""Shared model config, parameter initialization, and logical sharding."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------- config


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 256
    head_dim: Optional[int] = None
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # Expert-parallel sharding of the expert dim. Perf iteration 1 (see
    # EXPERIMENTS.md §Perf): scatter-dispatch across a sharded expert dim
    # makes XLA replicate the (B, S*K, d) token buffers => TB-scale
    # all-reduces. FFN-TP inside experts keeps dispatch device-local.
    moe_expert_parallel: bool = False
    # attention pattern
    sliding_window: Optional[int] = None   # SWA on all attention layers
    local_global_ratio: int = 0            # gemma3: N local layers per global
    local_window: int = 1024
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0                    # zamba2: shared attn every k layers
    # vlm
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    # misc
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    act: str = "swiglu"  # swiglu | gelu
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs)
    scan_layers: bool = True
    train_microbatches: int = 8  # grad-accumulation steps at train_4k scale
    rwkv_chunk: int = 64
    ssm_chunk: int = 128
    attn_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6*N*D)."""
        import math
        from .lm import init_params  # lazy; avoids cycle
        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.key(0))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active-per-token params (MoE: only experts_per_token of them)."""
        import math
        total = self.param_count()
        if self.num_experts == 0:
            return total
        from .lm import init_params
        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.key(0))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        expert = sum(
            math.prod(x.shape)
            for path, x in flat
            if any("experts" in str(p) for p in path)
        )
        frac = self.experts_per_token / self.num_experts
        return int(total - expert + expert * frac)


# ------------------------------------------------------------------ init util


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if len(shape) else 1
    scale = 1.0 / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------- logical sharding
# The launcher registers logical-axis -> mesh-axis rules; on CPU tests no
# rules are registered and `logical()` is a no-op, so model code is mesh-free.

_RULES: Optional[dict] = None


def set_sharding_rules(rules: Optional[dict]) -> None:
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    global _RULES
    _RULES = rules


def get_sharding_rules() -> Optional[dict]:
    return _RULES


def logical(x, *axes: Optional[str]):
    """Attach a sharding constraint by logical axis names (no-op w/o rules)."""
    if _RULES is None:
        return x
    spec = P(*[_RULES.get(a) if a is not None else None for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)
