"""Model assembly: heterogeneous layer stacks as scanned stages.

A config maps to a *stage plan*: a list of (pattern, repeats) where pattern
is a tuple of layer kinds (e.g. gemma3's 5 local + 1 global super-block).
Each stage's parameters are stacked over `repeats` and applied with
``lax.scan`` (+ optional remat), so HLO size is O(#stages), not O(depth).

Layer kinds:
  attn          self-attention + MLP (window = cfg.sliding_window if set)
  attn_local    sliding-window self-attention + MLP (cfg.local_window)
  attn_global   full self-attention + MLP
  enc_attn      bidirectional self-attention + MLP (encoder)
  dec_attn      causal self-attn + cross-attn(memory) + MLP (enc-dec decoder)
  moe_attn      self-attention + MoE FFN
  cross         cross-attention(memory) + MLP (VLM image layers)
  ssm           Mamba2 block
  shared_attn   zamba2's weight-shared attention block (params stored once)
  rwkv          RWKV6 time-mix + channel-mix
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attention, cross_attention, decode_attention,
                        init_attention, init_kv_cache)
from .common import ModelConfig, logical, split_keys
from .layers import embed, init_embed, init_mlp, init_rmsnorm, mlp, rmsnorm, unembed
from .moe import init_moe, moe_ffn
from .rwkv import (channel_mix_decode, channel_mix_forward,
                   init_channel_mix, init_rwkv_cache, init_time_mix,
                   time_mix_decode, time_mix_forward)
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward

LOSS_CHUNK = 1024

# ------------------------------------------------------------------ planning


def stage_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    L = cfg.num_layers
    if cfg.family == "moe":
        return [(("moe_attn",), L)]
    if cfg.family == "ssm":
        return [(("rwkv",), L)]
    if cfg.family == "hybrid":
        k = cfg.attn_every or 6
        reps, rem = divmod(L, k)
        plan = []
        if reps:
            plan.append((("shared_attn",) + ("ssm",) * k, reps))
        if rem:
            plan.append((("ssm",), rem))
        return plan
    if cfg.family == "vlm":
        k = cfg.cross_attn_every or 5
        reps, rem = divmod(L, k)
        plan = []
        if reps:
            plan.append((("attn",) * (k - 1) + ("cross",), reps))
        if rem:
            plan.append((("attn",), rem))
        return plan
    if cfg.family == "audio":  # decoder side; encoder handled separately
        return [(("dec_attn",), L)]
    # dense
    if cfg.local_global_ratio:
        k = cfg.local_global_ratio
        reps, rem = divmod(L, k + 1)
        plan = []
        if reps:
            plan.append((("attn_local",) * k + ("attn_global",), reps))
        if rem:
            plan.append((("attn_local",), rem))
        return plan
    return [(("attn",), L)]


def _kind_window(kind: str, cfg: ModelConfig) -> Optional[int]:
    if kind == "attn_local":
        return cfg.local_window
    if kind in ("attn", "moe_attn", "shared_attn"):
        return cfg.sliding_window
    return None


# ---------------------------------------------------------------------- init


def _init_layer(key, kind: str, cfg: ModelConfig):
    ks = split_keys(key, ["a", "b", "c", "d", "e", "f"])
    n = lambda: init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if kind in ("attn", "attn_local", "attn_global", "enc_attn", "shared_attn"):
        return {"norm1": n(), "attn": init_attention(ks["a"], cfg),
                "norm2": n(), "mlp": init_mlp(ks["b"], cfg)}
    if kind == "moe_attn":
        return {"norm1": n(), "attn": init_attention(ks["a"], cfg),
                "norm2": n(), "moe": init_moe(ks["b"], cfg)}
    if kind == "cross":
        return {"norm1": n(), "cross": init_attention(ks["a"], cfg, cross=True),
                "norm2": n(), "mlp": init_mlp(ks["b"], cfg)}
    if kind == "dec_attn":
        return {"norm1": n(), "attn": init_attention(ks["a"], cfg),
                "norm_x": n(), "cross": init_attention(ks["c"], cfg, cross=True),
                "norm2": n(), "mlp": init_mlp(ks["b"], cfg)}
    if kind == "ssm":
        return {"norm1": n(), "ssm": init_ssm(ks["a"], cfg)}
    if kind == "rwkv":
        return {"norm1": n(), "tm": init_time_mix(ks["a"], cfg),
                "norm2": n(), "cm": init_channel_mix(ks["b"], cfg)}
    raise ValueError(kind)


def _init_pattern(key, pattern, cfg):
    keys = jax.random.split(key, len(pattern))
    return {
        f"p{i}": _init_layer(keys[i], kind, cfg)
        for i, kind in enumerate(pattern) if kind != "shared_attn"
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    plan = stage_plan(cfg)
    ks = split_keys(key, ["embed", "stages", "shared", "final", "enc"])
    params: Dict[str, Any] = {"embed": init_embed(ks["embed"], cfg),
                              "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    skeys = jax.random.split(ks["stages"], len(plan))
    stages = []
    for (pattern, reps), sk in zip(plan, skeys):
        if reps == 1 or not cfg.scan_layers:
            rkeys = jax.random.split(sk, reps)
            stages.append(jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_init_pattern(rk, pattern, cfg) for rk in rkeys]))
        else:
            stages.append(jax.vmap(
                lambda k: _init_pattern(k, pattern, cfg))(jax.random.split(sk, reps)))
    params["stages"] = stages
    if any("shared_attn" in pat for pat, _ in plan):
        params["shared"] = _init_layer(ks["shared"], "shared_attn", cfg)
    if cfg.encoder_layers:
        ekeys = jax.random.split(ks["enc"], cfg.encoder_layers)
        params["encoder"] = {
            "stage": jax.vmap(
                lambda k: _init_pattern(k, ("enc_attn",), cfg))(ekeys),
            "norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        }
    return params


# ------------------------------------------------------------- forward train


def _apply_layer(kind, p, x, cfg, memory):
    """One layer, training/prefill. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "attn_global", "enc_attn", "shared_attn"):
        causal = kind != "enc_attn"
        x = x + attention(p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg,
                          causal=causal, window=_kind_window(kind, cfg))
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
    elif kind == "moe_attn":
        x = x + attention(p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg,
                          causal=True, window=_kind_window(kind, cfg))
        y, aux = moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
        x = x + y
    elif kind == "cross":
        x = x + cross_attention(p["cross"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                memory, cfg)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
    elif kind == "dec_attn":
        x = x + attention(p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg,
                          causal=True)
        x = x + cross_attention(p["cross"], rmsnorm(p["norm_x"], x, cfg.norm_eps),
                                memory, cfg)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
    elif kind == "ssm":
        x = x + ssm_forward(p["ssm"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg)
    elif kind == "rwkv":
        x = x + time_mix_forward(p["tm"], rmsnorm(p["norm1"], x, cfg.norm_eps), cfg)
        x = x + channel_mix_forward(p["cm"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
    else:
        raise ValueError(kind)
    return x, aux


def _apply_stage(stage_params, pattern, x, cfg, memory, shared):
    def body(carry, pslice):
        h, aux = carry
        for i, kind in enumerate(pattern):
            p = shared if kind == "shared_attn" else pslice[f"p{i}"]
            h, a = _apply_layer(kind, p, h, cfg, memory)
            aux = aux + a
        h = logical(h, "batch", None, None)
        return (h, aux), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stage_params)
    return x, aux


def forward_hidden(params, tokens, cfg: ModelConfig, memory=None):
    """tokens (B,S) -> hidden (B,S,d), aux loss."""
    x = embed(params["embed"], tokens, cfg)
    if cfg.family == "audio" and memory is None:
        raise ValueError("audio model needs encoder memory")
    aux_total = jnp.zeros((), jnp.float32)
    for stage_params, (pattern, _) in zip(params["stages"], stage_plan(cfg)):
        x, aux = _apply_stage(stage_params, pattern, x, cfg, memory,
                              params.get("shared"))
        aux_total = aux_total + aux
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def encode_frames(params, frames, cfg: ModelConfig):
    """Whisper encoder over stubbed frame embeddings (B,F,d)."""
    x = frames.astype(cfg.dtype)
    x, _ = _apply_stage(params["encoder"]["stage"], ("enc_attn",), x, cfg,
                        None, None)
    return rmsnorm(params["encoder"]["norm"], x, cfg.norm_eps)


def lm_loss(params, batch, cfg: ModelConfig):
    """Next-token CE, chunked over the sequence so (S,V) logits are never
    materialized at once (vocab up to 262k).  batch: dict with tokens,
    labels, and optional memory/frames."""
    memory = batch.get("memory")
    if cfg.family == "audio":
        memory = encode_frames(params, batch["frames"], cfg)
    x, aux = forward_hidden(params, batch["tokens"], cfg, memory)
    labels = batch["labels"]
    B, S, _ = x.shape
    C = min(LOSS_CHUNK, S)
    pad = (-S) % C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // C
    xc = x.reshape(B, nc, C, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        xch, lch = inp
        logits = unembed(params["embed"], xch, cfg)  # f32 (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        valid = (lch >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    body = jax.checkpoint(chunk_loss, prevent_cse=False) if cfg.remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    return loss


# ------------------------------------------------------------------- serving


class DecodeCache(NamedTuple):
    stages: Tuple[Any, ...]   # per stage: dict p{i} -> stacked layer caches
    memory: Optional[jax.Array]  # VLM image / whisper encoder output


def _init_layer_cache(kind, cfg, batch, max_seq, memory_len):
    if kind in ("attn", "attn_local", "attn_global", "moe_attn", "shared_attn"):
        return init_kv_cache(cfg, batch, max_seq, _kind_window(kind, cfg))
    if kind == "cross":
        return init_kv_cache(cfg, batch, memory_len)
    if kind == "dec_attn":
        return {"self": init_kv_cache(cfg, batch, max_seq),
                "cross": init_kv_cache(cfg, batch, memory_len)}
    if kind == "ssm":
        return init_ssm_cache(cfg, batch)
    if kind == "rwkv":
        return init_rwkv_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               memory_len: int = 0) -> DecodeCache:
    stages = []
    for pattern, reps in stage_plan(cfg):
        one = {
            f"p{i}": _init_layer_cache(kind, cfg, batch, max_seq, memory_len)
            for i, kind in enumerate(pattern)
        }
        stages.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one))
    mem = None
    if memory_len:
        mem = jnp.zeros((batch, memory_len, cfg.d_model), cfg.dtype)
    return DecodeCache(tuple(stages), mem)


def _cross_decode(p, x, kv: KVCache, cfg):
    """Decode-time cross attention against precomputed memory K/V."""
    B = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, h, hd)
    from .attention import _repeat_kv  # local import to reuse
    kk = _repeat_kv(kv.k.astype(dt), h)
    vv = _repeat_kv(kv.v.astype(dt), h)
    s = jnp.einsum("bohd,bthd->bhot", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhot,bthd->bohd", w, vv.astype(jnp.float32))
    return (o.reshape(B, 1, h * hd).astype(dt)) @ p["wo"].astype(dt)


def _decode_layer(kind, p, x, cache, cfg):
    if kind in ("attn", "attn_local", "attn_global", "moe_attn", "shared_attn"):
        y, new = decode_attention(p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                  cache, cfg, window=_kind_window(kind, cfg))
        x = x + y
        if kind == "moe_attn":
            y, _ = moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
            x = x + y
        else:
            x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
        return x, new
    if kind == "cross":
        x = x + _cross_decode(p["cross"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                              cache, cfg)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
        return x, cache
    if kind == "dec_attn":
        y, new_self = decode_attention(
            p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), cache["self"], cfg)
        x = x + y
        x = x + _cross_decode(p["cross"], rmsnorm(p["norm_x"], x, cfg.norm_eps),
                              cache["cross"], cfg)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg)
        return x, {"self": new_self, "cross": cache["cross"]}
    if kind == "ssm":
        y, new = ssm_decode(p["ssm"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                            cache, cfg)
        return x + y, new
    if kind == "rwkv":
        y, new = time_mix_decode(p["tm"], rmsnorm(p["norm1"], x, cfg.norm_eps),
                                 cache, cfg)
        x = x + y
        y, new = channel_mix_decode(p["cm"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                                    new, cfg)
        return x + y, new
    raise ValueError(kind)


def decode_step(params, cache: DecodeCache, tokens, cfg: ModelConfig):
    """tokens (B,1) -> (logits (B,1,V), new cache)."""
    x = embed(params["embed"], tokens, cfg)
    new_stages = []
    for stage_params, stage_cache, (pattern, _) in zip(
            params["stages"], cache.stages, stage_plan(cfg)):

        def body(h, inp):
            pslice, cslice = inp
            new_c = {}
            for i, kind in enumerate(pattern):
                p = params.get("shared") if kind == "shared_attn" else pslice.get(f"p{i}")
                h, new_c[f"p{i}"] = _decode_layer(kind, p, h, cslice[f"p{i}"], cfg)
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (stage_params, stage_cache))
        new_stages.append(new_cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, DecodeCache(tuple(new_stages), cache.memory)
