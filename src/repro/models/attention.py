"""Attention: RoPE, GQA, flash-style chunked softmax, SWA/local-global,
cross-attention, and single-token decode against a KV cache.

The training/prefill path never materializes the (S x T) score matrix in HBM:
a ``lax.scan`` over KV chunks keeps the online-softmax running max/denominator
(m, l) and the output accumulator in registers/VMEM-sized tiles -- the
TPU-idiomatic flash formulation.  Masking (causal / sliding window) is
computed from position indices per chunk, so sliding-window layers can bound
their KV cache to the window length (ring buffer) at decode time.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, dense_init, logical, split_keys

_NEG_INF = -1e30


# ---------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- param blocks
def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = split_keys(key, ["q", "k", "v", "o"])
    return {
        "wq": dense_init(ks["q"], (d, h * hd), 0, cfg.param_dtype),
        "wk": dense_init(ks["k"], (d, kvh * hd), 0, cfg.param_dtype),
        "wv": dense_init(ks["v"], (d, kvh * hd), 0, cfg.param_dtype),
        "wo": dense_init(ks["o"], (h * hd, d), 0, cfg.param_dtype),
    }


# ------------------------------------------------------- flash core (q long)
def _flash(q, k, v, q_pos, kv_pos, *, causal: bool, window: Optional[int],
           chunk: int, kv_len: Optional[jax.Array] = None):
    """q: (B,S,H,hd), k/v: (B,T,H,hd) (kv already repeated to H heads).

    Returns (B,S,H,hd).  Masks: causal (q_pos >= kv_pos), sliding window
    (q_pos - kv_pos < window), kv_len (kv_pos < kv_len) for padded caches.

    Sliding-window self-attention takes the BANDED path (perf iteration 3,
    EXPERIMENTS.md §Perf): q is chunked too and each q chunk visits only the
    ceil(window/chunk)+1 kv chunks inside its band, so attention traffic and
    FLOPs scale with S*window instead of S*T.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    chunk = min(chunk, T)
    if (window is not None and causal and S == T and kv_len is None
            and S % chunk == 0 and S // chunk > window // chunk + 1):
        return _flash_banded(q, k, v, q_pos, window=window, chunk=chunk)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max // 2)
    nchunk = k.shape[1] // chunk
    kc = k.reshape(B, nchunk, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nchunk, chunk)

    # Perf iteration 2 (EXPERIMENTS.md §Perf): keep QK/PV matmul operands in
    # the compute dtype (bf16) and accumulate in f32 via
    # preferred_element_type -- f32 operands leak f32 cotangents into the
    # backward TP all-reduces (2x wire bytes) and HBM traffic.
    scale = jnp.asarray(1.0 / np.sqrt(hd), q.dtype)
    qs = q * scale

    def body(carry, inp):
        o, m, l = carry
        kb, vb, pb = inp  # (B,chunk,H,hd), (B,chunk,H,hd), (chunk,)
        s = jnp.einsum("bshd,bthd->bhst", qs, kb,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((S, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= pb[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - pb[None, :]) < window
        if kv_len is not None:
            mask &= pb[None, :] < kv_len
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bshd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        ).transpose(0, 2, 1, 3)
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, H, S, hd), jnp.float32)
    m0 = jnp.full((B, H, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (kc, vc, pc))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,S,H,hd)


def _flash_banded(q, k, v, q_pos, *, window: int, chunk: int):
    """Sliding-window causal self-attention with q-chunking: each q chunk
    attends only to its band of kv chunks (indices qi-band+1 .. qi)."""
    B, S, H, hd = q.shape
    nq = S // chunk
    band = window // chunk + 1
    scale = jnp.asarray(1.0 / np.sqrt(hd), q.dtype)
    qc = (q * scale).reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pos_c = q_pos.reshape(nq, chunk)

    def q_block(carry, inp):
        qi = inp["idx"]  # scalar chunk index
        qb, qp = inp["q"], inp["pos"]  # (B,chunk,H,hd), (chunk,)
        o = jnp.zeros((B, H, chunk, hd), jnp.float32)
        m = jnp.full((B, H, chunk), _NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, chunk), jnp.float32)
        for b in range(band):
            j = jnp.maximum(qi - b, 0)
            kb = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
            pb = jax.lax.dynamic_index_in_dim(pos_c, j, 0, keepdims=False)
            s = jnp.einsum("bshd,bthd->bhst", qb, kb,
                           preferred_element_type=jnp.float32)
            mask = (qp[:, None] >= pb[None, :]) \
                & ((qp[:, None] - pb[None, :]) < window) \
                & (qi - b >= 0)
            s = jnp.where(mask[None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + jnp.einsum(
                "bhst,bthd->bshd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32).transpose(0, 2, 1, 3)
            m = m_new
        out = (o / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)
        return carry, out.astype(q.dtype)

    xs = {"idx": jnp.arange(nq, dtype=jnp.int32), "q": qc, "pos": pos_c}
    _, oc = jax.lax.scan(q_block, (), xs)
    return oc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def _repeat_kv(x, h: int):
    kvh = x.shape[2]
    if kvh == h:
        return x
    return jnp.repeat(x, h // kvh, axis=2)


# ---------------------------------------------------------------- public ops
def attention(p, x, cfg: ModelConfig, *, causal=True, window=None,
              positions=None, use_rope=True):
    """Self-attention over x (B,S,d) for training / prefill."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, kvh, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, kvh, hd)
    q = logical(q, "batch", None, "heads", None)
    k = logical(k, "batch", None, "kv_heads", None)
    v = logical(v, "batch", None, "kv_heads", None)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    o = _flash(q, k, v, positions, positions, causal=causal, window=window,
               chunk=cfg.attn_chunk)
    o = o.reshape(B, S, h * hd)
    return o @ p["wo"].astype(dt)


def cross_attention(p, x, memory, cfg: ModelConfig):
    """x (B,S,d) attends to memory (B,M,d); no mask, no rope."""
    B, S, _ = x.shape
    M = memory.shape[1]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, h, hd)
    k = (memory @ p["wk"].astype(dt)).reshape(B, M, kvh, hd)
    v = (memory @ p["wv"].astype(dt)).reshape(B, M, kvh, hd)
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    qp = jnp.arange(S, dtype=jnp.int32)
    kp = jnp.arange(M, dtype=jnp.int32)
    o = _flash(q, k, v, qp, kp, causal=False, window=None, chunk=cfg.attn_chunk)
    return o.reshape(B, S, h * hd) @ p["wo"].astype(dt)


# --------------------------------------------------------------- decode path
class KVCache(NamedTuple):
    k: jax.Array  # (B, C, kvh, hd)  C = window or max_seq
    v: jax.Array
    length: jax.Array  # () int32: tokens seen so far (ring for windowed)


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  window: Optional[int] = None, dtype=None) -> KVCache:
    c = min(window, max_seq) if window else max_seq
    dt = dtype or cfg.dtype
    shape = (batch, c, cfg.num_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                   jnp.zeros((), jnp.int32))


def decode_attention(p, x, cache: KVCache, cfg: ModelConfig, *,
                     window: Optional[int] = None, use_rope=True):
    """One-token decode: x (B,1,d) + cache -> (out (B,1,d), new cache).

    Windowed layers use a ring buffer of size `window`; full layers append.
    """
    B = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    C = cache.k.shape[1]
    pos = cache.length  # scalar position of the new token
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, 1, kvh, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, 1, kvh, hd)
    if use_rope:
        posv = pos[None].astype(jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    slot = jnp.mod(pos, C)
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    # positions stored in each ring slot (for rope-consistent masking)
    idx = jnp.arange(C, dtype=jnp.int32)
    # slot i currently holds absolute position: latest write wins
    abs_pos = jnp.where(idx <= slot, pos - (slot - idx), pos - C + (idx - slot))
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= (pos - abs_pos) < window

    kk = _repeat_kv(new_k.astype(dt), h)
    vv = _repeat_kv(new_v.astype(dt), h)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bohd,bthd->bhot", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhot,bthd->bohd", w, vv.astype(jnp.float32))
    o = o.reshape(B, 1, h * hd).astype(dt)
    out = o @ p["wo"].astype(dt)
    return out, KVCache(new_k, new_v, pos + 1)


def prefill_kv(p, x, cfg: ModelConfig, max_seq: int,
               window: Optional[int] = None) -> KVCache:
    """Build a cache from a full prompt (used by serve prefill)."""
    B, S, _ = x.shape
    kvh, hd = cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    k = (x @ p["wk"].astype(dt)).reshape(B, S, kvh, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, kvh, hd)
    k = rope(k, jnp.arange(S, dtype=jnp.int32), cfg.rope_theta)
    cache = init_kv_cache(cfg, B, max_seq, window, dtype=dt)
    C = cache.k.shape[1]
    take = min(S, C)
    # ring invariant: absolute position t lives in slot t mod C
    slots = (jnp.arange(take, dtype=jnp.int32) + (S - take)) % C
    kk = cache.k.at[:, slots].set(k[:, S - take:].astype(cache.k.dtype))
    vv = cache.v.at[:, slots].set(v[:, S - take:].astype(cache.v.dtype))
    return KVCache(kk, vv, jnp.asarray(S, jnp.int32))
