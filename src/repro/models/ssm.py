"""Mamba2 (SSD) layer: chunked matmul-form state-space scan (TPU-native).

Training/prefill uses the state-space-duality chunked algorithm: within a
chunk of Q tokens everything is dense matmuls with an exact (Q,Q) decay
matrix per head (the per-head decay is scalar, so no log-space tricks are
needed); across chunks a ``lax.scan`` carries the (H,N,P) state.  Decode is
the O(1) recurrence on the same state plus a depthwise-conv ring cache.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, logical, split_keys
from .layers import init_rmsnorm, rmsnorm


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = di + 2 * N
    return di, H, P, N, conv_ch


def init_ssm(key, cfg: ModelConfig):
    di, H, P, N, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = split_keys(key, ["in", "out", "conv", "A", "dt"])
    return {
        "in_proj": dense_init(ks["in"], (d, 2 * di + 2 * N + H), 0, cfg.param_dtype),
        "out_proj": dense_init(ks["out"], (di, d), 0, cfg.param_dtype),
        "conv_w": dense_init(ks["conv"], (cfg.ssm_conv, conv_ch), 0, cfg.param_dtype),
        "A_log": jnp.zeros((H,), cfg.param_dtype),
        "D": jnp.ones((H,), cfg.param_dtype),
        "dt_bias": jnp.zeros((H,), cfg.param_dtype),
        "norm": init_rmsnorm(di, cfg.param_dtype),
    }


def _split_proj(p, u, cfg: ModelConfig):
    di, H, P, N, conv_ch = _dims(cfg)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_ch], axis=-1)
    return z, xbc, dt


def _conv_train(p, xbc, cfg: ModelConfig):
    """Causal depthwise conv over (B,S,ch)."""
    kw = cfg.ssm_conv
    w = p["conv_w"].astype(xbc.dtype)  # (kw, ch)
    pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(kw))
    return jax.nn.silu(out)


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, N, P)
    conv: jax.Array        # (B, kw-1, conv_ch)
    length: jax.Array      # () int32


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None) -> SSMCache:
    di, H, P, N, conv_ch = _dims(cfg)
    dt = dtype or cfg.dtype
    return SSMCache(
        jnp.zeros((batch, H, N, P), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dt),
        jnp.zeros((), jnp.int32),
    )


def ssm_forward(p, u, cfg: ModelConfig):
    """u (B,S,d_model) -> (B,S,d_model), chunked SSD scan."""
    di, H, P, N, _ = _dims(cfg)
    B, S, _ = u.shape
    dt_c = u.dtype
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc = _conv_train(p, xbc, cfg)
    x, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    x = x.reshape(B, S, H, P)
    x = logical(x, "batch", None, "heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    a = dt * A  # (B,S,H) per-step log decay

    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // Q

    def to_chunks(t):
        return t.reshape((B, nc, Q) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xc, bc, cc, dtc, ac = map(to_chunks, (x, Bm, Cm, dt, a))

    def body(state, inp):
        xq, bq, cq, dtq, aq = inp  # (B,Q,...)
        cum = jnp.cumsum(aq, axis=1)  # (B,Q,H)
        # intra-chunk: y[t] = sum_{s<=t} exp(cum_t-cum_s) (C_t.B_s) dt_s x_s
        scores = jnp.einsum("btn,bsn->bts", cq.astype(jnp.float32),
                            bq.astype(jnp.float32))  # (B,Q,Q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        w_ts = scores[..., None] * L  # (B,t,s,H)
        dx = dtq[..., None] * xq.astype(jnp.float32)  # (B,Q,H,P)
        y = jnp.einsum("btsh,bshp->bthp", w_ts, dx)
        # inter-chunk: y[t] += exp(cum_t) C_t . state
        y += jnp.einsum("btn,bhnp,bth->bthp", cq.astype(jnp.float32), state,
                        jnp.exp(cum))
        # state update
        tot = cum[:, -1:, :]  # (B,1,H)
        sdecay = jnp.exp(tot - cum)  # (B,Q,H) decay from s to chunk end
        state = state * jnp.exp(tot[:, 0, :])[:, :, None, None] + jnp.einsum(
            "bsh,bsn,bshp->bhnp", sdecay, bq.astype(jnp.float32), dx)
        return state, y.astype(dt_c)

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, yc = jax.lax.scan(body, state0, (xc, bc, cc, dtc, ac))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, P)[:, :S]
    y = y + x[:, :S] * p["D"].astype(dt_c)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_c)


def ssm_decode(p, u, cache: SSMCache, cfg: ModelConfig) -> Tuple[jax.Array, SSMCache]:
    """One-token decode: u (B,1,d_model) -> (B,1,d_model) + new cache."""
    di, H, P, N, conv_ch = _dims(cfg)
    B = u.shape[0]
    dt_c = u.dtype
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    # conv ring: window = [cache (kw-1), new]
    win = jnp.concatenate([cache.conv, xbc.astype(cache.conv.dtype)], axis=1)
    w = p["conv_w"].astype(jnp.float32)  # (kw, ch)
    conv_out = jnp.sum(win.astype(jnp.float32) * w[None], axis=1)  # (B,ch)
    xbc1 = jax.nn.silu(conv_out).astype(dt_c)
    x, Bm, Cm = jnp.split(xbc1, [di, di + N], axis=-1)
    x = x.reshape(B, H, P)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # (B,H)
    inc = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32),
                     x.astype(jnp.float32))
    state = cache.state * decay[..., None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), state)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(dt_c)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_c)
    new_cache = SSMCache(state, win[:, 1:], cache.length + 1)
    return out, new_cache
