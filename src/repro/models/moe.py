"""Mixture-of-Experts FFN with top-k routing and capacity-bounded scatter
dispatch (GShard-style, per-batch-row groups so the position-in-expert cumsum
never crosses the sharded batch axis).

Expert weights are stacked (E, d, f): shardable either on the expert axis
(EP, when E % tp == 0) or on the FFN axis (Megatron-style TP inside each
expert) -- the launcher picks via sharding rules ("experts" / "ff").
Aux load-balancing loss follows Switch (mean gate fraction * token fraction).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, logical, split_keys


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, ["router", "gate", "up", "down"])
    p = {
        "router": dense_init(ks["router"], (d, e), 0, cfg.param_dtype),
        "experts_up": dense_init(ks["up"], (e, d, f), 1, cfg.param_dtype),
        "experts_down": dense_init(ks["down"], (e, f, d), 1, cfg.param_dtype),
    }
    if cfg.act == "swiglu":
        p["experts_gate"] = dense_init(ks["gate"], (e, d, f), 1, cfg.param_dtype)
    return p


def moe_ffn(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    dt = x.dtype
    C = max(int(S * K / E * cfg.capacity_factor), 1)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, topk = jax.lax.top_k(probs, K)  # (B,S,K)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: E * mean_e(fraction of tokens) * mean_e(gate mass)
    token_frac = jnp.mean(
        jax.nn.one_hot(topk[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    gate_frac = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(token_frac * gate_frac)

    # position of each (token, k) inside its expert, per batch row
    flat = topk.reshape(B, S * K)  # expert ids
    oh = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (B, S*K, E)
    pos = jnp.cumsum(oh, axis=1) - 1  # position within expert
    pos_in_e = jnp.sum(pos * oh, axis=-1)  # (B, S*K)
    keep = pos_in_e < C

    # scatter tokens into (B, E, C, d) buffers
    xrep = jnp.repeat(x, K, axis=1)  # (B, S*K, d) token copies
    buf = jnp.zeros((B, E, C, d), dt)
    bidx = jnp.arange(B)[:, None]
    safe_pos = jnp.where(keep, pos_in_e, 0)
    buf = buf.at[bidx, flat, jnp.where(keep, safe_pos, C - 1)].add(
        jnp.where(keep[..., None], xrep, 0), mode="drop"
    )
    buf = logical(buf, "batch", "experts", None, None)

    up = jnp.einsum("becd,edf->becf", buf, p["experts_up"].astype(dt))
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, p["experts_gate"].astype(dt))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    # "moe_ff" maps to the model axis only when experts don't (EP vs TP)
    h = logical(h, "batch", "experts", None, "moe_ff")
    out_buf = jnp.einsum("becf,efd->becd", h, p["experts_down"].astype(dt))

    # gather back and combine with gates
    y_tok = out_buf[bidx, flat, safe_pos]  # (B, S*K, d)
    y_tok = jnp.where(keep[..., None], y_tok, 0)
    y_tok = y_tok * gates.reshape(B, S * K)[..., None].astype(dt)
    y = jnp.sum(y_tok.reshape(B, S, K, d), axis=2)
    return logical(y, "batch", None, None), aux
