"""AdamW with decoupled weight decay, global-norm clipping, f32 master state.

Optimizer state is a pytree mirroring params; the launcher shards it with the
same rules as params plus ZeRO-1 (the `data` axis), so each data-parallel
replica owns a slice of (mu, nu).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: Optional[float] = 1.0):
    """lr: float or Callable[step] -> float."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    gn = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gn, "lr": lr_t}


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return sched
