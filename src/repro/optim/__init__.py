from . import adamw, gradcomp
from .adamw import AdamWState, global_norm, warmup_cosine

__all__ = ["adamw", "gradcomp", "AdamWState", "global_norm", "warmup_cosine"]
