"""IDEALEM gradient compression with error feedback (beyond-paper feature).

At 1000+ nodes the cross-pod gradient reduction is the scarcest link
(DCN/ICI ~50 GB/s vs 197 TFLOP/s).  We apply the paper's exchangeability
coding to flattened gradient blocks: blocks that are statistically
exchangeable with a dictionary entry (two-sample KS + min/max gate) are
replaced by a 1-byte index on the wire; the receiver substitutes the
dictionary block values.  Unlike telemetry, gradients are order-sensitive,
so substitution is *duplication* (paper Sec. V-B2 semantics, no random
permutation) and the resulting per-coordinate error is fed back into the
next step's gradient (error-feedback accumulator), which restores
convergence in expectation.

``compress()`` is a pure jittable function: decisions on device, the wire
byte accounting is returned as metrics (1 byte/hit-block vs 4*B bytes).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.encoder import encode_decisions
from repro.core.ks import critical_distance


class GradCompState(NamedTuple):
    residual: dict  # error-feedback accumulator, mirrors params


def init(params) -> GradCompState:
    return GradCompState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


@functools.partial(jax.jit, static_argnames=("block", "num_dict", "d_crit", "rel_tol"))
def _compress_flat(flat: jax.Array, *, block: int, num_dict: int,
                   d_crit: float, rel_tol: float):
    n = flat.shape[0]
    nb = n // block
    blocks = flat[: nb * block].reshape(nb, block)
    is_hit, slot, _ = encode_decisions(
        blocks, num_dict=num_dict, d_crit=d_crit, rel_tol=rel_tol)
    # receiver-side reconstruction: hit blocks replaced by their dictionary
    # entry (the most recent miss stored in that slot)
    miss_idx = jnp.where(~is_hit, jnp.arange(nb), -1)
    # for each slot, the index of the last miss written to it, per block time
    def scan_fn(carry, inp):
        slots_last = carry  # (num_dict,) last miss block idx per slot
        hit, s, i = inp
        slots_last = jnp.where(
            (~hit) & (jnp.arange(num_dict) == s), i, slots_last)
        src = jnp.where(hit, slots_last[s], i)
        return slots_last, src

    _, src = jax.lax.scan(
        scan_fn, jnp.zeros((num_dict,), jnp.int32),
        (is_hit, slot, jnp.arange(nb, dtype=jnp.int32)))
    recon_blocks = blocks[src]
    recon = jnp.concatenate([recon_blocks.reshape(-1), flat[nb * block:]])
    hits = jnp.sum(is_hit)
    bytes_orig = jnp.asarray(nb * block * 4, jnp.float32)
    bytes_wire = hits * 1.0 + (nb - hits) * (block * 4.0 + 1.0)
    return recon, {"hit_rate": hits / jnp.maximum(nb, 1),
                   "wire_ratio": bytes_orig / jnp.maximum(bytes_wire, 1.0)}


def compress(grads, state: GradCompState, *, block: int = 256,
             num_dict: int = 32, alpha: float = 0.05,
             rel_tol: float = 0.5) -> Tuple[dict, GradCompState, dict]:
    """grads + error feedback -> (transmitted grads, new state, metrics)."""
    d_crit = critical_distance(alpha, block, block)
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(state.residual)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate(
        [(g.astype(jnp.float32) + r.astype(jnp.float32)).reshape(-1)
         for g, r in zip(leaves, res_leaves)])
    recon, metrics = _compress_flat(
        flat, block=block, num_dict=num_dict, d_crit=float(d_crit),
        rel_tol=rel_tol)
    err = flat - recon
    out, res = [], []
    off = 0
    for g, sz in zip(leaves, sizes):
        out.append(recon[off:off + sz].reshape(g.shape).astype(g.dtype))
        res.append(err[off:off + sz].reshape(g.shape))
        off += sz
    return (jax.tree.unflatten(treedef, out),
            GradCompState(jax.tree.unflatten(treedef, res)),
            metrics)
