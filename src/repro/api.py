"""Wire-typed public API: the request/response types every entry point
shares (DESIGN.md Sec. 14).

The in-process services (``repro.serve.compress``) and the network front
end (``repro.serve.frontend``) speak the SAME types: a
:class:`CompressRequest` handed to ``CompressionService.handle`` is
byte-for-byte the object the front end decodes off the wire, so there is
exactly one place where payload encoding, validation and defaults live.

Every type round-trips through JSON (``to_json``/``from_json``): numpy
payloads travel as base64 of their raw little-endian bytes next to a
dtype tag, segment/container bytes as plain base64.  ``from_json``
validates strictly -- unknown keys and malformed fields raise
:class:`repro.errors.ApiError` (protocol code ``bad_request``), never a
bare ``KeyError`` -- because these constructors face the network.

:class:`CodecConfig` is the one serializable description of a codec: the
frozen, hashable counterpart of ``IdealemCodec``'s keyword sprawl.
Per-tenant codec configs travel over the wire through this type and
``IdealemCodec.from_config``/``.config`` round-trip it; plain kwargs keep
working unchanged.

Dependency-light by design: numpy + stdlib only (no jax import), so
clients can use the wire types without pulling the device stack.
"""
from __future__ import annotations

import base64
import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from .errors import ApiError

__all__ = [
    "CodecConfig",
    "CompressRequest",
    "FeedResult",
    "DecodeRangeRequest",
    "RangeResult",
    "encode_array",
    "decode_array",
    "encode_bytes",
    "decode_bytes",
]


# ------------------------------------------------------------ payload codecs
def encode_array(x: np.ndarray) -> dict:
    """1-D numpy array -> JSON-ready ``{"dtype", "b64"}`` document."""
    x = np.ascontiguousarray(x)
    return {"dtype": x.dtype.str, "b64": base64.b64encode(
        x.tobytes()).decode("ascii")}


def decode_array(doc: object, what: str = "array") -> np.ndarray:
    """Inverse of :func:`encode_array`; raises :class:`ApiError` on any
    malformed input (this constructor faces the network)."""
    if not isinstance(doc, dict) or "b64" not in doc or "dtype" not in doc:
        raise ApiError(f"{what}: expected {{'dtype', 'b64'}} object")
    try:
        dt = np.dtype(doc["dtype"])
        raw = base64.b64decode(doc["b64"], validate=True)
    except Exception as exc:
        raise ApiError(f"{what}: {exc}") from None
    if dt.itemsize == 0 or len(raw) % dt.itemsize:
        raise ApiError(f"{what}: {len(raw)} bytes is not a whole number "
                       f"of {dt.str} items")
    return np.frombuffer(raw, dtype=dt).copy()


def encode_bytes(b: bytes) -> str:
    return base64.b64encode(b).decode("ascii")


def decode_bytes(doc: object, what: str = "bytes") -> bytes:
    if not isinstance(doc, str):
        raise ApiError(f"{what}: expected base64 string")
    try:
        return base64.b64decode(doc, validate=True)
    except Exception as exc:
        raise ApiError(f"{what}: {exc}") from None


def _require(doc: dict, key: str, typ, what: str):
    if key not in doc:
        raise ApiError(f"{what}: missing field {key!r}")
    v = doc[key]
    if typ is float and isinstance(v, int):
        v = float(v)
    if typ is not None and not isinstance(v, typ):
        raise ApiError(f"{what}: field {key!r} must be {typ.__name__}, "
                       f"got {type(v).__name__}")
    return v


def _reject_unknown(doc: dict, known, what: str) -> None:
    extra = set(doc) - set(known)
    if extra:
        raise ApiError(f"{what}: unknown field(s) {sorted(extra)}")


# -------------------------------------------------------------- codec config
@dataclass(frozen=True)
class CodecConfig:
    """Frozen, JSON-serializable description of an ``IdealemCodec``.

    One value of this type pins every knob a codec instance needs --
    it IS the wire format for per-tenant codec configuration, and the
    hashable key under which the front end caches tenant codecs.
    ``repro.core.IdealemCodec.from_config(cfg)`` builds the codec;
    ``codec.config`` gives the config back (round-trip stable: the codec
    resolves ``error_bound_rel`` to ``error_bound`` once, and the config
    carries the resolved absolute bound).

    The adaptive ``selector`` schedule is deliberately NOT part of this
    type: ``SelectorConfig`` defaults are pinned by ``adaptive=True``, and
    custom selector schedules are an in-process tuning surface, not a wire
    contract.
    """

    mode: str = "std"
    block_size: int = 32
    num_dict: int = 255
    alpha: float = 0.01
    rel_tol: float = 0.1
    use_minmax: bool = True
    use_ks: bool = True
    max_count: int = 255
    value_range: Optional[Tuple[float, float]] = None
    backend: str = "jax"
    matcher: Optional[str] = None
    decode_seed: int = 0
    decode_backend: str = "numpy"
    error_bound: Optional[float] = None
    adaptive: bool = False

    def __post_init__(self):
        if self.value_range is not None:
            vr = tuple(float(v) for v in self.value_range)
            if len(vr) != 2:
                raise ApiError("CodecConfig: value_range must be (lo, hi)")
            object.__setattr__(self, "value_range", vr)

    def to_json(self) -> dict:
        """JSON-ready dict holding only the non-default knobs (a config
        serialized by an older client stays readable as defaults move)."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v != f.default:
                out[f.name] = list(v) if isinstance(v, tuple) else v
        return out

    @classmethod
    def from_json(cls, doc: object) -> "CodecConfig":
        if doc is None:
            return cls()
        if not isinstance(doc, dict):
            raise ApiError("CodecConfig: expected object")
        names = {f.name for f in dataclasses.fields(cls)}
        _reject_unknown(doc, names, "CodecConfig")
        kw = dict(doc)
        if kw.get("value_range") is not None:
            vr = kw["value_range"]
            if (not isinstance(vr, (list, tuple)) or len(vr) != 2
                    or not all(isinstance(v, (int, float)) for v in vr)):
                raise ApiError("CodecConfig: value_range must be [lo, hi]")
            kw["value_range"] = tuple(float(v) for v in vr)
        try:
            return cls(**kw)
        except (TypeError, ValueError) as exc:
            raise ApiError(f"CodecConfig: {exc}") from None

    def kwargs(self) -> dict:
        """The ``IdealemCodec(**kwargs)`` form of this config."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


# ---------------------------------------------------------------- wire types
@dataclass(frozen=True, eq=False)
class CompressRequest:
    """Feed ``samples`` into open stream ``stream_id``.

    The same object serves both call forms: in-process
    ``CompressionService.handle(req)`` and the front end's
    ``POST /v1/streams/{id}/feed``.  ``samples`` is 1-D (the front end
    serves single-channel wire streams; batched multi-channel cohorts are
    an in-process shape)."""

    stream_id: str
    samples: np.ndarray

    def __post_init__(self):
        arr = np.asarray(self.samples)
        if arr.ndim != 1:
            raise ApiError("CompressRequest: samples must be 1-D")
        object.__setattr__(self, "samples", arr)

    def to_json(self) -> dict:
        return {"stream_id": self.stream_id,
                "samples": encode_array(self.samples)}

    @classmethod
    def from_json(cls, doc: object) -> "CompressRequest":
        if not isinstance(doc, dict):
            raise ApiError("CompressRequest: expected object")
        _reject_unknown(doc, ("stream_id", "samples"), "CompressRequest")
        return cls(
            stream_id=_require(doc, "stream_id", str, "CompressRequest"),
            samples=decode_array(_require(doc, "samples", None,
                                          "CompressRequest"),
                                 "CompressRequest.samples"))


@dataclass(frozen=True, eq=False)
class FeedResult:
    """One feed's (or close's) outcome: the emitted segment bytes plus the
    accounting delta this call produced.  ``segment`` may be empty (the
    samples joined a sub-block tail, or a coalesced stream staged them for
    a later flush); concatenating every returned segment of a stream
    yields the decodable stream."""

    stream_id: str
    segment: bytes = b""
    blocks: int = 0
    hits: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    final: bool = False

    def to_json(self) -> dict:
        return {"stream_id": self.stream_id,
                "segment": encode_bytes(self.segment),
                "blocks": self.blocks, "hits": self.hits,
                "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
                "final": self.final}

    @classmethod
    def from_json(cls, doc: object) -> "FeedResult":
        if not isinstance(doc, dict):
            raise ApiError("FeedResult: expected object")
        _reject_unknown(doc, ("stream_id", "segment", "blocks", "hits",
                              "bytes_in", "bytes_out", "final"),
                        "FeedResult")
        return cls(
            stream_id=_require(doc, "stream_id", str, "FeedResult"),
            segment=decode_bytes(doc.get("segment", ""),
                                 "FeedResult.segment"),
            blocks=_require(doc, "blocks", int, "FeedResult"),
            hits=_require(doc, "hits", int, "FeedResult"),
            bytes_in=_require(doc, "bytes_in", int, "FeedResult"),
            bytes_out=_require(doc, "bytes_out", int, "FeedResult"),
            final=bool(doc.get("final", False)))


@dataclass(frozen=True, eq=False)
class DecodeRangeRequest:
    """Range-decode blocks ``[start_block, stop_block)`` of a channel of
    an attached container.  ``request_id`` correlates the answer through
    batched/pipelined serving (auto-assigned by the front end when
    empty)."""

    store_id: str
    start_block: int
    stop_block: int
    channel: int = 0
    request_id: str = ""

    def __post_init__(self):
        if not (0 <= int(self.start_block) < int(self.stop_block)):
            raise ApiError(
                f"DecodeRangeRequest: bad range [{self.start_block}, "
                f"{self.stop_block})")

    def to_json(self) -> dict:
        return {"store_id": self.store_id,
                "start_block": int(self.start_block),
                "stop_block": int(self.stop_block),
                "channel": int(self.channel),
                "request_id": self.request_id}

    @classmethod
    def from_json(cls, doc: object) -> "DecodeRangeRequest":
        if not isinstance(doc, dict):
            raise ApiError("DecodeRangeRequest: expected object")
        _reject_unknown(doc, ("store_id", "start_block", "stop_block",
                              "channel", "request_id"), "DecodeRangeRequest")
        return cls(
            store_id=_require(doc, "store_id", str, "DecodeRangeRequest"),
            start_block=_require(doc, "start_block", int,
                                 "DecodeRangeRequest"),
            stop_block=_require(doc, "stop_block", int, "DecodeRangeRequest"),
            channel=int(doc.get("channel", 0)),
            request_id=str(doc.get("request_id", "")))


@dataclass(frozen=True, eq=False)
class RangeResult:
    """A range request's reconstructed samples."""

    request_id: str
    values: np.ndarray = field(default_factory=lambda: np.empty(0))

    def to_json(self) -> dict:
        return {"request_id": self.request_id,
                "values": encode_array(self.values)}

    @classmethod
    def from_json(cls, doc: object) -> "RangeResult":
        if not isinstance(doc, dict):
            raise ApiError("RangeResult: expected object")
        _reject_unknown(doc, ("request_id", "values"), "RangeResult")
        return cls(
            request_id=_require(doc, "request_id", str, "RangeResult"),
            values=decode_array(_require(doc, "values", None, "RangeResult"),
                                "RangeResult.values"))
