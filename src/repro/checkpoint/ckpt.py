"""Checkpointing: manifest + per-leaf arrays, atomic rename, async save,
optional IDEALEM or zstd payload compression.

Layout:  <dir>/step_<N>.tmp/ -> (atomic rename) -> <dir>/step_<N>/
           manifest.json      tree structure, shapes, dtypes, codec
           leaf_<i>.bin       raw | zstd | idealem-compressed payload

A half-written checkpoint can never be picked up by ``latest_step`` because
the rename is the commit point -- the crash-consistency contract the fault-
tolerance driver (repro.runtime) relies on.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np
import zstandard as zstd

from repro.core import IdealemCodec

_CODEC_NONE, _CODEC_ZSTD, _CODEC_IDEALEM = "none", "zstd", "idealem"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def _encode_leaf(arr: np.ndarray, codec: str) -> Tuple[bytes, str]:
    raw = arr.tobytes()
    if codec == _CODEC_ZSTD:
        return zstd.ZstdCompressor(level=3).compress(raw), _CODEC_ZSTD
    if codec == _CODEC_IDEALEM and arr.dtype in (np.float32, np.float64) \
            and arr.size >= 4096:
        c = IdealemCodec(mode="std", block_size=64, num_dict=255, alpha=0.05,
                         rel_tol=0.3, backend="numpy")
        blob = c.encode(arr.reshape(-1).astype(np.float64))
        if len(blob) < len(raw):
            return blob, _CODEC_IDEALEM
        return zstd.ZstdCompressor(level=3).compress(raw), _CODEC_ZSTD
    return raw, _CODEC_NONE


def _decode_leaf(data: bytes, codec: str, shape, dtype) -> np.ndarray:
    if codec == _CODEC_ZSTD:
        data = zstd.ZstdDecompressor().decompress(data)
        return np.frombuffer(data, dtype=dtype).reshape(shape).copy()
    if codec == _CODEC_IDEALEM:
        c = IdealemCodec(mode="std", block_size=64, num_dict=255, alpha=0.05,
                         rel_tol=0.3, backend="numpy")
        flat = c.decode(data).astype(dtype)
        return flat.reshape(shape)
    return np.frombuffer(data, dtype=dtype).reshape(shape).copy()


def save(path: str, step: int, tree: Any, codec: str = _CODEC_NONE) -> str:
    """Write checkpoint atomically; returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, arr in enumerate(leaves):
        blob, used = _encode_leaf(arr, codec)
        with open(os.path.join(tmp, f"leaf_{i}.bin"), "wb") as f:
            f.write(blob)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype), "codec": used})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit point
    return final


def async_save(path: str, step: int, tree: Any,
               codec: str = _CODEC_NONE) -> threading.Thread:
    """Snapshot to host memory synchronously, write in a background thread."""
    leaves, treedef = _flatten(tree)  # device->host copy happens here
    snapshot = jax.tree.unflatten(treedef, leaves)
    t = threading.Thread(target=save, args=(path, step, snapshot, codec))
    t.start()
    return t


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = jax.tree.flatten(like)
    assert len(like_leaves) == len(manifest["leaves"]), "tree structure mismatch"
    out = []
    for i, (ref, meta) in enumerate(zip(like_leaves, manifest["leaves"])):
        with open(os.path.join(d, f"leaf_{i}.bin"), "rb") as f:
            data = f.read()
        arr = _decode_leaf(data, meta["codec"], meta["shape"], meta["dtype"])
        assert tuple(arr.shape) == tuple(np.shape(ref)), \
            f"leaf {i}: {arr.shape} vs {np.shape(ref)}"
        out.append(arr)
    return jax.tree.unflatten(treedef, out)
