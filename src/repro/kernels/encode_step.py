"""Pallas TPU kernel: one fused IDEALEM encode step (DESIGN.md Sec. 10).

``dict_match`` fused the two similarity checks (KS + min/max gate) into one
kernel, but the encoder's scan step remained a *composition*: the matcher
dispatch, the ``ks <= d_crit`` threshold, the arg-min over D and the FIFO
dictionary overwrite each ran as separate XLA ops with the full (D,) ks/mm
vectors materialized between them.  This kernel is the whole per-block step
in a single dispatch:

  1. min/max gate first (eq. 3), per dictionary tile -- and the gate result
     *masks the KS work*: a tile where no valid entry passes the gate skips
     its (tile_d, n, n) rank computation entirely (the paper's acceleration,
     now at the kernel level via ``@pl.when``).
  2. two-sample KS distance (eq. 1) on surviving tiles, with arithmetic
     identical to ``dict_match``.  Decisions match the composed pallas path
     for any threshold strictly between KS jump points (KS values are
     multiples of 1/n; XLA fusion choices such as FMA contraction can move
     a computed value by one ulp, so a d_crit placed *exactly* on k/n is
     undefined territory -- ``critical_distance`` thresholds never are).
  3. running arg-min of the lowest passing global index, accumulated across
     tiles in the ``dec`` output block (grid programs execute sequentially
     on TPU, so a revisited output block is a cross-program accumulator).
  4. the FIFO slot overwrite on miss, applied by the last program in the
     same dispatch -- the updated dictionary carry leaves the kernel ready
     for the next scan step.

Dictionary tiles stream through VMEM via a tiled BlockSpec, so the pallas
pipeline double-buffers them against compute; the carry-out buffers use a
constant index map and stay VMEM-resident across the whole grid.

D must be padded to a ``tile_d`` multiple with ``valid=False`` rows (the
encoder pads once at scan entry); padded rows never pass the gate and are
never inserted because the FIFO slot is ``count % num_dict`` with the
*logical* D.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dict_match import TILE_D, check_tile_divisible

__all__ = ["encode_step_pallas", "SENTINEL",
           "DEC_BEST", "DEC_HIT", "DEC_SLOT", "DEC_OVER", "DEC_COUNT",
           "CHAN_NF", "CHAN_INV_N", "CHAN_DCRIT", "CHAN_ERRCUM",
           "CHAN_EBON"]

# "no entry passed" marker for the running arg-min; any real global index
# (< 2^8 dictionary rows) is far below it.
SENTINEL = 2 ** 30

# layout of the (8,) int32 decision block (rows 5..7 are padding)
DEC_BEST, DEC_HIT, DEC_SLOT, DEC_OVER, DEC_COUNT = range(5)

# layout of the optional (8,) f32 per-channel parameter operand (mixed-mode
# adaptive cohorts, DESIGN.md Sec. 13; rows 5..7 are padding)
CHAN_NF, CHAN_INV_N, CHAN_DCRIT, CHAN_ERRCUM, CHAN_EBON = range(5)


def _encode_step_kernel(d_crit, rel_tol, use_minmax, use_ks, error_bound,
                        error_cumulative, num_dict, tile_d, chan, *refs):
    chan_ref = None
    if chan:
        chan_ref, *refs = refs
    if error_bound is None:
        (xs_ref, meta_ref, dict_ref, dmin_ref, dmax_ref, valid_ref,
         new_dict_ref, new_dmin_ref, new_dmax_ref, new_valid_ref,
         dec_ref) = refs
        raw_ref = rawdict_ref = new_raw_ref = None
    else:
        (xs_ref, raw_ref, meta_ref, dict_ref, rawdict_ref, dmin_ref,
         dmax_ref, valid_ref, new_dict_ref, new_raw_ref, new_dmin_ref,
         new_dmax_ref, new_valid_ref, dec_ref) = refs
    i = pl.program_id(0)
    nprog = pl.num_programs(0)
    n = xs_ref.shape[0]
    off = i * tile_d

    xs = xs_ref[:].astype(jnp.float32)       # (n,) sorted candidate
    ds = dict_ref[:, :].astype(jnp.float32)  # (tile_d, n) dictionary tile
    dmin = dmin_ref[:].astype(jnp.float32)
    dmax = dmax_ref[:].astype(jnp.float32)
    dvalid = valid_ref[:]
    if chan:
        # per-channel parameters replace the static d_crit/inv_n/err_cum;
        # tail columns beyond the channel's logical width are +inf pads,
        # masked out of every width-dependent reduction (Sec. 13)
        cp = chan_ref[:].astype(jnp.float32)
        inv_n = cp[CHAN_INV_N]
        col_ok = jax.lax.iota(jnp.float32, n) < cp[CHAN_NF]
        # == xs[n_c - 1] on sorted data: the masked max of the real columns
        xmax_v = jnp.max(jnp.where(col_ok, xs, -jnp.inf))
    else:
        inv_n = 1.0 / n

    @pl.when(i == 0)
    def _init():
        dec_ref[...] = jnp.zeros((8,), jnp.int32)
        dec_ref[DEC_BEST] = jnp.int32(SENTINEL)

    # Carry-out starts as a copy of the carry-in; the last program below
    # overwrites (at most) the one FIFO row.
    new_dict_ref[pl.ds(off, tile_d), :] = dict_ref[:, :]
    new_dmin_ref[pl.ds(off, tile_d)] = dmin_ref[:]
    new_dmax_ref[pl.ds(off, tile_d)] = dmax_ref[:]
    new_valid_ref[pl.ds(off, tile_d)] = dvalid

    # --- min/max gate first (eq. 3): arithmetic identical to dict_match ---
    if use_minmax:
        r = jnp.float32(rel_tol)
        xmin = xs[0]
        xmax = xmax_v if chan else xs[n - 1]
        t = (dmax - dmin) * r
        mm = ((xmin >= dmin - t) & (xmin <= dmin + t)
              & (xmax >= dmax - t) & (xmax <= dmax + t))
        gate = dvalid & mm
    else:
        gate = dvalid

    if error_bound is not None:
        # carry the raw (stream-order) rows alongside the sorted ones and
        # fold the pointwise-error demotion into the gate: a tile where no
        # entry is within the bound also skips its KS rank work.  Computed
        # in the stored dtype (no f32 cast) so the per-entry max|err| is
        # exactly what the no-permutation decode reproduces.
        new_raw_ref[pl.ds(off, tile_d), :] = rawdict_ref[:, :]
        diff = raw_ref[:][None, :] - rawdict_ref[:, :]
        if chan:
            # per-channel metric choice; pad columns hold inf - inf = NaN
            # and are masked out before the max
            ad = jnp.where(cp[CHAN_ERRCUM] != 0.0,
                           jnp.abs(jnp.cumsum(diff, axis=1)), jnp.abs(diff))
            ad = jnp.where(col_ok[None, :].astype(jnp.bool_), ad,
                           jnp.zeros((), ad.dtype))
            err_ok = jnp.max(ad, axis=1) <= jnp.asarray(
                error_bound, ad.dtype)
            err_ok = err_ok | (cp[CHAN_EBON] == 0.0)
        else:
            if error_cumulative:
                diff = jnp.cumsum(diff, axis=1)
            err_ok = jnp.max(jnp.abs(diff), axis=1) <= jnp.asarray(
                error_bound, diff.dtype)
        gate = gate & err_ok

    ids = off + jax.lax.iota(jnp.int32, tile_d)

    if use_ks:
        # KS rank work only when some valid entry survived the gate: the
        # O(tile_d * n^2) comparisons are skipped for cold tiles (and for
        # every tile while the dictionary is still empty).
        @pl.when(jnp.any(gate))
        def _ks_tile():
            # identical arithmetic to _dict_match_kernel (decision parity
            # with the composed pallas path; see module docstring)
            cmp_d_le_x = (ds[:, :, None] <= xs[None, None, :]
                          ).astype(jnp.float32)
            cnt_d = jnp.sum(cmp_d_le_x, axis=1)                 # (tile_d, n)
            f_x_at_x = (jax.lax.iota(jnp.float32, n) + 1.0) * inv_n
            a1 = jnp.abs(f_x_at_x[None, :] - cnt_d * inv_n)
            if chan:  # zero-fill pad columns (KS >= 0) before the max
                a1 = jnp.where(col_ok[None, :], a1, 0.0)
            d1 = jnp.max(a1, axis=1)

            cmp_x_le_d = (xs[None, None, :] <= ds[:, :, None]
                          ).astype(jnp.float32)
            cnt_x = jnp.sum(cmp_x_le_d, axis=2)                 # (tile_d, n)
            rank_d = jnp.sum((ds[:, None, :] <= ds[:, :, None]
                              ).astype(jnp.float32), axis=2)
            a2 = jnp.abs(cnt_x * inv_n - rank_d * inv_n)
            if chan:
                a2 = jnp.where(col_ok[None, :], a2, 0.0)
            d2 = jnp.max(a2, axis=1)
            ks = jnp.maximum(d1, d2)

            thresh = cp[CHAN_DCRIT] if chan else jnp.float32(d_crit)
            ok = gate & (ks <= thresh)
            lf = jnp.min(jnp.where(ok, ids, SENTINEL))
            dec_ref[DEC_BEST] = jnp.minimum(dec_ref[DEC_BEST], lf)
    else:
        lf = jnp.min(jnp.where(gate, ids, SENTINEL))
        dec_ref[DEC_BEST] = jnp.minimum(dec_ref[DEC_BEST], lf)

    # --- last program: finalize the decision and apply the FIFO insert ---
    @pl.when(i == nprog - 1)
    def _finalize():
        count = meta_ref[0]
        bvalid = meta_ref[1] != 0
        best = dec_ref[DEC_BEST]
        is_hit = (best < SENTINEL) & bvalid
        ins = jnp.mod(count, num_dict)  # logical D: pad rows never targeted
        do_ins = (~is_hit) & bvalid
        overwrite = do_ins & (count >= num_dict)
        slot = jnp.where(is_hit, best, ins).astype(jnp.int32)
        dec_ref[DEC_HIT] = is_hit.astype(jnp.int32)
        dec_ref[DEC_SLOT] = jnp.where(bvalid, slot, 0)
        dec_ref[DEC_OVER] = overwrite.astype(jnp.int32)
        dec_ref[DEC_COUNT] = count + do_ins.astype(jnp.int32)

        @pl.when(do_ins)
        def _insert():
            new_dict_ref[pl.ds(ins, 1), :] = xs_ref[:][None, :]
            new_dmin_ref[pl.ds(ins, 1)] = xs_ref[pl.ds(0, 1)]
            if chan:  # xs[n - 1] is a +inf pad; store the masked max
                new_dmax_ref[pl.ds(ins, 1)] = xmax_v.astype(
                    new_dmax_ref.dtype).reshape((1,))
            else:
                new_dmax_ref[pl.ds(ins, 1)] = xs_ref[pl.ds(n - 1, 1)]
            new_valid_ref[pl.ds(ins, 1)] = jnp.ones((1,), jnp.bool_)
            if error_bound is not None:
                new_raw_ref[pl.ds(ins, 1), :] = raw_ref[:][None, :]


@functools.partial(jax.jit, static_argnames=(
    "d_crit", "rel_tol", "use_minmax", "use_ks", "num_dict", "tile_d",
    "error_bound", "error_cumulative", "interpret"))
def encode_step_pallas(xs_sorted, sorted_blocks, dmin, dmax, valid, count,
                       block_valid, *, d_crit: float, rel_tol: float,
                       num_dict: int, use_minmax: bool = True,
                       use_ks: bool = True, tile_d: int = TILE_D,
                       raw=None, raw_blocks=None,
                       error_bound: float | None = None,
                       error_cumulative: bool = False,
                       chan=None,
                       interpret: bool = True):
    """One fused encode step.

    ``xs_sorted`` (n,) sorted candidate; ``sorted_blocks`` (Dp, n) /
    ``dmin``/``dmax``/``valid`` (Dp,) the *padded* dictionary carry (Dp a
    ``tile_d`` multiple, pad rows ``valid=False``); ``count`` () int32 FIFO
    position; ``block_valid`` () bool ragged-padding mask.  ``num_dict`` is
    the logical D.

    Returns ``(new_sorted, new_dmin, new_dmax, new_valid, dec)`` where
    ``dec`` is (8,) int32 laid out by the ``DEC_*`` constants: the winning
    global index (or SENTINEL), is_hit, slot, overwrite, updated count.

    With ``error_bound`` set, ``raw`` (n,) and ``raw_blocks`` (Dp, n) carry
    the stream-order rows, the pointwise max|err| demotion joins the gate,
    and the return becomes
    ``(new_sorted, new_dmin, new_dmax, new_valid, new_raw, dec)``.

    ``chan`` is the optional (8,) f32 per-channel parameter operand of the
    masked mixed-mode scan (``CHAN_*`` layout: logical width as f32, the
    f32-rounded ``1/n``, the channel's d_crit, the cumulative-error and
    bound-armed flags).  When set, tail columns beyond the logical width
    must be +inf pads; the static ``d_crit``/``error_cumulative`` args are
    ignored in favor of the operand, and the kernel is bitwise identical
    to the static form at the unpadded width (DESIGN.md Sec. 13).
    """
    num_dp, n = sorted_blocks.shape
    check_tile_divisible(num_dp, tile_d, "encode_step_pallas")
    if not 1 <= num_dict <= num_dp:
        raise ValueError(f"num_dict={num_dict} outside [1, Dp={num_dp}]")
    eb = error_bound is not None
    if eb and (raw is None or raw_blocks is None):
        raise ValueError("error_bound requires raw and raw_blocks")
    grid = (num_dp // tile_d,)
    meta = jnp.stack([jnp.asarray(count, jnp.int32),
                      jnp.asarray(block_valid).astype(jnp.int32)])
    kernel = functools.partial(
        _encode_step_kernel, float(d_crit), float(rel_tol), bool(use_minmax),
        bool(use_ks), None if error_bound is None else float(error_bound),
        bool(error_cumulative), int(num_dict), int(tile_d),
        chan is not None)
    in_specs = [
        pl.BlockSpec((n,), lambda i: (0,)),           # candidate: reused
        pl.BlockSpec((2,), lambda i: (0,)),           # [count, valid]
        pl.BlockSpec((tile_d, n), lambda i: (i, 0)),  # streamed dict tile
        pl.BlockSpec((tile_d,), lambda i: (i,)),
        pl.BlockSpec((tile_d,), lambda i: (i,)),
        pl.BlockSpec((tile_d,), lambda i: (i,)),
    ]
    out_specs = [
        # constant index maps: carry-out lives in VMEM across the grid
        pl.BlockSpec((num_dp, n), lambda i: (0, 0)),
        pl.BlockSpec((num_dp,), lambda i: (0,)),
        pl.BlockSpec((num_dp,), lambda i: (0,)),
        pl.BlockSpec((num_dp,), lambda i: (0,)),
        pl.BlockSpec((8,), lambda i: (0,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((num_dp, n), sorted_blocks.dtype),
        jax.ShapeDtypeStruct((num_dp,), dmin.dtype),
        jax.ShapeDtypeStruct((num_dp,), dmax.dtype),
        jax.ShapeDtypeStruct((num_dp,), jnp.bool_),
        jax.ShapeDtypeStruct((8,), jnp.int32),
    ]
    operands = [xs_sorted, meta, sorted_blocks, dmin, dmax, valid]
    if eb:
        # raw candidate after xs, raw dict tile after the sorted tile, raw
        # carry-out after the sorted carry-out (kernel unpack order)
        in_specs.insert(1, pl.BlockSpec((n,), lambda i: (0,)))
        in_specs.insert(4, pl.BlockSpec((tile_d, n), lambda i: (i, 0)))
        out_specs.insert(1, pl.BlockSpec((num_dp, n), lambda i: (0, 0)))
        out_shape.insert(1, jax.ShapeDtypeStruct((num_dp, n),
                                                 raw_blocks.dtype))
        operands = [xs_sorted, raw, meta, sorted_blocks, raw_blocks,
                    dmin, dmax, valid]
    if chan is not None:
        # channel-parameter block leads the operand list (kernel unpack)
        in_specs.insert(0, pl.BlockSpec((8,), lambda i: (0,)))
        operands.insert(0, chan)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    if eb:
        new_sorted, new_raw, ndmin, ndmax, nvalid, dec = out
        return new_sorted, ndmin, ndmax, nvalid, new_raw, dec
    return out
