"""Pallas TPU kernel: fused min/max gate + two-sample KS distance vs dictionary.

This is IDEALEM's encode hot spot (paper Fig. 15): for each incoming block the
encoder must test exchangeability against up to D=255 stored source
distributions.  The kernel keeps the sorted candidate resident in VMEM and
streams dictionary tiles through, computing for every entry:

  mm[d] = min/max gate, eq. (3)
  ks[d] = sup_x |F_cand(x) - F_dict_d(x)|   (two-sample KS statistic, eq. 1)

ECDF counting is done with dense broadcast comparisons: the candidate is
sorted, so F_cand(xs_i) = (i+1)/n, and F_dict(xs_i) = #{dict <= xs_i}/n needs
no sorted dictionary at all -- counting is order-free.  This is O(n^2) per
entry but branch-free, layout-friendly VPU work (n <= 256 => a (TILE_D, n, n)
bool intermediate of ~0.5 MB in VMEM), in contrast to the CPU early-exit
merge walk which serializes.

Grid: one program per tile of TILE_D dictionary entries.  The wrapper in
``ops.py`` pads D up to a tile multiple and slices the result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 8  # default dictionary-tile height; sweepable via ``tile_d=``

__all__ = ["KernelShapeError", "dict_match_pallas", "TILE_D"]


# Historical import path: the class now lives in the unified hierarchy
# (repro.errors) under the ReproError root; same object either way.
from repro.errors import KernelShapeError  # noqa: E402,F401


def check_tile_divisible(num_d: int, tile_d: int, kernel: str) -> None:
    """D must be a tile multiple; the wrappers in ``ops.py`` (and the fused
    encoder's pad-at-scan-entry) guarantee it -- anything else is a caller
    bug worth a precise message."""
    if tile_d < 1:
        raise KernelShapeError(f"{kernel}: tile_d={tile_d} must be >= 1")
    if num_d % tile_d:
        pad = (-num_d) % tile_d
        raise KernelShapeError(
            f"{kernel}: D={num_d} is not a multiple of tile_d={tile_d}; "
            f"pad the dictionary with {pad} more row(s) to "
            f"{num_d + pad} (ops.dict_match pads automatically)")


def _dict_match_kernel(xs_ref, dict_ref, dmin_ref, dmax_ref, rtol_ref,
                       ks_ref, mm_ref):
    # tile height comes from the BlockSpec: dict_ref is (tile_d, n)
    n = xs_ref.shape[0]
    xs = xs_ref[:]                       # (n,) sorted candidate
    ds = dict_ref[:, :]                  # (TILE_D, n) dictionary tile
    inv_n = 1.0 / n

    # --- KS distance: evaluate |F_x - F_d| at both samples' jump points ---
    # counts of dict values <= each candidate point: (TILE_D, n_x)
    cmp_d_le_x = (ds[:, :, None] <= xs[None, None, :]).astype(jnp.float32)
    cnt_d = jnp.sum(cmp_d_le_x, axis=1)                        # (TILE_D, n)
    f_x_at_x = (jax.lax.iota(jnp.float32, n) + 1.0) * inv_n    # (n,)
    d1 = jnp.max(jnp.abs(f_x_at_x[None, :] - cnt_d * inv_n), axis=1)

    # counts of candidate values <= each dict point: (TILE_D, n_d)
    cmp_x_le_d = (xs[None, None, :] <= ds[:, :, None]).astype(jnp.float32)
    cnt_x = jnp.sum(cmp_x_le_d, axis=2)                        # (TILE_D, n)
    # F_d at its own (unsorted) points: rank of each point within its row.
    rank_d = jnp.sum((ds[:, None, :] <= ds[:, :, None]).astype(jnp.float32),
                     axis=2)                                   # (TILE_D, n)
    d2 = jnp.max(jnp.abs(cnt_x * inv_n - rank_d * inv_n), axis=1)

    ks_ref[:] = jnp.maximum(d1, d2)

    # --- min/max gate (eq. 3) ---
    r = rtol_ref[0]
    xmin, xmax = xs[0], xs[n - 1]
    dmin, dmax = dmin_ref[:], dmax_ref[:]
    t = (dmax - dmin) * r
    mm = ((xmin >= dmin - t) & (xmin <= dmin + t)
          & (xmax >= dmax - t) & (xmax <= dmax + t))
    mm_ref[:] = mm


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def dict_match_pallas(xs_sorted, dict_blocks, dmin, dmax, rel_tol,
                      interpret: bool = True, tile_d: int = TILE_D):
    """xs_sorted (n,), dict_blocks (D, n) [any order], dmin/dmax (D,),
    rel_tol scalar -> (ks (D,) f32, mm (D,) bool).  D must be a multiple of
    ``tile_d`` (use ops.dict_match for arbitrary D); ``tile_d`` trades VMEM
    footprint of the (tile_d, n, n) comparison against grid length, and is
    swept by the encode autotuner."""
    num_d, n = dict_blocks.shape
    check_tile_divisible(num_d, tile_d, "dict_match_pallas")
    grid = (num_d // tile_d,)
    rtol_arr = jnp.asarray([rel_tol], dtype=jnp.float32)
    ks, mm = pl.pallas_call(
        _dict_match_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),           # candidate: reused
            pl.BlockSpec((tile_d, n), lambda i: (i, 0)),  # dict tile
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_d,), jnp.float32),
            jax.ShapeDtypeStruct((num_d,), jnp.bool_),
        ],
        interpret=interpret,
    )(
        xs_sorted.astype(jnp.float32),
        dict_blocks.astype(jnp.float32),
        dmin.astype(jnp.float32),
        dmax.astype(jnp.float32),
        rtol_arr,
    )
    return ks, mm
