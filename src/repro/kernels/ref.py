"""Pure-jnp oracle for the ``dict_match`` Pallas kernel.

Same math, no pallas: broadcast-count ECDF distances + eq. (3) gate.
Cross-checked in tests against both the kernel (interpret mode) and the
independent searchsorted implementation in ``repro.core.ks``.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["dict_match_ref"]


def dict_match_ref(xs_sorted, dict_blocks, dmin, dmax, rel_tol):
    """xs_sorted (n,), dict_blocks (D, n) -> (ks (D,) f32, mm (D,) bool)."""
    xs = xs_sorted.astype(jnp.float32)
    ds = dict_blocks.astype(jnp.float32)
    n = xs.shape[0]
    inv_n = 1.0 / n

    cnt_d = jnp.sum(ds[:, :, None] <= xs[None, None, :], axis=1)  # (D, n)
    f_x_at_x = (jnp.arange(1, n + 1, dtype=jnp.float32)) * inv_n
    d1 = jnp.max(jnp.abs(f_x_at_x[None, :] - cnt_d * inv_n), axis=1)

    cnt_x = jnp.sum(xs[None, None, :] <= ds[:, :, None], axis=2)  # (D, n)
    rank_d = jnp.sum(ds[:, None, :] <= ds[:, :, None], axis=2)    # (D, n)
    d2 = jnp.max(jnp.abs(cnt_x * inv_n - rank_d * inv_n), axis=1)

    ks = jnp.maximum(d1, d2)

    xmin, xmax = xs[0], xs[n - 1]
    dmin = dmin.astype(jnp.float32)
    dmax = dmax.astype(jnp.float32)
    t = (dmax - dmin) * jnp.float32(rel_tol)
    mm = ((xmin >= dmin - t) & (xmin <= dmin + t)
          & (xmax >= dmax - t) & (xmax <= dmax + t))
    return ks, mm


def flash_decode_ref(q, k_cache, v_cache, valid):
    """Pure-jnp oracle for the flash_decode kernel.

    q (B,H,hd) pre-scaled; k/v (B,C,Hkv,hd); valid (B,C) -> (B,H,hd) f32."""
    B, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    import jax
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd)
