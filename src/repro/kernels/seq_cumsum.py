"""Pallas kernel: sequential in-block cumulative sum (delta-mode decode).

Delta-mode reconstruction (paper Sec. V-B2) rebuilds each block as
``base + cumsum(deltas)``.  The host decoder uses ``np.cumsum``, which
accumulates strictly left-to-right; XLA's ``cumsum`` lowers to an
associative scan whose f64 rounding differs in the last bit for long
blocks (measured, see tests/test_decode_backends.py).  Byte-identity
between the host and device decode paths therefore needs a cumsum that
accumulates in the SAME sequential order -- this kernel.

One program per tile of TILE_R rows; within the tile a ``fori_loop`` walks
the P columns carrying the running sum, exactly like ``np.add.accumulate``.
Column 0 is stored as-is (``acc = x[:, 0]``, not ``0 + x[:, 0]``) so a
leading ``-0.0`` survives bit-for-bit.  P is small (block_size - 1 <= 254)
so the serialized column walk costs nothing against the gather around it.

On CPU the kernel runs in interpret mode (like ``dict_match``); on TPU f64
is unsupported and the caller's exactness probe (repro.core.decode) falls
back to the host path instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 8

__all__ = ["seq_cumsum_pallas", "seq_cumsum", "TILE_R"]

# On CPU we must run the kernel in interpret mode; on TPU compile for real.
_INTERPRET = jax.default_backend() != "tpu"


def _seq_cumsum_kernel(x_ref, o_ref):
    P = x_ref.shape[1]
    acc = x_ref[:, 0]
    pl.store(o_ref, (slice(None), pl.dslice(0, 1)), acc[:, None])

    def body(j, acc):
        v = pl.load(x_ref, (slice(None), pl.dslice(j, 1)))[:, 0]
        acc = acc + v
        pl.store(o_ref, (slice(None), pl.dslice(j, 1)), acc[:, None])
        return acc

    jax.lax.fori_loop(1, P, body, acc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def seq_cumsum_pallas(x, interpret: bool = True):
    """x (R, P) -> row-wise cumulative sum, accumulated strictly
    left-to-right (bit-identical to ``np.cumsum(x, axis=1)``).  R must be
    a multiple of TILE_R (use ``seq_cumsum`` for arbitrary R)."""
    R, P = x.shape
    assert R % TILE_R == 0, "pad R to a TILE_R multiple (see seq_cumsum)"
    return pl.pallas_call(
        _seq_cumsum_kernel,
        grid=(R // TILE_R,),
        in_specs=[pl.BlockSpec((TILE_R, P), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_R, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, P), x.dtype),
        interpret=interpret,
    )(x)


@jax.jit
def seq_cumsum(x):
    """Pad-to-tile wrapper for arbitrary row counts."""
    R = x.shape[0]
    pad = (-R) % TILE_R
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return seq_cumsum_pallas(x, interpret=_INTERPRET)[:R]
