"""Pallas TPU kernel: single-token flash-decode attention over a KV cache.

Serving's hot spot: one query token per sequence against a (C, Hkv, hd)
ring cache.  The kernel tiles the cache into VMEM-sized chunks along C and
keeps the online-softmax state (m, l, acc) in VMEM scratch across grid
steps, so the (H, C) score row never round-trips HBM.  GQA is handled
in-kernel by grouping query heads over each kv head (no materialized
repeat_kv).  Grid: (batch, C/chunk), cache-chunk minor so scratch carries
across the chunk sweep; the last chunk step finalizes o = acc / l.

Validated in interpret mode against ``ref.flash_decode_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK_C = 512

__all__ = ["flash_decode_pallas", "CHUNK_C"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr):
    nc = pl.num_programs(1)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0]          # (Hkv, G, hd) grouped query heads
    k = k_ref[0]          # (chunk, Hkv, hd)
    v = v_ref[0]          # (chunk, Hkv, hd)
    valid = valid_ref[0]  # (chunk,) bool

    s = jnp.einsum("kgd,ckd->kgc", q.astype(jnp.float32),
                   k.astype(jnp.float32))  # (Hkv, G, chunk)
    s = jnp.where(valid[None, None, :], s, _NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, :, None])
    alpha = jnp.exp(m_prev - m_new)
    pv = jnp.einsum("kgc,ckd->kgd", p, v.astype(jnp.float32))
    acc_scr[...] = acc_scr[...] * alpha[:, :, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)

    @pl.when(j == nc - 1)
    def _final():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, :, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_pallas(q, k_cache, v_cache, valid, *, interpret: bool = True):
    """q: (B, H, hd) pre-scaled query; k/v_cache: (B, C, Hkv, hd);
    valid: (B, C) bool (ring-position validity incl. window masking).
    Returns (B, H, hd) f32."""
    B, H, hd = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    chunk = min(CHUNK_C, C)
    assert C % chunk == 0, "cache length must be a multiple of the chunk"
    qg = q.reshape(B, Hkv, G, hd)
    out = pl.pallas_call(
        _kernel,
        grid=(B, C // chunk),
        in_specs=[
            pl.BlockSpec((1, Hkv, G, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, chunk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, chunk, Hkv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, Hkv, G, hd), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G), jnp.float32),      # running max
            pltpu.VMEM((Hkv, G), jnp.float32),      # running denominator
            pltpu.VMEM((Hkv, G, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid)
    return out.reshape(B, H, hd)
