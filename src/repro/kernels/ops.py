"""Jit'd public wrappers around the Pallas ``dict_match`` kernel.

``dict_match``     -- (ks, mm) for arbitrary D (pads to TILE_D multiple).
                      This is the encoder matcher signature: pass it as
                      ``matcher=`` to ``repro.core.encoder.encode_decisions``
                      so the kernel's fused min/max gate is consumed directly
                      instead of being recomputed outside the kernel.
``dict_match_ks``  -- legacy KS-only view (gate discarded); kept for the
                      kernel test suite and external callers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dict_match import TILE_D, dict_match_pallas
from .ref import dict_match_ref

__all__ = ["dict_match", "dict_match_ks", "dict_match_reference"]

# On CPU we must run the kernel in interpret mode; on TPU compile for real.
_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("rel_tol", "tile_d"))
def dict_match(xs_sorted, dict_blocks, dmin, dmax, rel_tol: float = 0.1,
               tile_d: int = TILE_D):
    """Pad-to-tile wrapper; returns (ks (D,), mm (D,))."""
    num_d, n = dict_blocks.shape
    pad = (-num_d) % tile_d
    if pad:
        dict_blocks = jnp.pad(dict_blocks, ((0, pad), (0, 0)))
        dmin = jnp.pad(dmin, (0, pad))
        dmax = jnp.pad(dmax, (0, pad))
    ks, mm = dict_match_pallas(xs_sorted, dict_blocks, dmin, dmax, rel_tol,
                               interpret=_INTERPRET, tile_d=tile_d)
    return ks[:num_d], mm[:num_d]


def dict_match_ks(xs_sorted, dict_sorted, rel_tol: float = 0.5):
    """Raw KS distances from the kernel, min/max gate discarded.

    The streaming encoder no longer uses this: it passes ``dict_match`` as
    its fused matcher and consumes (ks, mm) together.
    """
    dmin = dict_sorted[:, 0]
    dmax = dict_sorted[:, -1]
    ks, _ = dict_match(xs_sorted, dict_sorted, dmin, dmax, rel_tol)
    return ks


def dict_match_reference(xs_sorted, dict_blocks, dmin, dmax, rel_tol: float = 0.1):
    """Pure-jnp oracle with the public signature."""
    return dict_match_ref(xs_sorted, dict_blocks, dmin, dmax, rel_tol)
