"""Jit'd public wrappers around the Pallas ``dict_match`` kernel.

``dict_match``     -- (ks, mm) for arbitrary D (pads to TILE_D multiple)
``dict_match_ks``  -- encoder-compatible matcher: returns the KS distance with
                      failed min/max gates masked to +inf, so the encoder's
                      single `ks <= d_crit` comparison applies both checks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dict_match import TILE_D, dict_match_pallas
from .ref import dict_match_ref

__all__ = ["dict_match", "dict_match_ks", "dict_match_reference"]

# On CPU we must run the kernel in interpret mode; on TPU compile for real.
_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("rel_tol",))
def dict_match(xs_sorted, dict_blocks, dmin, dmax, rel_tol: float = 0.1):
    """Pad-to-tile wrapper; returns (ks (D,), mm (D,))."""
    num_d, n = dict_blocks.shape
    pad = (-num_d) % TILE_D
    if pad:
        dict_blocks = jnp.pad(dict_blocks, ((0, pad), (0, 0)))
        dmin = jnp.pad(dmin, (0, pad))
        dmax = jnp.pad(dmax, (0, pad))
    ks, mm = dict_match_pallas(xs_sorted, dict_blocks, dmin, dmax, rel_tol,
                               interpret=_INTERPRET)
    return ks[:num_d], mm[:num_d]


def dict_match_ks(xs_sorted, dict_sorted, rel_tol: float = 0.5):
    """Matcher signature used by ``repro.core.encoder.encode_decisions``.

    The encoder applies its own min/max gate; this variant returns the raw KS
    distances (gate handled by the encoder mask), computed by the kernel.
    """
    dmin = dict_sorted[:, 0]
    dmax = dict_sorted[:, -1]
    ks, _ = dict_match(xs_sorted, dict_sorted, dmin, dmax, rel_tol)
    return ks


def dict_match_reference(xs_sorted, dict_blocks, dmin, dmax, rel_tol: float = 0.1):
    """Pure-jnp oracle with the public signature."""
    return dict_match_ref(xs_sorted, dict_blocks, dmin, dmax, rel_tol)
