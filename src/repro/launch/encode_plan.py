"""Encode plans: how a batched (C, nb, n) encode spreads over the devices.

The batched encoder (DESIGN.md Sec. 2) treats channels as embarrassingly
parallel; this module decides the mapping onto hardware for the scale-out
path (DESIGN.md Sec. 6):

  * mesh shape: a 1-D mesh over (at most) all local devices -- never more
    devices than channels, a device with zero channels is wasted;
  * channel padding: C rounded up to a mesh-axis multiple, the pad rows
    masked out of the scan with the encoder's block-validity mask;
  * block quantum: the suggested per-feed block count that keeps every
    shard's scan long enough to amortize dispatch (one compiled shape).

Plans are plain data: the codec core takes ``mesh``/``axis_name`` and
padded arrays, so ``repro.core`` stays free of launch-layer imports.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["EncodePlan", "make_encode_plan", "shard_state", "pad_channels"]

# Per-shard bytes of block payload a single feed step should carry before
# scan-dispatch overhead stops dominating (CPU/TPU measured order only).
_QUANTUM_BYTES = 1 << 20


class EncodePlan(NamedTuple):
    """Placement decision for one batched encode configuration.

    ``dict_shards > 1`` selects dictionary (D-axis) sharding on a 2-D
    (channels, dict) mesh: within each channel group the dictionary rows
    are split over ``dict_shards`` devices and the per-step best match is
    all-reduced (DESIGN.md Sec. 10), so one fat channel can use several
    devices.  The default keeps the 1-D channel-only mesh.
    """

    mesh: Mesh
    axis_name: str
    channels: int          # logical channel count C
    padded_channels: int   # C rounded up to a devices multiple
    shard_channels: int    # channels resident per device
    block_quantum: int     # suggested blocks per channel per feed step
    dict_axis: str = "dict"
    dict_shards: int = 1   # devices sharing each channel's dictionary rows

    @property
    def num_devices(self) -> int:
        return self.mesh.shape[self.axis_name]

    def validate_adaptive(self) -> "EncodePlan":
        """Check the plan can drive adaptive (mixed-mode) sessions.

        The batched mixed scan (DESIGN.md Sec. 13) shards the channel axis
        only -- each lane carries its own mode/width/threshold as masked
        per-channel parameters, and the dictionary rows of one lane must
        stay resident on one device for the in-place lane resets a
        selector switch performs.  Returns ``self`` so call sites can
        chain ``make_encode_plan(...).validate_adaptive()``."""
        if self.dict_shards > 1:
            raise ValueError(
                "adaptive sessions shard channels only; build the plan "
                "with dict_shards=1")
        return self

    def channel_sharding(self, trailing_dims: int = 0) -> NamedSharding:
        """Sharding for an array with a leading channel axis (on a 2-D
        mesh the array is replicated across dictionary shards)."""
        return NamedSharding(
            self.mesh, P(self.axis_name, *([None] * trailing_dims)))

    def state_sharding(self):
        """``DictState``-shaped sharding pytree for carry placement
        (sessions and the serve coalescer device_put with this, keeping
        ``repro.core`` free of launch imports).  The field layout comes
        from ``encoder.state_partition_spec`` -- the one source of truth
        the shard_map in_specs also use.

        With ``dict_shards > 1`` the resumable carry keeps its *logical* D
        (not necessarily a shard multiple), so only the channel axis is
        placed here; the D-sharded scan pads and reshards the dictionary
        rows internally."""
        from repro.core.encoder import state_partition_spec

        specs = state_partition_spec(self.axis_name)
        return type(specs)(*(NamedSharding(self.mesh, p) for p in specs))

    def summary(self) -> dict:
        return {
            "devices": self.num_devices,
            "channels": self.channels,
            "padded_channels": self.padded_channels,
            "shard_channels": self.shard_channels,
            "block_quantum": self.block_quantum,
            "dict_shards": self.dict_shards,
        }


def make_encode_plan(
    channels: int,
    *,
    block_size: int = 32,
    itemsize: int = 4,
    devices: Optional[Sequence] = None,
    axis_name: str = "channels",
    dict_axis: str = "dict",
    dict_shards: int = 1,
) -> EncodePlan:
    """Pick mesh shape, channel padding and per-shard batch quantum.

    ``devices`` defaults to all local devices; pass a subset to pin the
    encode to specific chips.  ``itemsize`` is the on-device payload dtype
    (the encoder computes in float32 by default).

    ``dict_shards > 1`` asks for D-axis sharding: the device list is
    reshaped into a (channel groups, dict_shards) 2-D mesh, so plans can
    choose channel-sharding (default), D-sharding (``channels=1``), or
    both from one mesh shape.
    """
    if channels < 1:
        raise ValueError("channels must be >= 1")
    if dict_shards < 1:
        raise ValueError("dict_shards must be >= 1")
    devs = list(devices) if devices is not None else jax.devices()
    if dict_shards > 1:
        if len(devs) < dict_shards:
            raise ValueError(
                f"dict_shards={dict_shards} needs at least that many "
                f"devices, have {len(devs)}")
        ch_devs = max(1, min(len(devs) // dict_shards, channels))
        mesh = Mesh(
            np.array(devs[:ch_devs * dict_shards]).reshape(
                ch_devs, dict_shards),
            (axis_name, dict_axis))
        nd = ch_devs
    else:
        nd = max(1, min(len(devs), channels))
        mesh = Mesh(np.array(devs[:nd]), (axis_name,))
    padded = -(-channels // nd) * nd
    shard_channels = padded // nd
    quantum = max(1, _QUANTUM_BYTES // (shard_channels * block_size * itemsize))
    return EncodePlan(
        mesh=mesh,
        axis_name=axis_name,
        channels=channels,
        padded_channels=padded,
        shard_channels=shard_channels,
        block_quantum=quantum,
        dict_axis=dict_axis,
        dict_shards=dict_shards,
    )


def pad_channels(plan: EncodePlan, arr: np.ndarray) -> np.ndarray:
    """Pad the leading channel axis of a host array up to the plan's padded
    channel count (pad rows are masked out of the scan by the caller)."""
    pad = plan.padded_channels - arr.shape[0]
    if pad == 0:
        return arr
    width = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, width)


def shard_state(plan: EncodePlan, state):
    """Place a ``DictState`` with a (padded) leading channel axis so each
    device holds its channel shard (the carry then stays device-resident
    across resumable encode calls)."""
    if state.count.shape[0] != plan.padded_channels:
        raise ValueError(
            f"state carries {state.count.shape[0]} channels, plan expects "
            f"{plan.padded_channels} (padded)")
    return jax.device_put(state, plan.state_sharding())
