"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits each while-loop body ONCE,
so with scanned layers / microbatches / attention chunks it undercounts FLOPs
and bytes by orders of magnitude.  This module parses ``compiled.as_text()``
into computations, builds the call graph (while bodies carry their
``known_trip_count``, fusions/calls carry weight 1), and accumulates:

  - flops:       2 * numel(result) * prod(contracting dims) per dot op
                 (matmul convention; elementwise flops are negligible for
                 these workloads and excluded, as in MFU accounting)
  - bytes:       operand+result bytes of ops at fusion boundaries (fusion
                 internals live in registers/VMEM); dynamic-slice family
                 counted by slice size, not full-operand size
  - collectives: per-kind wire bytes per chip under a ring cost model,
                 multiplied by the enclosing loops' trip counts

All numbers are per chip: the module analyzed is the SPMD-partitioned
per-device program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "u4": 1, "s4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s*"
                     r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SLICE_OPS = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast", "constant",
             "iota", "after-all", "partition-id", "replica-id", "bitcast-convert"}
# ops a TPU compiler fuses into producers/consumers essentially always --
# standalone occurrences on the CPU-optimized module are not HBM traffic
_FUSABLE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "convert",
    "compare", "select", "exponential", "log", "tanh", "logistic", "power",
    "sqrt", "rsqrt", "negate", "abs", "floor", "ceil", "sign", "cosine",
    "sine", "is-finite", "and", "or", "not", "xor", "clamp", "broadcast",
    "reduce-precision", "exponential-minus-one", "log-plus-one", "reshape",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
}


def _shape_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """(total bytes, list of dims lists) for a possibly-tuple type string."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(ds)
    return total, shapes


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.symtab: Dict[str, str] = {}  # value name -> type string
        self.flops = 0.0
        self.bytes = 0.0
        # fusion-call bytes deferred until the callee's triviality is known
        self.fusion_bytes: List[Tuple[str, float]] = []
        self.n_heavy_ops = 0  # dots/reduces/sorts etc. inside this comp
        # if the root is an in-place dynamic-update-slice, the write traffic
        # is the update slice, not the full result buffer
        self.root_dus_update_bytes: Optional[float] = None
        self.coll: Dict[str, List[float]] = defaultdict(lambda: [0, 0.0])
        self.edges: List[Tuple[str, float, str]] = []  # (callee, weight, kind)


def parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameter shapes from the signature
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|\w+\[[\d,]*\])",
                                      m.group(2)):
                    cur.symtab[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        d = _DEF_RE.match(line)
        if d:
            cur.symtab[d.group(1)] = d.group(2)
    return comps, entry


def _operand_names(line: str, after: int) -> List[str]:
    """Operand value names from the op's argument list."""
    start = line.find("(", after)
    depth, end = 0, start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(line[start:end + 1])


def _operand_bytes(comp: _Comp, line: str, after: int) -> float:
    """Sum of operand sizes named in the op's argument list."""
    total = 0.0
    for name in _operand_names(line, after):
        t = comp.symtab.get(name)
        if t:
            total += _shape_info(t)[0]
    return total


def _analyze_comp(comp: _Comp) -> None:
    for line in comp.lines:
        d = _DEF_RE.match(line)
        if not d:
            continue
        _, type_str, op = d.group(1), d.group(2), d.group(3)
        res_bytes, res_shapes = _shape_info(type_str)

        if line.lstrip().startswith("ROOT") and op == "dynamic-update-slice":
            ops = _operand_names(line, len(d.group(0)) - 1)
            if len(ops) > 1:
                ut = comp.symtab.get(ops[1])
                comp.root_dus_update_bytes = (
                    _shape_info(ut)[0] if ut else res_bytes)

        if op == "while":
            b = _BODY_RE.search(line)
            c = _COND_RE.search(line)
            t = _TRIP_RE.search(line)
            trip = float(t.group(1)) if t else 1.0
            if b:
                comp.edges.append((b.group(1), trip, "while"))
            if c:
                comp.edges.append((c.group(1), trip + 1, "while"))
            continue
        cm = _CALLS_RE.search(line)
        if cm:
            comp.edges.append((cm.group(1), 1.0, "call"))

        if op in ("dot", "dot-general", "convolution"):
            mcon = _CONTRACT_RE.search(line)
            lhs = _OPERAND_RE.findall(line[line.find("(", len(d.group(0)) - 1):])
            k = 1.0
            if mcon and lhs:
                lhs_t = comp.symtab.get(lhs[0], "")
                _, lhs_shapes = _shape_info(lhs_t)
                if lhs_shapes:
                    for ci in [int(x) for x in mcon.group(1).split(",") if x]:
                        if ci < len(lhs_shapes[0]):
                            k *= lhs_shapes[0][ci]
            out_elems = 0.0
            for s in res_shapes:
                n = 1
                for x in s:
                    n *= x
                out_elems += n
            comp.flops += 2.0 * out_elems * k

        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                n = 1
                g = _GROUPS_BRACE_RE.search(line)
                if g:
                    n = len(g.group(1).split(","))
                else:
                    g = _GROUPS_IOTA_RE.search(line)
                    if g:
                        n = int(g.group(2))
                # the CPU backend PROMOTES bf16 reductions to f32
                # (to_apply=%..._promoted); on TPU these run in bf16, so
                # charge wire bytes at the unpromoted width
                if "promoted" in line:
                    res_bytes = res_bytes / 2
                if n > 1:
                    if kind == "all-reduce":
                        wire = 2.0 * (n - 1) / n * res_bytes
                    elif kind == "all-gather":
                        wire = (n - 1) / n * res_bytes
                    elif kind == "reduce-scatter":
                        wire = float(n - 1) * res_bytes
                    elif kind == "all-to-all":
                        wire = (n - 1) / n * res_bytes
                    else:
                        wire = float(res_bytes)
                    comp.coll[kind][0] += 1
                    comp.coll[kind][1] += wire
                break

        # bytes at fusion boundaries only; elementwise/broadcast ops fuse on
        # TPU and are excluded (their values are counted as operands of the
        # real consumers)
        if op in _FREE_OPS or op.endswith("-done") or op in _FUSABLE_OPS:
            continue
        if op in _SLICE_OPS:
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: traffic ~ 2x the update slice, not the
                # full buffer (which is the result type)
                ops = _operand_names(line, len(d.group(0)) - 1)
                upd_idx = 1 if op == "dynamic-update-slice" else 2
                upd_t = comp.symtab.get(ops[upd_idx]) if len(ops) > upd_idx else None
                comp.bytes += 2.0 * (_shape_info(upd_t)[0] if upd_t else res_bytes)
            else:
                comp.bytes += 2.0 * res_bytes
        elif op in ("while", "conditional", "call", "optimization-barrier"):
            # control flow: the body's traffic is accounted via multipliers;
            # charging the carried tuple here would double count
            continue
        elif op == "fusion":
            cm2 = _CALLS_RE.search(line)
            if cm2:
                # input charge resolved later from the callee's parameter
                # usage (dynamic-slice params charge slice-size only)
                comp.fusion_bytes.append((cm2.group(1), float(res_bytes)))
            else:
                comp.bytes += res_bytes + _operand_bytes(
                    comp, line, len(d.group(0)) - 1)
        else:
            if op in ("dot", "dot-general", "convolution", "reduce", "sort",
                      "reduce-window", "rng", "rng-bit-generator"):
                comp.n_heavy_ops += 1
            comp.bytes += res_bytes + _operand_bytes(comp, line,
                                                     len(d.group(0)) - 1)


def analyze(text: str) -> Dict:
    comps, entry = parse_computations(text)
    for c in comps.values():
        _analyze_comp(c)

    # propagate multipliers from entry through the call DAG (Kahn order)
    if entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    from collections import deque
    indeg = {name: 0 for name in comps}
    for c in comps.values():
        for callee, _w, _k in c.edges:
            if callee in indeg:
                indeg[callee] += 1
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    q = deque([n for n in comps if indeg[n] == 0])
    while q:
        cur = q.popleft()
        for callee, w, _k in comps[cur].edges:
            if callee in comps:
                mult[callee] += mult[cur] * w
                indeg[callee] -= 1
                if indeg[callee] == 0:
                    q.append(callee)

    # resolve deferred fusion-call bytes: calls into "light" computations
    # (pure elementwise/broadcast pipelines, which the TPU compiler fuses
    # into neighbors) are not HBM traffic
    def _light(name: str) -> bool:
        c = comps.get(name)
        if c is None:
            return False
        return (c.n_heavy_ops == 0 and c.bytes == 0.0
                and not c.fusion_bytes and len(c.lines) <= 10)

    # input charge of a fusion: parameters consumed only by dynamic-slice
    # inside the fusion read slice-size bytes, not the full (e.g. stacked
    # scan-parameter) operand
    _param_charge_cache: Dict[str, float] = {}

    def _param_charge(name: str) -> float:
        if name in _param_charge_cache:
            return _param_charge_cache[name]
        c = comps.get(name)
        charge = 0.0
        if c is not None:
            params = []  # (pname, type)
            for line in c.lines:
                d = _DEF_RE.match(line)
                if d and d.group(3) == "parameter":
                    params.append((d.group(1), d.group(2)))
            for pname, ptype in params:
                use_re = re.compile(r"%" + re.escape(pname) + r"(?![\w.])")
                slice_bytes = 0.0
                full = False
                used = False
                for line in c.lines:
                    d = _DEF_RE.match(line)
                    if not d or d.group(1) == pname:
                        continue
                    if use_re.search(line):
                        used = True
                        op = d.group(3)
                        if op == "dynamic-slice":
                            slice_bytes += _shape_info(d.group(2))[0]
                        elif op == "dynamic-update-slice":
                            ops = _operand_names(line, len(d.group(0)) - 1)
                            if ops and ops[0] == pname and len(ops) > 1:
                                ut = c.symtab.get(ops[1])
                                slice_bytes += _shape_info(ut)[0] if ut else 0
                            else:  # param is the update itself
                                slice_bytes += _shape_info(ptype)[0]
                        else:
                            full = True
                            break
                if not used:
                    continue
                charge += _shape_info(ptype)[0] if full else slice_bytes
        _param_charge_cache[name] = charge
        return charge

    total_flops = 0.0
    total_bytes = 0.0
    coll: Dict[str, Dict[str, float]] = {}
    # fusion-internal computations: bytes already counted at the call site,
    # so only count bytes for computations reached via while/entry (regions)
    fused_callees = set()
    for c in comps.values():
        for callee, _w, kind in c.edges:
            if kind == "call":
                fused_callees.add(callee)
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        total_flops += m * c.flops
        if name not in fused_callees:
            own = c.bytes
            for callee, res_b in c.fusion_bytes:
                if not _light(callee):
                    cal = comps.get(callee)
                    if cal is not None and cal.root_dus_update_bytes is not None:
                        res_b = 2.0 * cal.root_dus_update_bytes
                    own += res_b + _param_charge(callee)
            total_bytes += m * own
        for kind, (cnt, wire) in c.coll.items():
            d = coll.setdefault(kind, {"count": 0.0, "wire_bytes": 0.0})
            d["count"] += m * cnt
            d["wire_bytes"] += m * wire
    return {
        "flops": total_flops,
        "bytes": total_bytes,
        "collectives": coll,
        "collective_wire_bytes": sum(d["wire_bytes"] for d in coll.values()),
    }
