"""Sharded-encode byte-identity self-check (DESIGN.md Sec. 6).

Acceptance gate for the scale-out encode path: for every mode x D regime
the sharded session (channel axis split over 2+ devices via shard_map) and
the request coalescer must emit streams whose decoded output -- and, for
the session, the exact segment bytes -- match the single-device encode.

Run in a subprocess so the forced host device count precedes the jax
import (the tier-1 test tests/test_shard_encode.py does exactly that):

  REPRO_SHARD_DEVICES=4 PYTHONPATH=src python -m repro.launch.shard_check

Prints one JSON record; "status": "ok" means every case was byte-identical.
"""
import os

if __name__ == "__main__":  # own the device-count flag (precedes jax import)
    _flag = ("--xla_force_host_platform_device_count="
             + os.environ.get("REPRO_SHARD_DEVICES", "2"))
    # append to any pre-existing XLA_FLAGS (last occurrence wins) so an
    # exported XLA_FLAGS cannot silently demote the check to 1 device
    os.environ["XLA_FLAGS"] = (
        (os.environ.get("XLA_FLAGS", "") + " " + _flag).strip())

import json
from typing import List

import numpy as np

__all__ = ["run_check"]

CASES = [  # (mode, num_dict, value_range)
    ("std", 255, None),
    ("std", 1, None),
    ("residual", 32, (0.0, 360.0)),
    ("residual", 1, None),
    ("delta", 32, None),
    ("delta", 1, (0.0, 360.0)),
]


def _signal(n: int, vr, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    parts = [rng.normal(m, s, size=n // 3)
             for m, s in [(0, 1), (5, 0.5), (0, 1)]]
    x = np.concatenate(parts + [rng.normal(0, 1, size=n - 3 * (n // 3))])
    if vr is not None:
        x = np.mod(np.abs(x) * 40.0, vr[1] - vr[0]) + vr[0]
    return x


def _session_blobs(codec, chans, plan) -> List[bytes]:
    C = chans.shape[0]
    s = codec.session(channels=C, plan=plan)
    parts = [s.feed(chans[:, :517]), s.feed(chans[:, 517:]), s.finish()]
    return [b"".join(p[ci] for p in parts) for ci in range(C)]


def run_check(backend: str = "jax", channels: int = 5,
              samples: int = 16 * 80 + 7, dict_shards: int = 0) -> dict:
    """``dict_shards=0`` shards the dictionary over all devices (D-axis
    case) in addition to the channel-sharded cases; ``1`` disables it."""
    import jax

    from repro.core import IdealemCodec
    from repro.launch.encode_plan import make_encode_plan
    from repro.serve import FlushPolicy, StreamCoalescer

    n_dev = jax.device_count()
    want = int(os.environ.get("REPRO_SHARD_DEVICES", "0"))
    if want and n_dev != want:
        return {"status": "wrong_device_count", "devices": n_dev,
                "expected": want}
    if dict_shards == 0:
        dict_shards = n_dev
    checked = []
    for mode, num_dict, vr in CASES:
        codec = IdealemCodec(mode=mode, block_size=16, num_dict=num_dict,
                             alpha=0.05, rel_tol=0.5, value_range=vr,
                             backend=backend)
        chans = np.stack([_signal(samples, vr, seed=11 + ci)
                          for ci in range(channels)])
        plan = make_encode_plan(channels, block_size=16)
        assert plan.num_devices == min(n_dev, channels), plan.summary()

        # sharded session bytes == single-device session bytes
        single = _session_blobs(codec, chans, plan=None)
        sharded = _session_blobs(codec, chans, plan=plan)
        if single != sharded:
            return {"status": "mismatch", "where": "session",
                    "mode": mode, "num_dict": num_dict}

        # D-sharded session bytes == single-device session bytes: the
        # dictionary rows of every channel split over the dict mesh axis,
        # per-step best match all-reduced (one channel group: the fat-
        # channel scale-out the channel-sharded path cannot provide)
        if dict_shards > 1:
            dplan = make_encode_plan(channels, block_size=16,
                                     dict_shards=dict_shards)
            assert dplan.dict_shards == dict_shards, dplan.summary()
            dsharded = _session_blobs(codec, chans, plan=dplan)
            if single != dsharded:
                return {"status": "mismatch", "where": "session_dshard",
                        "mode": mode, "num_dict": num_dict}

        # coalesced ragged streams decode like one-shot per-stream encode
        cplan = make_encode_plan(-(-channels // n_dev) * n_dev, block_size=16)
        co = StreamCoalescer(policy=FlushPolicy(max_batch_blocks=64),
                             plan=cplan, mode=mode, block_size=16,
                             num_dict=num_dict, alpha=0.05, rel_tol=0.5,
                             value_range=vr, backend=backend)
        segs = {ci: [] for ci in range(channels)}
        for ci in range(channels):
            co.open_stream(str(ci))
        step = [37 + 13 * ci for ci in range(channels)]
        lo = [0] * channels
        while any(lo[ci] < samples for ci in range(channels)):
            for ci in range(channels):
                if lo[ci] < samples:
                    res = co.submit(str(ci), chans[ci, lo[ci]:lo[ci] + step[ci]])
                    lo[ci] += step[ci]
                    if res:
                        for k, v in res.items():
                            segs[int(k)].append(v)
        for ci in range(channels):
            segs[ci].append(co.close_stream(str(ci)))
        for ci in range(channels):
            got = codec.decode(b"".join(segs[ci]))
            ref = codec.decode(codec.encode(chans[ci]))
            if not np.array_equal(got, ref):
                return {"status": "mismatch", "where": "coalescer",
                        "mode": mode, "num_dict": num_dict, "channel": ci}
        checked.append(f"{mode}/D{num_dict}")
    return {"status": "ok", "devices": n_dev, "backend": backend,
            "cases": checked}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jax",
                    choices=["jax", "pallas"])
    ap.add_argument("--dict-shards", type=int, default=0,
                    help="dictionary shards for the D-axis case "
                         "(0 = all devices, 1 = skip)")
    args = ap.parse_args()
    rec = run_check(backend=args.backend, dict_shards=args.dict_shards)
    print(json.dumps(rec))
    if rec["status"] != "ok":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
