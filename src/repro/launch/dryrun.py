import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Optional override for CPU CI tests (must still precede the jax import).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct, no allocation),
jits the right step (train_step / prefill_step / serve_step) with explicit
in/out shardings on the production mesh, compiles, and records:

  - compiled.cost_analysis()   -> per-chip HLO FLOPs / bytes accessed
  - compiled.as_text() parse   -> per-chip collective wire bytes (ring model)
  - compiled.memory_analysis() -> per-chip buffer sizes (when available)

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system.  Results are JSON artifacts consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k --mesh both --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --jobs 6
"""
import argparse
import functools
import json
import re
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCHS, SHAPES, LONG_CONTEXT_OK, ShapeSpec, get_config
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch import sharding as shd
from repro.models import lm
from repro.models.common import ModelConfig, set_sharding_rules
from repro.train import init_train_state, make_train_step
from repro.serve import prefill_step

# ----------------------------------------------------------------- input specs

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a cache of length S
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return specs


def _memory_len(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    if cfg.family == "audio":
        return cfg.encoder_seq
    return 0


# ------------------------------------------------------------ collective parse

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_OP_RE = re.compile(
    r"=\s*(\(?[^=]*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-chip wire bytes per collective kind (ring cost model)."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        size = _type_bytes(type_str)
        if size == 0:
            continue
        n = 1
        g = _GROUPS_BRACE_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g = _GROUPS_IOTA_RE.search(line)
            if g:
                n = int(g.group(2))
        if n <= 1:
            continue
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif kind == "all-gather":
            wire = (n - 1) / n * size  # result type is the gathered shape
        elif kind == "reduce-scatter":
            wire = float(n - 1) * size  # result is the scattered shard
        elif kind == "all-to-all":
            wire = (n - 1) / n * size
        else:  # collective-permute
            wire = float(size)
        d = out.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += wire
    return out


# ------------------------------------------------------------------- lowering


def _mesh_context(mesh):
    """``jax.sharding.set_mesh`` landed after the 0.4.x line; older releases
    spell it ``use_mesh`` or rely on ``Mesh`` being a context manager."""
    setter = getattr(jax.sharding, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None)
    return setter(mesh) if setter is not None else mesh


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int = 0, cfg_override=None, smoke: bool = False):
    """Returns (lowered, meta) for one cell.  smoke=True swaps in the
    reduced config (same family/stage plan) -- used by CI to validate the
    full lowering path on the production mesh quickly."""
    cfg = cfg_override or get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes(multi_pod)
    set_sharding_rules(shd.activation_rules(cfg, mesh, baxes))

    specs = input_specs(cfg, shape)
    batch_sh = shd.to_shardings(
        shd.batch_specs(specs, mesh, baxes), mesh)

    key = jax.random.key(0)
    if shape.kind == "train":
        mb = microbatches if microbatches else cfg.train_microbatches
        while shape.global_batch % mb or (shape.global_batch // mb) < 1:
            mb //= 2
        state_shape = jax.eval_shape(
            functools.partial(init_train_state, cfg=cfg), key)
        state_spec = shd.state_specs(state_shape, cfg, mesh)
        state_sh = shd.to_shardings(state_spec, mesh)
        step = make_train_step(cfg, lr=1e-4, microbatches=mb)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        with _mesh_context(mesh):
            lowered = fn.lower(state_shape, specs)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(
            functools.partial(lm.init_params, cfg=cfg), key)
        pspec = shd.param_specs(params_shape, cfg, mesh)
        p_sh = shd.to_shardings(pspec, mesh)
        mem_key = ("memory" if "memory" in specs
                   else "frames" if "frames" in specs else None)

        def pf(params, tokens, memory=None):
            if cfg.family == "audio":
                memory = lm.encode_frames(params, memory, cfg)
            return prefill_step(params, tokens, cfg, memory)

        if mem_key:
            fn = jax.jit(pf, in_shardings=(p_sh, batch_sh["tokens"],
                                           batch_sh[mem_key]))
            args = (params_shape, specs["tokens"], specs[mem_key])
        else:
            fn = jax.jit(pf, in_shardings=(p_sh, batch_sh["tokens"]))
            args = (params_shape, specs["tokens"])
        with _mesh_context(mesh):
            lowered = fn.lower(*args)
    else:  # decode
        params_shape = jax.eval_shape(
            functools.partial(lm.init_params, cfg=cfg), key)
        pspec = shd.param_specs(params_shape, cfg, mesh)
        p_sh = shd.to_shardings(pspec, mesh)
        cache_shape = jax.eval_shape(functools.partial(
            lm.init_cache, cfg, shape.global_batch, shape.seq_len,
            _memory_len(cfg)))
        shard_seq = shape.global_batch == 1
        cache_spec = shd.cache_specs(cache_shape, cfg, mesh, baxes, shard_seq)
        cache_sh = shd.to_shardings(cache_spec, mesh)

        def ds(params, cache, tokens):
            return lm.decode_step(params, cache, tokens, cfg)

        fn = jax.jit(ds, in_shardings=(p_sh, cache_sh, batch_sh["tokens"]),
                     out_shardings=(None, cache_sh), donate_argnums=(1,))
        with _mesh_context(mesh):
            lowered = fn.lower(params_shape, cache_shape,
                               specs["tokens"])
    set_sharding_rules(None)
    n_params = cfg.param_count()
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind, "n_params": n_params,
            "n_active": cfg.active_param_count(),
            "chips": 512 if multi_pod else 256,
            "global_batch": shape.global_batch, "seq_len": shape.seq_len}
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             microbatches: int = 0, smoke: bool = False) -> dict:
    t0 = time.time()
    rec: dict = {}
    try:
        lowered, rec = build_cell(arch, shape_name, multi_pod, microbatches,
                                  smoke=smoke)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax<=0.4 wraps it in a list
            cost = cost[0] if cost else {}
        # XLA's analysis visits while bodies once -> undercounts scans;
        # kept for reference only. The roofline uses the trip-count-aware
        # numbers from hlo_cost.analyze.
        rec["xla_flops_body_once"] = float(cost.get("flops", -1))
        rec["xla_bytes_body_once"] = float(cost.get("bytes accessed", -1))
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)
            }
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)}
        text = compiled.as_text()
        # persist the partitioned HLO (zstd) so analysis can be re-run
        # without recompiling; optional -- cost analysis proceeds without it
        try:
            import zstandard as zstd
        except ImportError:
            zstd = None
        if zstd is not None:
            os.makedirs(out_dir, exist_ok=True)
            tag0 = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
            with open(os.path.join(out_dir, tag0 + ".hlo.zst"), "wb") as f:
                f.write(zstd.ZstdCompressor(level=3).compress(text.encode()))
        from repro.launch.hlo_cost import analyze as hlo_analyze
        cost2 = hlo_analyze(text)
        rec["flops_per_chip"] = cost2["flops"]
        rec["bytes_per_chip"] = cost2["bytes"]
        rec["collectives"] = cost2["collectives"]
        rec["collective_wire_bytes_per_chip"] = cost2["collective_wire_bytes"]
        rec["trace_s"] = t1 - t0
        rec["compile_s"] = t2 - t1
        rec["status"] = "ok"
    except Exception as e:
        rec.update({"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": "fail", "error": f"{type(e).__name__}: {e}"})
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{rec['status']}] {tag} "
          f"(compile {rec.get('compile_s', 0):.1f}s) "
          f"{rec.get('error', '')}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = use cfg.train_microbatches")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs on the production mesh (CI)")
    args = ap.parse_args()

    archs = ARCHS if (args.all or not args.arch) else [ALIASES.get(args.arch, args.arch)]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
                print(f"[skip] {arch}_{shape} (full attention; DESIGN.md S5)",
                      flush=True)
                continue
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               args.microbatches, smoke=args.smoke)
                n_fail += rec["status"] != "ok"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
