from .encode_plan import EncodePlan, make_encode_plan, pad_channels, shard_state
from .mesh import batch_axes, make_debug_mesh, make_production_mesh

__all__ = ["EncodePlan", "make_encode_plan", "pad_channels", "shard_state",
           "batch_axes", "make_debug_mesh", "make_production_mesh"]
