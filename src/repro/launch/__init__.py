from .mesh import batch_axes, make_debug_mesh, make_production_mesh

__all__ = ["batch_axes", "make_debug_mesh", "make_production_mesh"]
