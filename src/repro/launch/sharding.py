"""Sharding policy: pytree-path based PartitionSpecs for params, optimizer
state, caches and batches.

Baseline layout (Megatron TP x FSDP/ZeRO-1):
  - `model` axis: attention head projections, FFN hidden, vocab, (experts).
  - `data` axis: FSDP shard of every large parameter's other big dim; the
    optimizer state mirrors the param specs (ZeRO-1).
  - batch dims: ('pod','data') multi-pod, ('data',) single-pod.
  - long-context decode (batch=1): the KV-cache *sequence* dim shards over
    the batch axes instead (sequence parallelism); GSPMD turns the cache
    attention into a distributed softmax (partial max/sum + all-reduce).

Axes are dropped when a dim is not divisible by the mesh axis size (GSPMD
would pad; for the baseline we prefer clean layouts and replicate instead).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "to_shardings",
           "activation_rules"]


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in name]))
    return mesh.shape[name]


def _fit(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes that don't divide the corresponding dim."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        out.append(ax if shape[i] % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


# --------------------------------------------------------------- param rules
def _param_rule(pstr: str, ndim: int, cfg: ModelConfig, ep: bool) -> P:
    """Spec for the *unstacked* parameter (scan dim handled by caller)."""
    name = pstr.rsplit("/", 1)[-1]
    d = {"f": "data", "m": "model"}
    if name in ("wq", "wk", "wv", "up", "gate", "in_proj", "wr", "wg",
                "w_lora_a"):
        return P("data", "model")
    if name in ("wo", "down", "out_proj", "wv_cm", "w_lora_b"):
        return P("model", "data")
    if name == "table":      # (vocab, d): vocab on model, d FSDP
        return P("model", "data")
    if name == "unembed":    # (d, vocab)
        return P("data", "model")
    if name == "router":
        return P("data", None)
    if name in ("experts_up", "experts_gate"):  # (E, d, f)
        return P("model", "data", None) if ep else P(None, "data", "model")
    if name == "experts_down":  # (E, f, d)
        return P("model", None, "data") if ep else P(None, "model", "data")
    if name == "conv_w":
        return P(None, "model")
    if name == "u":
        return P("model", None)
    # rwkv channel-mix wk/wv share names with time-mix; handled above (wk
    # (d,ff) -> data,model fits both). wv in channel mix is (ff, d):
    if name == "wk":
        return P("data", "model")
    if name == "wv" and ndim == 2:
        return P("data", "model")
    return P(*([None] * ndim))  # norms, biases, mu, scalars: replicated


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching a (possibly abstract) param tree."""
    tp = mesh.shape["model"]
    ep = (cfg.num_experts > 0 and cfg.num_experts % tp == 0
          and cfg.moe_expert_parallel)

    def rule(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        scanned = ("stages/" in pstr or pstr.startswith("stages")
                   or "/stage/" in pstr)
        ndim = len(shape) - (1 if scanned else 0)
        spec = _param_rule(pstr, ndim, cfg, ep)
        # rwkv channel-mix wv is (ff, d): flip if first dim == d_ff
        name = pstr.rsplit("/", 1)[-1]
        core = shape[1:] if scanned else shape
        if name == "wv" and len(core) == 2 and core[0] == cfg.d_ff:
            spec = P("model", "data")
        if scanned:
            spec = P(*((None,) + tuple(spec)))
        return _fit(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def state_specs(state_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """TrainState specs: params + (mu, nu mirror params) + scalars."""
    from repro.train import TrainState  # avoid cycle

    pspecs = param_specs(state_shape.params, cfg, mesh)
    opt = state_shape.opt
    gc = state_shape.gradcomp
    return TrainState(
        params=pspecs,
        opt=type(opt)(step=P(),
                      mu=param_specs(opt.mu, cfg, mesh),
                      nu=param_specs(opt.nu, cfg, mesh)),
        gradcomp=None if gc is None else type(gc)(
            residual=param_specs(gc.residual, cfg, mesh)),
    )


# --------------------------------------------------------------- batch rules
def batch_specs(batch_shape: Any, mesh: Mesh, baxes) -> Any:
    def rule(path, leaf):
        spec = P(baxes, *([None] * (len(leaf.shape) - 1)))
        return _fit(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


# --------------------------------------------------------------- cache rules
def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh, baxes,
                shard_sequence: bool) -> Any:
    """KV/state cache specs.  shard_sequence=True (long-context, batch=1):
    the KV-cache *sequence* dim takes the batch axes (sequence parallelism;
    GSPMD lowers the cache attention to a distributed softmax).

    Dispatches on the typed cache containers (KVCache / SSMCache /
    RwkvCache); every array has a leading stage-repeats dim from the scan."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache
    from repro.models.rwkv import RwkvCache
    from repro.models.lm import DecodeCache

    def kv_spec(kv: KVCache):
        seq = baxes if shard_sequence else None
        b = None if shard_sequence else baxes
        return KVCache(
            k=_fit(P(None, b, seq, "model", None), kv.k.shape, mesh),
            v=_fit(P(None, b, seq, "model", None), kv.v.shape, mesh),
            length=P(),
        )

    def ssm_spec(c: SSMCache):
        return SSMCache(
            state=_fit(P(None, baxes, "model", None, None), c.state.shape, mesh),
            conv=_fit(P(None, baxes, None, "model"), c.conv.shape, mesh),
            length=P(),
        )

    def rwkv_spec(c: RwkvCache):
        return RwkvCache(
            state=_fit(P(None, baxes, "model", None, None), c.state.shape, mesh),
            last_tm=_fit(P(None, baxes, None), c.last_tm.shape, mesh),
            last_cm=_fit(P(None, baxes, None), c.last_cm.shape, mesh),
            length=P(),
        )

    def rule(leaf):
        if isinstance(leaf, KVCache):
            return kv_spec(leaf)
        if isinstance(leaf, SSMCache):
            return ssm_spec(leaf)
        if isinstance(leaf, RwkvCache):
            return rwkv_spec(leaf)
        return _fit(P(baxes, None, None), leaf.shape, mesh)  # memory (B,M,d)

    stages = jax.tree.map(
        rule, cache_shape.stages,
        is_leaf=lambda x: isinstance(x, (KVCache, SSMCache, RwkvCache)))
    mem = None if cache_shape.memory is None else rule(cache_shape.memory)
    return DecodeCache(stages=stages, memory=mem)


# --------------------------------------------------- activations (logical())
def activation_rules(cfg: ModelConfig, mesh: Mesh, baxes) -> dict:
    tp = mesh.shape["model"]
    ep = bool(cfg.num_experts and cfg.num_experts % tp == 0
              and cfg.moe_expert_parallel)
    return {
        "batch": baxes,
        "ff": "model" if cfg.d_ff % tp == 0 else None,
        "heads": "model" if cfg.num_heads % tp == 0 else None,
        "kv_heads": "model" if cfg.num_kv_heads % tp == 0 else None,
        "vocab": "model" if cfg.vocab_size % tp == 0 else None,
        "experts": "model" if ep else None,
        # expert-FFN dim takes the model axis only when experts don't (TP
        # inside experts vs EP across them -- never both on one tensor)
        "moe_ff": None if ep else ("model" if cfg.d_ff % tp == 0 else None),
    }


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
