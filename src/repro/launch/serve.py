"""Serving launcher: batched decode with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import lm
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch), smoke=args.smoke)
    params = lm.init_params(jax.random.key(0), cfg)
    mem_len = (cfg.num_image_tokens if cfg.family == "vlm"
               else cfg.encoder_seq if cfg.family == "audio" else 0)
    engine = ServeEngine(cfg, params, max_seq=args.max_seq,
                         memory_len=mem_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.gen)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s batched)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
