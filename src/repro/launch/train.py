"""Training launcher: single-host CPU execution or mesh-sharded execution.

Production entry point (real TPU pods would run this under the cluster
launcher with jax.distributed.initialize):

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt --gradcomp
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.data import synthetic
from repro.runtime import FaultInjector, FaultTolerantTrainer
from repro.train import init_train_state, make_train_step


def build_batches(cfg, steps: int, batch: int, seq: int, seed: int = 0):
    batches = list(synthetic.token_stream(steps, batch, seq, cfg.vocab_size,
                                          seed=seed))
    for b in batches:
        if cfg.family == "vlm":
            b["memory"] = np.zeros((batch, cfg.num_image_tokens, cfg.d_model),
                                   np.float32)
        if cfg.family == "audio":
            b["frames"] = np.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                   np.float32)
    return batches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--gradcomp", action="store_true",
                    help="IDEALEM gradient compression + error feedback")
    ap.add_argument("--inject-crash", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch), smoke=args.smoke)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps}")
    state = init_train_state(jax.random.key(0), cfg,
                             use_gradcomp=args.gradcomp)
    step_fn = jax.jit(make_train_step(
        cfg, lr=args.lr, microbatches=args.microbatches,
        use_gradcomp=args.gradcomp))

    injector = FaultInjector({args.inject_crash: "crash"}) \
        if args.inject_crash is not None else None
    trainer = FaultTolerantTrainer(
        train_step=step_fn, state=state, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, injector=injector)
    batches = build_batches(cfg, args.steps, args.batch, args.seq)
    t0 = time.time()
    trainer.run(batches, args.steps)
    dt = time.time() - t0
    losses = [e["loss"] for e in trainer.log if "loss" in e]
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"events: {[e for e in trainer.log if 'event' in e]}")


if __name__ == "__main__":
    main()
