"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is a
second data-parallel axis whose collectives cross the slow inter-pod links
-- exactly the hop IDEALEM gradient compression targets (DESIGN.md Sec. 2).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "batch_axes", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess with forced device
    count)."""
    return jax.make_mesh((data, model), ("data", "model"))
