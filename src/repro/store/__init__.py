"""Indexed decode store: random-access containers over IDEALEM streams.

The write side (``repro.core.session``, ``repro.serve``) emits append-mode
segment streams; this package is the symmetric read side (DESIGN.md
Sec. 7):

  container  -- ``.idlm``-wrapping container format with a footer index
                (per-segment offsets, cumulative block counts, FIFO fill
                counters, dictionary snapshots, restart points) and
                ``pack``/``ContainerWriter`` writers + a strict reader;
  reader     -- ``decode_range``/``decode_ranges``/``decode_channels``:
                seek via the index, walk only the covering segments, and
                rebuild in one padded batch -- byte-identical to the
                corresponding slice of a full ``decode_stream``.
"""
from .container import (Container, ContainerFormatError, ContainerWriter,
                        pack)
from .reader import (ParsedChunk, decode_channels, decode_range,
                     decode_ranges, gather_parts, parse_chunk, plan_parts,
                     plan_windows)

__all__ = [
    "Container",
    "ContainerFormatError",
    "ContainerWriter",
    "pack",
    "ParsedChunk",
    "parse_chunk",
    "plan_windows",
    "gather_parts",
    "plan_parts",
    "decode_range",
    "decode_ranges",
    "decode_channels",
]
