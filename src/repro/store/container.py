"""Random-access container format for IDEALEM streams (DESIGN.md Sec. 7).

A raw ``.idlm`` stream is a chain of segments that can only be decoded by
walking every decision byte from the front: segment boundaries, the FIFO
fill counter and the dictionary contents are all implicit in the bytes that
came before.  The container wraps one or more streams (one per *channel*)
with a footer index that makes every segment seekable:

  file   := file-header | chunk* | index | footer
  chunk  := one verbatim ``.idlm`` segment (header + body, untouched)
  index  := per-chunk records + dictionary snapshots (below)
  footer := index offset/length + CRC-32, fixed size, at the very end

Per chunk the index records the byte offset/length, the channel, the block
count and per-channel cumulative block count, the CONT/MORE/tail flags, the
FIFO fill counter *entering* the segment, and the nearest clean restart
point (a segment is independently decodable from empty state iff it is not
FLAG_CONT and enters with an empty dictionary; within a channel that is its
first segment).  The *dictionary snapshot* is what buys true random access:
for every slot valid at segment entry, the absolute byte offset of the
payload of the most recent miss written to that slot.  A reader can
therefore start parsing at ANY segment -- carried dictionary entries are
gathered straight from the snapshot offsets instead of replaying history
(``repro.store.reader``).

Snapshots are stored as *deltas* (container v2): per chunk, only the
``(slot, offset)`` pairs that changed since the previous chunk of the same
channel -- i.e. the slots the previous segment's misses touched.  A full
snapshot per chunk is O(chunks x D); for a high-D channel cut into many
tiny segments the delta form shrinks the index to O(total misses), and the
reader reassembles the full per-chunk snapshots once at open time
(tests/test_store.py pins the size win).

Chunks are byte-verbatim segments, so concatenating a channel's chunks
reproduces the original stream exactly; ``pack``/``append`` never re-encode.
The strict reader validates both magics, the version, the footer CRC and
the structural invariants before trusting any offset.  ``Container.open``
can back the data region with a read-only ``mmap`` so archives larger than
RAM are served zero-copy (chunks are ``memoryview`` slices into the map;
only the index is materialized).
"""
from __future__ import annotations

import io
import itertools
import os
import struct
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import stream as stream_mod
from repro.core.stream import StreamFormatError, StreamHeader

__all__ = [
    "ContainerFormatError",
    "Container",
    "ContainerWriter",
    "pack",
]

FILE_MAGIC = b"IDLMPAK1"
FOOTER_MAGIC = b"IDLXFTR1"
CONTAINER_VERSION = 2    # v2: dictionary snapshots stored as deltas
_FILE_HDR = struct.Struct("<8sH6x")      # 16 bytes
_FOOTER = struct.Struct("<8sQII")        # 24 bytes: magic, off, len, crc
_INDEX_HDR = struct.Struct("<IHH")       # n_chunks, n_channels, reserved

# Monotonic token source for containers without a backing file, so parsed-
# chunk caches keyed on ``cache_token`` can never alias two distinct
# in-memory containers (an ``id()`` could be recycled after GC).
_MEM_TOKENS = itertools.count()

CHUNK_CONT = 1    # segment continues the previous segment's dictionary
CHUNK_MORE = 2    # another segment follows in this channel's stream
CHUNK_TAIL = 4    # segment header carries a non-empty sample tail

# (name, dtype) pairs of the fixed per-chunk index columns, in file order.
_COLUMNS = [
    ("channel", "<u2"),
    ("offset", "<u8"),
    ("length", "<u4"),
    ("n_blocks", "<u4"),
    ("blocks_before", "<u8"),
    ("fill_in", "<u2"),
    ("flags", "u1"),
    ("restart", "<u4"),
]


# Historical import path: the class now lives in the unified hierarchy
# (repro.errors) under the ReproError root; same object either way.
from repro.errors import ContainerFormatError  # noqa: E402,F401


# --------------------------------------------------------------------- writer

@dataclass
class _ChannelState:
    """Writer-side running state of one channel's stream."""

    header: StreamHeader              # first segment's header (param source)
    fill: int = 0                     # FIFO fill counter after last segment
    blocks: int = 0                   # total blocks appended
    restart: int = 0                  # container chunk id of the stream start
    finished: bool = False            # a non-MORE segment has been appended
    snap: np.ndarray = field(
        default_factory=lambda: np.full(0, -1, dtype=np.int64))

    def params(self):
        h = self.header
        return (h.mode, h.block_size, h.num_dict, h.max_count,
                np.dtype(h.dtype), h.value_range)


class ContainerWriter:
    """Incremental container writer.

    ``append(data, channel)`` accepts one segment or a chain of segments
    (e.g. everything an ``IdealemSession`` has emitted so far) and writes
    them as index-tracked chunks; ``finalize()`` writes the index + footer.
    With no ``path`` the container is built in memory and ``finalize``
    returns the bytes.  ``ContainerWriter.reopen`` resumes appending to an
    existing container file: the index carries enough state (fill counters,
    snapshots) to continue any unfinished channel.
    """

    def __init__(self, path: Optional[str] = None):
        self._own: Optional[io.BytesIO] = None
        if path is None:
            self._f = self._own = io.BytesIO()
        else:
            self._f = open(path, "wb")
        self._f.write(_FILE_HDR.pack(FILE_MAGIC, CONTAINER_VERSION))
        self._pos = _FILE_HDR.size
        self._chan: Dict[int, _ChannelState] = {}
        self._records: List[tuple] = []   # per-chunk fixed columns
        self._snaps: List[np.ndarray] = []
        self._finalized = False

    # -- lifecycle ---------------------------------------------------------
    def append(self, data: bytes, channel: int = 0) -> None:
        """Append one segment -- or a back-to-back chain of segments -- to
        ``channel``.  Segments are stored verbatim; the index entry (fill
        counter, dictionary snapshot, cumulative blocks) is derived by
        walking the decision bytes once, right here."""
        if self._finalized:
            raise RuntimeError("container already finalized")
        if not (0 <= channel < 2 ** 16):
            raise ValueError("channel must fit in uint16")
        if len(data) == 0:
            return
        st = self._chan.get(channel)
        buf = memoryview(data)
        # validate the leading segment's framing BEFORE walking: a segment
        # fed with the wrong carried fill counter walks as garbage, which
        # would mask the real mistake (wrong CONT flag) behind a walk error
        hdr0, _ = stream_mod._unpack_header(buf, 0)
        if st is None and hdr0.cont:
            raise StreamFormatError(
                f"channel {channel}: first segment sets FLAG_CONT", 0)
        if st is not None and not hdr0.cont:
            raise StreamFormatError(
                f"channel {channel}: mid-stream segment without FLAG_CONT "
                "(stream restarts are not supported)", 0)
        segs, is_hit, slot, ovw = stream_mod._walk_all(
            buf, 0, st.fill if st else 0, till_end=True)
        for seg in segs:
            st = self._append_seg(channel, buf, seg, is_hit, slot, ovw)

    def _append_seg(self, channel, buf, seg, is_hit, slot, ovw):
        hdr = seg.header
        st = self._chan.get(channel)
        if st is None:
            if hdr.cont:
                raise StreamFormatError(
                    f"channel {channel}: first segment sets FLAG_CONT",
                    seg.start)
            st = self._chan[channel] = _ChannelState(
                header=hdr, restart=len(self._records),
                snap=np.full(hdr.num_dict, -1, dtype=np.int64))
        else:
            if st.finished:
                raise StreamFormatError(
                    f"channel {channel}: stream already finished", seg.start)
            if not hdr.cont:
                raise StreamFormatError(
                    f"channel {channel}: mid-stream segment without "
                    "FLAG_CONT (stream restarts are not supported)",
                    seg.start)
            if st.params() != _ChannelState(header=hdr).params():
                raise StreamFormatError(
                    f"channel {channel}: segment codec parameters changed",
                    seg.start)

        file_off = self._pos
        delta = file_off - seg.start  # segment buffer -> file offsets
        flags = ((CHUNK_CONT if hdr.cont else 0)
                 | (CHUNK_MORE if hdr.more else 0)
                 | (CHUNK_TAIL if len(hdr.tail) else 0))
        self._records.append((
            channel, file_off, seg.end - seg.start, seg.n_blocks, st.blocks,
            seg.fill_in, flags, st.restart,
        ))
        self._snaps.append(st.snap[:seg.fill_in].copy())

        # fold this segment's misses into the channel's snapshot state
        h = is_hit[seg.i0:seg.i0 + seg.n_blocks]
        if seg.n_blocks:
            o = ovw[seg.i0:seg.i0 + seg.n_blocks]
            s = slot[seg.i0:seg.i0 + seg.n_blocks]
            _, pay = stream_mod._segment_offsets(
                hdr, seg.body_start + delta, h, o, hdr.cont)
            np.maximum.at(st.snap, s[~h], pay)
        st.fill = min(st.fill + int(np.sum(~h)), hdr.num_dict)
        st.blocks += seg.n_blocks
        st.finished = not hdr.more

        self._f.write(buf[seg.start:seg.end])
        self._pos += seg.end - seg.start
        return st

    def finalize(self) -> Optional[bytes]:
        """Write the index + footer.  Returns the container bytes when
        writing in memory, ``None`` when backed by a file (closed here)."""
        if self._finalized:
            raise RuntimeError("container already finalized")
        self._finalized = True
        index = self._serialize_index()
        self._f.write(index)
        self._f.write(_FOOTER.pack(FOOTER_MAGIC, self._pos, len(index),
                                   zlib.crc32(index)))
        if self._own is not None:
            out = self._own.getvalue()
            self._own.close()
            return out
        self._f.close()
        return None

    # -- internals ---------------------------------------------------------
    def _serialize_index(self) -> bytes:
        """Index layout (v2): header | fixed columns | per-chunk delta
        count (u2) | delta slots (u8) | delta offsets (i8).

        The writer keeps FULL per-chunk snapshots in memory (``reopen``
        needs them); only serialization diffs consecutive snapshots of the
        same channel.  The first chunk of a channel enters with an empty
        dictionary, so its delta is empty too; growth slots (fill_in rose)
        always diff against the -1 sentinel and are therefore emitted."""
        n = len(self._records)
        cols = list(zip(*self._records)) if n else [[] for _ in _COLUMNS]
        parts = [_INDEX_HDR.pack(n, len(self._chan), 0)]
        for (name, dt), col in zip(_COLUMNS, cols):
            parts.append(np.asarray(col, dtype=dt).tobytes())
        counts = np.zeros(n, dtype="<u2")
        slot_parts, off_parts = [], []
        prev: Dict[int, np.ndarray] = {}
        for k, (rec, snap) in enumerate(zip(self._records, self._snaps)):
            ch = int(rec[0])
            p = prev.get(ch, np.zeros(0, np.int64))
            base = np.full(len(snap), -1, dtype=np.int64)
            base[:len(p)] = p  # fill never shrinks: len(p) <= len(snap)
            ds = np.flatnonzero(base != snap)
            counts[k] = len(ds)
            slot_parts.append(ds.astype(np.uint8))
            off_parts.append(snap[ds])
            prev[ch] = snap
        parts.append(counts.tobytes())
        parts.append((np.concatenate(slot_parts) if slot_parts
                      else np.zeros(0, np.uint8)).tobytes())
        parts.append((np.concatenate(off_parts) if off_parts
                      else np.zeros(0, np.int64)).astype("<i8").tobytes())
        return b"".join(parts)

    @classmethod
    def reopen(cls, path: str) -> "ContainerWriter":
        """Resume appending to an existing container file: restore the
        per-channel writer state from the index, truncate the old
        index + footer, and keep writing chunks."""
        src = Container.open(path)
        w = cls.__new__(cls)
        w._own = None
        w._f = open(path, "r+b")
        w._f.seek(src.data_end)
        w._f.truncate()
        w._pos = src.data_end
        w._records = [tuple(int(src._cols[name][i]) for name, _ in _COLUMNS)
                      for i in range(src.n_chunks)]
        w._snaps = [src.snapshot(i).copy() for i in range(src.n_chunks)]
        w._finalized = False
        w._chan = {}
        buf = memoryview(src.data)
        for c in src.channels:
            ks = src.chunks_of(c)
            last = int(ks[-1])
            hdr0 = src.header_of(int(ks[0]))
            st = _ChannelState(
                header=hdr0, restart=int(src._cols["restart"][last]),
                snap=np.full(hdr0.num_dict, -1, dtype=np.int64))
            st.snap[:len(src.snapshot(last))] = src.snapshot(last)
            # exit state of the last chunk = its entry snapshot + its misses
            hdr_l, off = stream_mod._unpack_header(
                buf, int(src._cols["offset"][last]))
            hb, sb, ob = bytearray(), bytearray(), bytearray()
            stream_mod._walk_segment(buf, off, hdr_l,
                                     int(src._cols["fill_in"][last]),
                                     hb, sb, ob)
            h = np.frombuffer(hb, np.uint8).astype(bool)
            if len(h):
                _, pay = stream_mod._segment_offsets(
                    hdr_l, off, h, np.frombuffer(ob, np.uint8).astype(bool),
                    hdr_l.cont)
                np.maximum.at(st.snap,
                              np.frombuffer(sb, np.uint8)[~h].astype(np.int64),
                              pay)
            st.fill = min(int(src._cols["fill_in"][last]) + int(np.sum(~h)),
                          hdr0.num_dict)
            st.blocks = src.total_blocks(c)
            st.finished = not hdr_l.more
            w._chan[int(c)] = st
        return w


def pack(streams: Union[bytes, Sequence[bytes], Mapping[int, bytes]],
         path: Optional[str] = None) -> Optional[bytes]:
    """One-shot packer: wrap finished ``.idlm`` stream(s) in a container.

    ``streams`` is a single stream (channel 0), a sequence (channel = list
    position) or a mapping ``{channel: stream}`` -- e.g. the per-channel
    blobs of a multi-channel session.  Returns the container bytes (or
    ``None`` after writing to ``path``)."""
    if isinstance(streams, (bytes, bytearray, memoryview)):
        streams = {0: bytes(streams)}
    elif not isinstance(streams, Mapping):
        streams = dict(enumerate(streams))
    w = ContainerWriter(path)
    for channel in sorted(streams):
        w.append(streams[channel], channel=channel)
    return w.finalize()


# --------------------------------------------------------------------- reader

class Container:
    """Strict random-access reader over a packed container.

    Validation happens once, at construction: both magics, the version, the
    footer CRC over the index bytes, and the structural invariants (chunk
    extents inside the data region, per-channel block continuity, snapshot
    sizes).  After that every accessor is O(1) numpy indexing; segment
    bodies are only ever walked by the range decoder, and only for the
    chunks a request actually covers."""

    def __init__(self, data, source_path: Optional[str] = None):
        self.data = data  # bytes, or any buffer (e.g. a read-only mmap)
        self._mmap = None
        self._file = None
        buf = memoryview(data)
        if len(data) < _FILE_HDR.size + _FOOTER.size:
            raise ContainerFormatError("container shorter than its framing")
        magic, ver = _FILE_HDR.unpack_from(buf, 0)
        if magic != FILE_MAGIC:
            raise ContainerFormatError("bad container magic")
        if ver != CONTAINER_VERSION:
            raise ContainerFormatError(f"unsupported container version {ver}")
        fmagic, idx_off, idx_len, crc = _FOOTER.unpack_from(
            buf, len(data) - _FOOTER.size)
        if fmagic != FOOTER_MAGIC:
            raise ContainerFormatError("bad footer magic")
        if not (_FILE_HDR.size <= idx_off
                and idx_off + idx_len + _FOOTER.size == len(data)):
            raise ContainerFormatError("index extent inconsistent with file "
                                       "size")
        index = bytes(buf[idx_off:idx_off + idx_len])
        del buf  # release the exported view (mmap.close() would refuse)
        if zlib.crc32(index) != crc:
            raise ContainerFormatError("index CRC mismatch")
        #: footer CRC doubles as the container *generation*: two opens of
        #: the same (unmodified) file share it, a reopen-append changes it.
        self.generation = int(crc)
        #: identity for parsed-chunk caches (``repro.serve``): containers
        #: opened from the same file generation share cached walks.
        if source_path is not None:
            self.cache_token = (os.path.abspath(source_path), self.generation)
        else:
            self.cache_token = ("mem", next(_MEM_TOKENS))
        self.source_path = source_path
        self.data_end = idx_off
        self._parse_index(index)
        self._check_invariants()

    @classmethod
    def open(cls, path: str, mmap: bool = False) -> "Container":
        """Open a container file.  With ``mmap=True`` the data region is a
        read-only memory map: chunk accesses are zero-copy ``memoryview``
        slices into the page cache, so archives larger than RAM serve
        range reads without ever materializing the file.  Call ``close()``
        (or use the container as a context manager) to drop the map; views
        handed out by ``chunk_bytes`` must not outlive it."""
        if not mmap:
            with open(path, "rb") as f:
                return cls(f.read(), source_path=path)
        import mmap as mmap_mod
        f = open(path, "rb")
        try:
            mm = mmap_mod.mmap(f.fileno(), 0, access=mmap_mod.ACCESS_READ)
        except Exception:
            f.close()
            raise
        try:
            store = cls(mm, source_path=path)
        except Exception:
            mm.close()
            f.close()
            raise
        store._mmap, store._file = mm, f
        return store

    def close(self) -> None:
        """Release the backing mmap/file (no-op for in-memory containers)."""
        if self._mmap is not None:
            self._mmap.close()
            self._file.close()
            self._mmap = self._file = None

    def __enter__(self) -> "Container":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- index parsing -----------------------------------------------------
    def _parse_index(self, index: bytes) -> None:
        try:
            n, n_chan, _ = _INDEX_HDR.unpack_from(index, 0)
        except struct.error:
            raise ContainerFormatError("truncated index header") from None
        off = _INDEX_HDR.size
        self.n_chunks = n
        self._cols: Dict[str, np.ndarray] = {}
        for name, dt in _COLUMNS:
            width = n * np.dtype(dt).itemsize
            if off + width > len(index):
                raise ContainerFormatError(f"index column {name} truncated")
            self._cols[name] = np.frombuffer(index, dtype=dt, count=n,
                                             offset=off).astype(np.int64)
            off += width
        # snapshot deltas: per-chunk count, then slot/offset blobs (v2)
        if off + 2 * n > len(index):
            raise ContainerFormatError("snapshot delta counts truncated")
        counts = np.frombuffer(index, dtype="<u2", count=n,
                               offset=off).astype(np.int64)
        off += 2 * n
        n_delta = int(counts.sum())
        if off + n_delta + 8 * n_delta != len(index):
            raise ContainerFormatError("snapshot delta blob size mismatch")
        d_slots = np.frombuffer(index, dtype=np.uint8, count=n_delta,
                                offset=off).astype(np.int64)
        d_offs = np.frombuffer(index, dtype="<i8", count=n_delta,
                               offset=off + n_delta).astype(np.int64)
        self._cols["snap_delta"] = counts
        self._snap_start = np.concatenate(
            [[0], np.cumsum(self._cols["fill_in"])]).astype(np.int64)
        self._snaps = self._reassemble_snapshots(counts, d_slots, d_offs)
        self.channels = sorted(int(c)
                               for c in np.unique(self._cols["channel"]))
        if len(self.channels) != n_chan:
            raise ContainerFormatError("channel count mismatch")
        self._by_channel = {
            c: np.flatnonzero(self._cols["channel"] == c)
            for c in self.channels
        }

    def _reassemble_snapshots(self, counts: np.ndarray, d_slots: np.ndarray,
                              d_offs: np.ndarray) -> np.ndarray:
        """Rebuild the full per-chunk snapshots from the delta form, once,
        at open time: per channel, each chunk's entering snapshot is the
        previous chunk's plus its ``(slot, offset)`` deltas (growth slots
        appear as deltas against the -1 sentinel, which
        ``_check_invariants`` then rejects if any slot was never set)."""
        fill = self._cols["fill_in"]
        snaps = np.full(int(fill.sum()), -1, dtype=np.int64)
        dstart = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        prev: Dict[int, np.ndarray] = {}
        for k in range(self.n_chunks):
            ch = int(self._cols["channel"][k])
            f = int(fill[k])
            cur = np.full(f, -1, dtype=np.int64)
            p = prev.get(ch)
            if p is not None:
                if len(p) > f:
                    raise ContainerFormatError(
                        f"chunk {k}: fill counter shrank within channel {ch}")
                cur[:len(p)] = p
            sl = d_slots[dstart[k]:dstart[k + 1]]
            if len(sl) and (f == 0 or int(sl.max()) >= f):
                raise ContainerFormatError(
                    f"chunk {k}: snapshot delta slot outside the fill range")
            cur[sl] = d_offs[dstart[k]:dstart[k + 1]]
            snaps[self._snap_start[k]:self._snap_start[k + 1]] = cur
            prev[ch] = cur
        return snaps

    def _check_invariants(self) -> None:
        cols = self._cols
        ends = cols["offset"] + cols["length"]
        if self.n_chunks:
            if int(cols["offset"].min()) < _FILE_HDR.size:
                raise ContainerFormatError("chunk overlaps the file header")
            if int(ends.max()) > self.data_end:
                raise ContainerFormatError("chunk overruns the data region")
            if np.any(cols["length"] <= 0):
                raise ContainerFormatError("zero-length chunk")
        if np.any(self._snaps < 0):
            raise ContainerFormatError("negative snapshot offset")
        for c, ks in self._by_channel.items():
            # snapshot offsets are trusted by the range decoder's payload
            # gather: every one must hold a full payload row inside the
            # data region
            hdr = self.header_of(int(ks[0]))
            P = (hdr.block_size if hdr.mode == stream_mod.MODE_STD
                 else hdr.block_size - 1)
            width = P * np.dtype(hdr.dtype).itemsize
            snaps = [self.snapshot(int(k)) for k in ks]
            snaps = np.concatenate(snaps) if snaps else np.zeros(0, np.int64)
            if len(snaps) and (int(snaps.min()) < _FILE_HDR.size
                               or int(snaps.max()) + width > self.data_end):
                raise ContainerFormatError(
                    f"channel {c}: snapshot offset outside the data region")
            bb = cols["blocks_before"][ks]
            nb = cols["n_blocks"][ks]
            if np.any(bb != np.concatenate([[0], np.cumsum(nb)[:-1]])):
                raise ContainerFormatError(
                    f"channel {c}: cumulative block counts are inconsistent")
            r = cols["restart"][ks]
            if np.any(r != ks[0]):
                raise ContainerFormatError(
                    f"channel {c}: restart points outside the channel")

    # -- accessors ---------------------------------------------------------
    def chunks_of(self, channel: int) -> np.ndarray:
        """Container chunk ids of ``channel``'s segments, in stream order."""
        try:
            return self._by_channel[channel]
        except KeyError:
            raise KeyError(f"no channel {channel} in container") from None

    def chunk_bytes(self, chunk: int) -> memoryview:
        off = int(self._cols["offset"][chunk])
        return memoryview(self.data)[off:off + int(self._cols["length"][chunk])]

    def header_of(self, chunk: int) -> StreamHeader:
        hdr, _ = stream_mod._unpack_header(
            memoryview(self.data), int(self._cols["offset"][chunk]))
        return hdr

    def snapshot(self, chunk: int) -> np.ndarray:
        """Dictionary snapshot entering ``chunk``: absolute payload byte
        offset of the live miss for every valid slot (slot order)."""
        return self._snaps[self._snap_start[chunk]:self._snap_start[chunk + 1]]

    def total_blocks(self, channel: int = 0) -> int:
        ks = self.chunks_of(channel)
        return int(self._cols["blocks_before"][ks[-1]]
                   + self._cols["n_blocks"][ks[-1]])

    def tail(self, channel: int = 0) -> np.ndarray:
        """Sample tail of the channel's final segment (may be empty)."""
        last = int(self.chunks_of(channel)[-1])
        if not (self._cols["flags"][last] & CHUNK_TAIL):
            hdr = self.header_of(int(self.chunks_of(channel)[0]))
            return np.zeros(0, dtype=hdr.dtype)
        return self.header_of(last).tail

    def stream_bytes(self, channel: int = 0) -> bytes:
        """Reassemble the channel's original ``.idlm`` stream verbatim."""
        return b"".join(bytes(self.chunk_bytes(int(k)))
                        for k in self.chunks_of(channel))

    def describe(self) -> dict:
        """Summary used by ``scripts/store_tool.py inspect``."""
        out = {"chunks": self.n_chunks, "channels": {},
               "data_bytes": self.data_end - _FILE_HDR.size,
               "index_bytes": len(self.data) - self.data_end - _FOOTER.size,
               "snapshot_entries": int(self._cols["fill_in"].sum()),
               "snapshot_delta_entries": int(self._cols["snap_delta"].sum())}
        for c in self.channels:
            ks = self.chunks_of(c)
            hdr = self.header_of(int(ks[0]))
            out["channels"][c] = {
                "segments": len(ks),
                "blocks": self.total_blocks(c),
                "tail_samples": len(self.tail(c)),
                "mode": hdr.mode,
                "block_size": hdr.block_size,
                "num_dict": hdr.num_dict,
                "dtype": str(np.dtype(hdr.dtype)),
                "finished": not (self._cols["flags"][ks[-1]] & CHUNK_MORE),
            }
        return out
