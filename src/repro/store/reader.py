"""Batched range decode over a packed container (DESIGN.md Secs. 7-8).

``decode_range(store, i, j)`` returns exactly
``decode_stream(channel_stream)[i*B : j*B]`` -- byte-identical -- while
touching only the segments that cover blocks ``[i, j)``:

  1. *seek*: the footer index's cumulative block counts locate the covering
     chunks (two ``searchsorted``\\ s, no byte walking);
  2. *parse*: only those chunks' decision bytes are walked (``parse_chunk``,
     cacheable -- the serving layer LRUs it);  carried dictionary entries
     are materialized from the index's snapshot offsets as *virtual misses*
     in front of the window, so history is never replayed;
  3. *plan + reconstruct*: the requested blocks' payload rows are gathered
     in one fancy-indexing pass (``decode.gather_rows``) into per-request
     ``PlanPart``\\ s, padded into ONE ``DecodePlan`` and rebuilt by the
     unified engine (``repro.core.decode.reconstruct``) on the selected
     backend.  Hit permutations are keyed on the global block position
     (``decode.hit_perms``), which is what makes the slice exact.

This module owns the *container-specific* plumbing only (seek, window
assembly, snapshot materialization, byte gather); all reconstruction math
lives in ``repro.core.decode``.  ``plan_parts`` is the half-open seam the
serving layer uses to merge parts from MANY containers into one device
dispatch per flush (``repro.serve.compress.DecompressionService``).
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import decode as decode_mod
from repro.core import stream as stream_mod
from repro.core.decode import PlanPart
from repro.core.stream import StreamFormatError, StreamHeader

from .container import Container

# Read-path registry metrics (ISSUE 8).  Chunk walks count *actual*
# decision-byte walks -- the serving LRU's hits never reach parse_chunk,
# so (requests served) vs (walks) is the cache story end to end.
_M_WALKS = obs.registry().counter(
    "repro_store_chunk_walks_total",
    "container chunk decision-byte walks (cache misses reach here)")
_M_RANGE_REQS = obs.registry().counter(
    "repro_store_range_requests_total",
    "range-decode requests (one per (channel, start, stop) tuple)")
_M_GATHER_BYTES = obs.registry().counter(
    "repro_store_gather_bytes_total",
    "payload/base bytes fancy-index-gathered from containers")
# request extents in blocks, pow-2-ish ladder: 1 block .. 64k blocks
_M_RANGE_BLOCKS = obs.registry().histogram(
    "repro_store_range_blocks",
    "requested range sizes in blocks",
    buckets=tuple(float(1 << p) for p in range(0, 17, 2)))

__all__ = [
    "ParsedChunk",
    "parse_chunk",
    "plan_windows",
    "gather_parts",
    "plan_parts",
    "decode_range",
    "decode_ranges",
    "decode_channels",
]


class ParsedChunk(NamedTuple):
    """One chunk's walked decisions + absolute value-byte offsets.

    Pure function of ``(container bytes, chunk id)`` -- safe to cache; the
    serving layer's LRU (``repro.serve.compress.DecompressionService``)
    holds exactly these."""

    header: StreamHeader
    is_hit: np.ndarray             # (nb,) bool
    slot: np.ndarray               # (nb,) int32
    base_offs: Optional[np.ndarray]  # (nb,) abs offsets (res/delta) or None
    pay_offs: np.ndarray           # (n_miss,) abs payload offsets, miss order


def parse_chunk(store: Container, chunk: int) -> ParsedChunk:
    """Walk one chunk's decision bytes in isolation.

    The index supplies the two pieces of cross-segment state a raw stream
    only has implicitly: the FIFO fill counter entering the segment and
    (elsewhere, via ``Container.snapshot``) the dictionary contents."""
    _M_WALKS.inc()
    buf = memoryview(store.data)
    start = int(store._cols["offset"][chunk])
    hdr, off = stream_mod._unpack_header(buf, start)
    fill_in = int(store._cols["fill_in"][chunk])
    hb, sb, ob = bytearray(), bytearray(), bytearray()
    end, _ = stream_mod._walk_segment(buf, off, hdr, fill_in, hb, sb, ob)
    if end != start + int(store._cols["length"][chunk]):
        raise StreamFormatError(
            f"chunk {chunk} walk ended at {end}, index says "
            f"{start + int(store._cols['length'][chunk])}", end)
    h = np.frombuffer(hb, np.uint8).astype(bool)
    s = np.frombuffer(sb, np.uint8).astype(np.int32)
    o = np.frombuffer(ob, np.uint8).astype(bool)
    if len(h):
        bo, po = stream_mod._segment_offsets(hdr, off, h, o, hdr.cont)
    else:
        bo = None if hdr.mode == stream_mod.MODE_STD else np.zeros(0, np.int64)
        po = np.zeros(0, np.int64)
    return ParsedChunk(hdr, h, s, bo, po)


ParseFn = Callable[[Container, int], ParsedChunk]


class _Window(NamedTuple):
    """Decision state of the chunks covering one block range, plus the
    snapshot-sourced virtual misses standing in for pre-window history."""

    header: StreamHeader
    gb0: int                  # global block index of the window's first block
    n_vir: int                # virtual (snapshot) misses prepended
    src_pay_offs: np.ndarray  # per-miss payload offsets (virtuals first)
    src: np.ndarray           # per-block source row, window-local, incl. virt
    is_hit: np.ndarray        # (window nb,) real blocks only
    base_offs: Optional[np.ndarray]


def _covering_chunks(store: Container, channel: int, start: int,
                     stop: int) -> Tuple[np.ndarray, int]:
    ks = store.chunks_of(channel)
    total = store.total_blocks(channel)
    if not (0 <= start < stop <= total):
        raise IndexError(
            f"block range [{start}, {stop}) outside [0, {total}) of "
            f"channel {channel}")
    ends = (store._cols["blocks_before"][ks]
            + store._cols["n_blocks"][ks])
    k0 = int(np.searchsorted(ends, start, side="right"))
    k1 = int(np.searchsorted(ends, stop, side="left"))
    return ks[k0:k1 + 1], int(store._cols["blocks_before"][ks[k0]])


def _parse_window(store: Container, chunks: np.ndarray, gb0: int,
                  parse: ParseFn) -> _Window:
    parts = [parse(store, int(k)) for k in chunks]
    hdr = parts[0].header
    fill0 = int(store._cols["fill_in"][chunks[0]])
    snap = store.snapshot(int(chunks[0]))
    h = np.concatenate([p.is_hit for p in parts])
    s = np.concatenate([p.slot for p in parts])
    pay = np.concatenate([p.pay_offs for p in parts])
    bo = (None if hdr.mode == stream_mod.MODE_STD
          else np.concatenate([p.base_offs for p in parts]))

    # Carried dictionary entries enter as virtual misses in front of the
    # window: slot k's live payload lives at snapshot offset k.  After this,
    # hit-source resolution is identical to the full decoder's.
    h_ext = np.concatenate([np.zeros(fill0, bool), h])
    s_ext = np.concatenate([np.arange(fill0, dtype=np.int32), s])
    src = decode_mod.decode_sources(h_ext, s_ext)
    return _Window(hdr, gb0, fill0, np.concatenate([snap, pay]), src, h, bo)


def plan_windows(store: Container, requests: Sequence[Tuple[int, int, int]],
                 parse: ParseFn = parse_chunk
                 ) -> Tuple[StreamHeader, List[_Window]]:
    """The *plan* stage of a batched range decode: seek + walk only.

    For many ``(channel, start, stop)`` requests, locate each request's
    covering chunks via the footer index and walk their decision bytes
    into ``_Window``\\ s (hit sources resolved, snapshot entries prepended
    as virtual misses).  No value bytes are touched yet -- that is
    :func:`gather_parts`, the stage a pipelined server may run later
    (``repro.serve.pipeline``).  Requests whose windows share a chunk walk
    it once (per-call memo; the serving layer's LRU composes on top).
    Heterogeneous codec parameters across requests raise: split such
    requests into separate calls (the serving layer groups by parameter
    key before calling)."""
    memo: Dict[int, ParsedChunk] = {}

    def parse_once(st, k):
        if k not in memo:
            memo[k] = parse(st, k)
        return memo[k]

    windows = []
    for channel, start, stop in requests:
        chunks, gb0 = _covering_chunks(store, channel, start, stop)
        windows.append(_parse_window(store, chunks, gb0, parse_once))

    hdr = windows[0].header
    for w in windows[1:]:
        if ((w.header.mode, w.header.block_size, np.dtype(w.header.dtype),
             w.header.value_range)
                != (hdr.mode, hdr.block_size, np.dtype(hdr.dtype),
                    hdr.value_range)):
            raise ValueError(
                "batched ranges must share mode/block_size/dtype/value_range"
                "; split heterogeneous requests into separate decode_ranges "
                "calls")
    return hdr, windows


def gather_parts(store: Container, hdr: StreamHeader,
                 windows: Sequence[_Window],
                 requests: Sequence[Tuple[int, int, int]]) -> List[PlanPart]:
    """The *gather* stage: one shared fancy-index pass over the raw
    container bytes resolving every planned window's in-range payload
    (and base) offsets into source-resolved ``PlanPart``\\ s."""
    dt = np.dtype(hdr.dtype)
    std = hdr.mode == stream_mod.MODE_STD
    P = hdr.block_size if std else hdr.block_size - 1
    u8 = np.frombuffer(store.data, dtype=np.uint8)

    # one shared gather: every request's in-range payload offsets (and
    # bases), concatenated, hit the raw bytes in a single fancy-index pass
    po_parts, bo_parts = [], []
    for w, (channel, start, stop) in zip(windows, requests):
        lo = start - w.gb0
        sl = slice(lo + w.n_vir, stop - w.gb0 + w.n_vir)
        po_parts.append(w.src_pay_offs[w.src[sl]])
        if not std:
            bo_parts.append(w.base_offs[lo:stop - w.gb0])
    rows_flat = decode_mod.gather_rows(u8, dt, np.concatenate(po_parts), P)
    bases_flat = (None if std else decode_mod.gather_rows(
        u8, dt, np.concatenate(bo_parts), 1).ravel())
    _M_GATHER_BYTES.inc(rows_flat.nbytes
                        + (0 if bases_flat is None else bases_flat.nbytes))

    parts, pos = [], 0
    for w, (channel, start, stop) in zip(windows, requests):
        n = stop - start
        parts.append(PlanPart(
            rows=rows_flat[pos:pos + n],
            bases=None if std else bases_flat[pos:pos + n],
            is_hit=w.is_hit[start - w.gb0:start - w.gb0 + n],
            block_idx=np.arange(start, stop, dtype=np.int64)))
        pos += n
    return parts


def plan_parts(store: Container, requests: Sequence[Tuple[int, int, int]],
               parse: ParseFn = parse_chunk
               ) -> Tuple[StreamHeader, List[PlanPart]]:
    """Seek + parse + gather for many ``(channel, start, stop)`` requests:
    :func:`plan_windows` followed by :func:`gather_parts`.  Returns the
    (shared) stream header and one source-resolved ``PlanPart`` per
    request."""
    hdr, windows = plan_windows(store, requests, parse=parse)
    return hdr, gather_parts(store, hdr, windows, requests)


def decode_range(store: Container, start_block: int, stop_block: int,
                 channel: int = 0, seed: int = 0,
                 parse: ParseFn = parse_chunk,
                 backend: str = "numpy") -> np.ndarray:
    """Decode blocks ``[start_block, stop_block)`` of one channel.

    Byte-identical to the same slice of a full ``decode_stream`` over the
    channel's reassembled stream (on EVERY backend); work is proportional
    to the requested range (only covering segments are walked -- see the
    ``segment_walk_count`` assertions in tests/test_store.py)."""
    return decode_ranges(store, [(channel, start_block, stop_block)],
                         seed=seed, parse=parse, backend=backend)[0]


def decode_ranges(store: Container, requests: Sequence[Tuple[int, int, int]],
                  seed: int = 0, parse: ParseFn = parse_chunk,
                  backend: str = "numpy") -> List[np.ndarray]:
    """Batched range decode: ``requests`` is ``[(channel, start, stop), ...]``.

    All requests share one payload gather and ONE reconstruct dispatch:
    ``plan_parts`` resolves each request to a ``PlanPart``,
    ``decode.pad_parts`` stacks them on a leading request axis padded to
    the longest request (exactly like the write side's ragged coalesced
    batches), and ``decode.reconstruct`` rebuilds everything on the chosen
    backend.  Returns one 1-D array per request, in request order."""
    if not len(requests):
        return []
    _M_RANGE_REQS.inc(len(requests))
    for _, start, stop in requests:
        _M_RANGE_BLOCKS.observe(stop - start)
    hdr, parts = plan_parts(store, requests, parse=parse)
    plan, nbm = decode_mod.pad_parts(
        hdr.mode, hdr.block_size, hdr.dtype, hdr.value_range, parts,
        seed=seed, no_perm=bool(getattr(hdr, "error_bounded", False)))
    out = decode_mod.reconstruct(plan, backend=backend).reshape(
        len(parts), nbm, hdr.block_size)
    return [out[r, :len(p.is_hit)].ravel() for r, p in enumerate(parts)]


def decode_channels(store: Container, channels: Optional[Sequence[int]] = None,
                    seed: int = 0, parse: ParseFn = parse_chunk,
                    backend: str = "numpy") -> Dict[int, np.ndarray]:
    """Full decode of the selected channels (default: all), tails included,
    through one batched ``decode_ranges`` call.  Equals ``decode_stream``
    over each channel's reassembled stream."""
    if channels is None:
        channels = store.channels
    requests, blank = [], {}
    for c in channels:
        nb = store.total_blocks(c)
        if nb:
            requests.append((c, 0, nb))
        else:
            blank[c] = np.zeros(0, dtype=store.header_of(
                int(store.chunks_of(c)[0])).dtype)
    bodies = decode_ranges(store, requests, seed=seed, parse=parse,
                           backend=backend)
    out = dict(blank)
    for (c, _, _), body in zip(requests, bodies):
        out[c] = body
    return {c: np.concatenate([out[c], store.tail(c)]) for c in channels}
