"""Training step: microbatched grad accumulation + AdamW (+ optional IDEALEM
gradient compression with error feedback).

Microbatching bounds the activation working set (remat checkpoints scale with
the microbatch, not the global batch) -- the knob that makes 32k-token
sequences fit HBM.  The accumulation loop is a ``lax.scan`` so HLO stays
O(1) in the number of microbatches.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.lm import init_params, lm_loss
from repro.optim import adamw, gradcomp


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    gradcomp: Optional[gradcomp.GradCompState]


def init_train_state(key, cfg: ModelConfig, use_gradcomp: bool = False) -> TrainState:
    params = init_params(key, cfg)
    gc = gradcomp.init(params) if use_gradcomp else None
    return TrainState(params, adamw.init(params), gc)


def make_train_step(cfg: ModelConfig, *, lr=3e-4, microbatches: int = 1,
                    weight_decay: float = 0.1, clip_norm: float = 1.0,
                    use_gradcomp: bool = False,
                    gradcomp_kw: Optional[dict] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch dict leaves have leading dim B_global, divisible by `microbatches`.
    """

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg)

    def train_step(state: TrainState, batch):
        def split_mb(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mbs = jax.tree.map(split_mb, batch)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def acc(carry, mb):
            loss_sum, grads = carry
            loss, g = jax.value_and_grad(loss_fn)(state.params, mb)
            grads = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), grads, g)
            return (loss_sum + loss, grads), None

        (loss_sum, grads), _ = jax.lax.scan(
            acc, (jnp.zeros(()), zero_grads), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv

        metrics = {"loss": loss}
        gc_state = state.gradcomp
        if use_gradcomp:
            grads, gc_state, gc_metrics = gradcomp.compress(
                grads, gc_state, **(gradcomp_kw or {}))
            metrics.update(gc_metrics)

        params, opt, opt_metrics = adamw.update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=weight_decay, clip_norm=clip_norm)
        metrics.update(opt_metrics)
        return TrainState(params, opt, gc_state), metrics

    return train_step
