"""Synthetic data generators.

Telemetry generators mimic the paper's evaluation data (Sec. VII): uPMU
magnitude channels (locally stationary noise around a level, with occasional
level shifts and brief tap-change steps) and phase-angle channels (constantly
increasing ramp wrapping in [0, 360)).  EEG-like 1/f noise matches the
spectral-analysis data set (Fig. 13).  Token streams feed the LM examples.
"""
from __future__ import annotations

import numpy as np

__all__ = ["pmu_magnitude", "pmu_angle", "eeg_like", "token_stream"]


def pmu_magnitude(n: int, *, level: float = 7200.0, noise: float = 1.5,
                  n_shifts: int = 4, n_taps: int = 6, tap_step: float = 45.0,
                  tap_len: int = 20, seed: int = 0) -> np.ndarray:
    """Voltage/current magnitude: noise + level shifts + brief tap changes."""
    rng = np.random.default_rng(seed)
    x = level + rng.normal(0, noise, n)
    for s in rng.integers(0, max(n - 1, 1), n_shifts):
        x[s:] += rng.normal(0, 4 * noise)
    for s in rng.integers(0, max(n - tap_len - 1, 1), n_taps):
        x[s:s + tap_len] += tap_step * rng.choice([-1.0, 1.0])
    return x


def pmu_angle(n: int, *, slope: float = 0.72, noise: float = 0.05,
              seed: int = 0) -> np.ndarray:
    """Phase angle: wrapping ramp in [0, 360) (paper Fig. 6)."""
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    return np.mod(t * slope + rng.normal(0, noise, n), 360.0)


def eeg_like(n: int, *, alpha: float = 1.0, seed: int = 0) -> np.ndarray:
    """1/f^alpha pink-ish noise via spectral shaping (Fig. 13 data set)."""
    rng = np.random.default_rng(seed)
    f = np.fft.rfftfreq(n)
    f[0] = f[1] if n > 1 else 1.0
    spec = (rng.normal(size=len(f)) + 1j * rng.normal(size=len(f)))
    spec /= f ** (alpha / 2.0)
    x = np.fft.irfft(spec, n)
    return (x / np.std(x)).astype(np.float64)


def token_stream(n_batches: int, batch: int, seq: int, vocab: int,
                 seed: int = 0):
    """Zipf-distributed token batches with next-token labels."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
        toks = np.clip(toks, 0, vocab - 1).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
