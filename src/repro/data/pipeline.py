"""Host data pipeline: prefetching loader with device placement and an
IDEALEM-compressed telemetry ingestion path.

At cluster scale every host feeds its local devices; here the loader shards a
global batch across the mesh's batch axes with
``jax.make_array_from_process_local_data`` (single-process: a device_put with
the right NamedSharding).  A background thread keeps `prefetch` batches in
flight so step time hides host latency (straggler smoothing, DESIGN.md 4).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import IdealemCodec


class Prefetcher:
    def __init__(self, it: Iterator, prefetch: int = 2,
                 place: Optional[Callable] = None):
        self._it = it
        self._place = place or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(self._place(item))
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def place_on_mesh(mesh, batch_axes=("data",)):
    """Returns a placement fn sharding dict-of-arrays batches on batch axes."""
    spec = P(batch_axes)

    def place(batch):
        return {
            k: jax.device_put(v, NamedSharding(mesh, spec))
            for k, v in batch.items()
        }

    return place


def compressed_telemetry_reader(blobs, codec: IdealemCodec) -> Iterator[np.ndarray]:
    """Inverse of the ingestion path: decode IDEALEM-compressed channels."""
    for blob in blobs:
        yield codec.decode(blob)


def compress_channels(channels: np.ndarray, codec: IdealemCodec):
    """Compress (C, N) telemetry; returns (blobs, mean compression ratio)."""
    blobs = [codec.encode(ch) for ch in channels]
    ratio = float(np.mean([channels[i].nbytes / len(b)
                           for i, b in enumerate(blobs)]))
    return blobs, ratio
