from . import synthetic
from .pipeline import Prefetcher, compress_channels, place_on_mesh

__all__ = ["synthetic", "Prefetcher", "compress_channels", "place_on_mesh"]
