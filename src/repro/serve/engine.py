"""Serving: prefill + batched decode with per-kind caches.

``serve_step`` is the unit the multi-pod dry-run lowers for decode shapes:
one new token against a KV/state cache of the configured context length.
``ServeEngine`` is the host loop: batch requests, prefill, decode until done
(static batch; slots refill between generations).

``FlushPolicy`` is the serving layer's shared micro-batching knob: request
coalescers (the compression ingest path in ``repro.serve.compress``, and
eventually continuous-batching LM decode) accumulate per-client payloads
and cut one padded device batch when the policy trips.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models import lm
from repro.models.lm import DecodeCache, decode_step, init_cache


@dataclass(frozen=True)
class FlushPolicy:
    """When a coalescer should stop accumulating and cut a device batch.

    ``max_batch_blocks`` bounds the padded scan length (device latency and
    the compile-shape bucket); ``max_batch_streams`` bounds how many
    clients wait on one dispatch (tail latency); ``max_age_s`` is the
    latency-SLO deadline -- a batch flushes once its oldest staged payload
    has waited this long, however little has accumulated.  Any threshold
    trips a flush; callers may always flush earlier (shutdown).

    ``pipeline_depth`` bounds how many flushed batches a *pipelined*
    coalescer (``repro.serve.pipeline``) may hold in flight: 1 is the
    alternating plan-then-reconstruct path (a flush returns its own
    batch's answers); 2 is double-buffering (host planning of batch N+1
    overlaps device reconstruction of batch N, and a flush returns the
    PREVIOUS batch's answers -- ``drain()`` collects the rest).

    The policy is pure: coalescers measure the age with their own
    (injectable) clock and pass it in, so deadline behaviour is unit
    testable without real sleeps.
    """

    max_batch_blocks: int = 4096
    max_batch_streams: int = 256
    max_age_s: Optional[float] = None
    pipeline_depth: int = 1

    def __post_init__(self):
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")

    def with_updates(self, **changes) -> "FlushPolicy":
        """A copy with the given knobs replaced -- the control loop's
        (``repro.serve.control``) actuation helper; the policy itself
        stays frozen/hashable."""
        import dataclasses
        return dataclasses.replace(self, **changes)

    def as_dict(self) -> dict:
        """JSON-ready knob dump (the front end's ``GET /v1/control``)."""
        return {"max_batch_blocks": self.max_batch_blocks,
                "max_batch_streams": self.max_batch_streams,
                "max_age_s": self.max_age_s,
                "pipeline_depth": self.pipeline_depth}

    def should_flush(self, n_streams: int, n_blocks: int,
                     age_s: Optional[float] = None) -> bool:
        if (self.max_age_s is not None and age_s is not None
                and age_s >= self.max_age_s and (n_streams or n_blocks)):
            return True
        return (n_streams >= self.max_batch_streams
                or n_blocks >= self.max_batch_blocks)


def serve_step(params, cache: DecodeCache, tokens, cfg: ModelConfig):
    """One decode step: tokens (B,1) -> (logits (B,1,V), new cache)."""
    return decode_step(params, cache, tokens, cfg)


def prefill_step(params, tokens, cfg: ModelConfig, memory=None):
    """Full-prompt forward -> logits for the last position.

    This is what the `prefill_*` dry-run shapes lower: the quadratic/chunked
    attention pass at the full context length (no backward).
    """
    x, _ = lm.forward_hidden(params, tokens, cfg, memory)
    from repro.models.layers import unembed
    return unembed(params["embed"], x[:, -1:, :], cfg)


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    max_seq: int = 2048
    memory_len: int = 0
    temperature: float = 0.0
    _decode: Optional[Callable] = None

    def __post_init__(self):
        self._decode = jax.jit(
            functools.partial(decode_step, cfg=self.cfg))

    def generate(self, prompts: np.ndarray, num_tokens: int,
                 seed: int = 0) -> np.ndarray:
        """prompts (B, P) int32 -> (B, num_tokens) greedy/sampled tokens.

        Prefill is run through the decode path token-by-token for cache
        consistency on heterogeneous stacks (attn/ssm/rwkv mixes); production
        prefill for pure-attention stacks can use `prefill_step` + cache
        scatter instead.
        """
        B, P = prompts.shape
        cache = init_cache(self.cfg, B, self.max_seq, self.memory_len)
        logits = None
        for t in range(P):
            logits, cache = self._decode(self.params, cache, prompts[:, t:t + 1])
        out = []
        key = jax.random.key(seed)
        tok = None
        for t in range(num_tokens):
            if self.temperature > 0:
                key, sk = jax.random.split(key)
                tok = jax.random.categorical(
                    sk, logits[:, -1] / self.temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok.astype(jnp.int32))
        return np.concatenate(out, axis=1)
