"""Closed control loop: steer ``FlushPolicy`` from live telemetry.

PR 5 made ``pipeline_depth``/``max_batch_blocks``/``max_age_s`` policy
knobs; PR 8 exported the per-stage flush latencies
(``repro_serve_stage_seconds{stage=plan|gather|reconstruct|emit}``).
This module closes the loop (ISSUE 10): every :meth:`ControlLoop.tick`
reads the *interval* latency distribution (bucket-count deltas since the
previous tick -- cumulative histograms never forget, the controller must),
estimates stage quantiles (``repro.obs.histogram_quantile``, the same
math the SLO gate uses), and moves the knobs:

* **latency**: when the summed per-stage p99 exceeds ``target_p99_s``,
  halve ``max_batch_blocks`` and ``max_age_s`` (smaller batches, earlier
  deadlines); when it sits below ``low_watermark * target``, double them
  back up (amortization) -- both clamped to configured bounds.
* **overlap**: when the device stage (reconstruct) p50 dominates the
  summed host stages p50 by ``depth_on_ratio``, raise ``pipeline_depth``
  to 2 (host planning of batch N+1 overlaps device reconstruct of N,
  DESIGN.md Sec. 9); otherwise drop back to 1 (the overlap thread is pure
  overhead when the host dominates).
* **drift**: the first healthy tick pins a reconstruct-p50 baseline; when
  the live p50 drifts beyond ``drift_factor`` of it, the measured
  autotune choices are stale (thermal change, contending tenant, new
  hardware) -- ``on_reprobe`` fires (default:
  ``repro.core.decode.reset_autotune``) and the baseline re-pins.

The loop is a plain synchronous object with an injectable registry, so
unit tests drive it from synthetic histograms; the front end
(``repro.serve.frontend``) ticks it on its timer and broadcasts the new
policy to every tenant's coalescers and decode services.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

from repro import obs

from .engine import FlushPolicy

__all__ = ["ControlConfig", "ControlDecision", "ControlLoop", "STAGES"]

STAGES = ("plan", "gather", "reconstruct", "emit")

_M_TICKS = obs.registry().counter(
    "repro_control_ticks_total", "control loop evaluations")
_M_ADJUST = {
    knob: obs.registry().counter(
        "repro_control_adjustments_total",
        "policy knob movements by the control loop",
        labels={"knob": knob})
    for knob in ("max_batch_blocks", "max_age_s", "pipeline_depth")
}
_M_REPROBE = obs.registry().counter(
    "repro_control_reprobes_total",
    "autotune re-probes triggered by latency drift")
_M_P99 = obs.registry().gauge(
    "repro_control_p99_seconds",
    "summed per-stage p99 at the last control tick")
_M_KNOB = {
    knob: obs.registry().gauge(
        f"repro_control_{knob}", f"current FlushPolicy {knob}")
    for knob in ("max_batch_blocks", "pipeline_depth")
}


@dataclass(frozen=True)
class ControlConfig:
    """Setpoints and actuator bounds of the loop."""

    target_p99_s: float = 0.050      # summed stage p99 budget per flush
    low_watermark: float = 0.25      # p99 below target*this => batch up
    min_batch_blocks: int = 256
    max_batch_blocks: int = 1 << 16
    min_age_s: float = 0.002
    max_age_s: float = 0.500
    depth_on_ratio: float = 1.2      # reconstruct p50 / host-stages p50
    drift_factor: float = 2.0        # reconstruct p50 vs pinned baseline
    min_observations: int = 8        # interval flushes needed to act


@dataclass(frozen=True)
class ControlDecision:
    """One tick's outcome: the (possibly new) policy and why."""

    policy: FlushPolicy
    changed: bool
    reprobed: bool
    reasons: Tuple[str, ...]
    p99_s: Optional[float]                   # summed stage p99, or None
    stage_p99_s: Dict[str, Optional[float]] = field(default_factory=dict)


class ControlLoop:
    """See the module docstring.  One instance per policy domain (the
    front end runs one and broadcasts); ``tick()`` is cheap enough for a
    sub-second timer."""

    def __init__(self, policy: Optional[FlushPolicy] = None,
                 config: Optional[ControlConfig] = None,
                 registry: Optional[obs.MetricsRegistry] = None,
                 on_reprobe: Optional[Callable[[], None]] = None):
        self.config = config or ControlConfig()
        self.policy = policy if policy is not None else FlushPolicy(
            max_age_s=self.config.max_age_s / 10)
        self._reg = registry if registry is not None else obs.registry()
        self._on_reprobe = (on_reprobe if on_reprobe is not None
                            else _default_reprobe)
        self._prev_counts: Dict[str, Tuple[int, ...]] = {}
        self._baseline_p50: Optional[float] = None
        self.ticks = 0
        self.decisions: list = []  # ControlDecision ring (bounded)

    # ------------------------------------------------------------- sampling
    def _stage_child(self, stage: str):
        for fam in self._reg.families():
            if fam.name == "repro_serve_stage_seconds" \
                    and fam.kind == "histogram":
                return fam.children.get((("stage", stage),))
        return None

    def _interval_counts(self, stage: str):
        """Per-bucket observation deltas since the previous tick (the
        controller steers on recent traffic, not the process lifetime)."""
        child = self._stage_child(stage)
        if child is None:
            return None, None
        counts = child.bucket_counts()
        prev = self._prev_counts.get(stage)
        self._prev_counts[stage] = counts
        if prev is None or len(prev) != len(counts):
            delta = counts  # first sight: the whole history is "recent"
        else:
            delta = tuple(c - p for c, p in zip(counts, prev))
        return child.bounds, delta

    # ----------------------------------------------------------------- tick
    def tick(self) -> ControlDecision:
        _M_TICKS.inc()
        self.ticks += 1
        cfg = self.config
        bounds_counts = {s: self._interval_counts(s) for s in STAGES}
        p99 = {}
        p50 = {}
        n_obs = {}
        for s, (bounds, delta) in bounds_counts.items():
            if bounds is None:
                p99[s] = p50[s] = None
                n_obs[s] = 0
                continue
            n_obs[s] = sum(delta)
            p99[s] = obs.histogram_quantile(bounds, delta, 0.99)
            p50[s] = obs.histogram_quantile(bounds, delta, 0.50)

        reasons = []
        reprobed = False
        pol = self.policy
        flushes = n_obs["reconstruct"]
        if flushes >= cfg.min_observations:
            total_p99 = sum(v for v in p99.values() if v is not None)
            _M_P99.set(total_p99)
            # -- latency vs target ------------------------------------------
            if total_p99 > cfg.target_p99_s:
                nb = max(cfg.min_batch_blocks, pol.max_batch_blocks // 2)
                if nb != pol.max_batch_blocks:
                    pol = pol.with_updates(max_batch_blocks=nb)
                    _M_ADJUST["max_batch_blocks"].inc()
                    reasons.append(
                        f"p99 {total_p99:.4f}s > target "
                        f"{cfg.target_p99_s:.4f}s: max_batch_blocks -> {nb}")
                if pol.max_age_s is not None:
                    age = max(cfg.min_age_s, pol.max_age_s / 2)
                    if age != pol.max_age_s:
                        pol = pol.with_updates(max_age_s=age)
                        _M_ADJUST["max_age_s"].inc()
                        reasons.append(f"max_age_s -> {age:.4f}")
            elif total_p99 < cfg.low_watermark * cfg.target_p99_s:
                nb = min(cfg.max_batch_blocks, pol.max_batch_blocks * 2)
                if nb != pol.max_batch_blocks:
                    pol = pol.with_updates(max_batch_blocks=nb)
                    _M_ADJUST["max_batch_blocks"].inc()
                    reasons.append(
                        f"p99 {total_p99:.4f}s under watermark: "
                        f"max_batch_blocks -> {nb}")
                if pol.max_age_s is not None:
                    age = min(cfg.max_age_s, pol.max_age_s * 2)
                    if age != pol.max_age_s:
                        pol = pol.with_updates(max_age_s=age)
                        _M_ADJUST["max_age_s"].inc()
                        reasons.append(f"max_age_s -> {age:.4f}")
            # -- pipeline depth from stage balance --------------------------
            host = [p50[s] for s in ("plan", "gather", "emit")]
            dev = p50["reconstruct"]
            if dev is not None and all(h is not None for h in host):
                host_sum = sum(host)
                want = 2 if dev > cfg.depth_on_ratio * host_sum else 1
                if want != pol.pipeline_depth:
                    pol = pol.with_updates(pipeline_depth=want)
                    _M_ADJUST["pipeline_depth"].inc()
                    reasons.append(
                        f"reconstruct p50 {dev:.4f}s vs host "
                        f"{host_sum:.4f}s: pipeline_depth -> {want}")
            # -- drift => autotune re-probe ---------------------------------
            # the baseline tracks the BEST reconstruct p50 seen since the
            # last re-probe ("what this pipeline can do"); drifting a
            # factor above it means the measured autotune choices went
            # stale, not that one tick was busy
            if dev is not None:
                if self._baseline_p50 is None:
                    self._baseline_p50 = dev
                elif dev > cfg.drift_factor * self._baseline_p50:
                    reprobed = True
                    _M_REPROBE.inc()
                    reasons.append(
                        f"reconstruct p50 drifted {dev:.4f}s vs baseline "
                        f"{self._baseline_p50:.4f}s: autotune re-probe")
                    self._baseline_p50 = dev
                    self._on_reprobe()
                else:
                    self._baseline_p50 = min(self._baseline_p50, dev)
            total = total_p99
        else:
            total = None

        changed = pol is not self.policy
        self.policy = pol
        _M_KNOB["max_batch_blocks"].set(pol.max_batch_blocks)
        _M_KNOB["pipeline_depth"].set(pol.pipeline_depth)
        decision = ControlDecision(policy=pol, changed=changed,
                                   reprobed=reprobed,
                                   reasons=tuple(reasons), p99_s=total,
                                   stage_p99_s=p99)
        self.decisions.append(decision)
        del self.decisions[:-64]
        return decision

    def status(self) -> dict:
        """JSON-ready controller state (``GET /v1/control``)."""
        last = self.decisions[-1] if self.decisions else None
        return {
            "ticks": self.ticks,
            "policy": self.policy.as_dict(),
            "target_p99_s": self.config.target_p99_s,
            "last_p99_s": None if last is None else last.p99_s,
            "last_reasons": [] if last is None else list(last.reasons),
            "baseline_reconstruct_p50_s": self._baseline_p50,
        }


def _default_reprobe() -> None:
    """Forget the measured decode-backend choices so the next dispatches
    re-time numpy/jax/pallas under the drifted conditions."""
    from repro.core import decode as decode_mod
    decode_mod.reset_autotune()
