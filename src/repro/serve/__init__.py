from .engine import ServeEngine, prefill_step, serve_step
from .compress import CompressionService

__all__ = ["ServeEngine", "prefill_step", "serve_step", "CompressionService"]
