from .engine import FlushPolicy, ServeEngine, prefill_step, serve_step
from .compress import (CompressionService, DecompressionService,
                       StreamCoalescer)
from .pipeline import (StageFuture, StagePipeline, SyncExecutor,
                       ThreadStageExecutor)

__all__ = ["FlushPolicy", "ServeEngine", "prefill_step", "serve_step",
           "CompressionService", "DecompressionService", "StreamCoalescer",
           "StageFuture", "StagePipeline", "SyncExecutor",
           "ThreadStageExecutor"]
