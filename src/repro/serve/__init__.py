from .engine import FlushPolicy, ServeEngine, prefill_step, serve_step
from .compress import (CompressionService, DecompressionService,
                       StreamCoalescer)
from .pipeline import (StageFuture, StagePipeline, SyncExecutor,
                       ThreadStageExecutor)
from .tenancy import (Tenant, TenantQuota, TenantRegistry, TenantStream,
                      TokenBucket)
from .control import ControlConfig, ControlDecision, ControlLoop
from .frontend import FrontendClient, ServeFrontend

__all__ = ["FlushPolicy", "ServeEngine", "prefill_step", "serve_step",
           "CompressionService", "DecompressionService", "StreamCoalescer",
           "StageFuture", "StagePipeline", "SyncExecutor",
           "ThreadStageExecutor",
           "Tenant", "TenantQuota", "TenantRegistry", "TenantStream",
           "TokenBucket",
           "ControlConfig", "ControlDecision", "ControlLoop",
           "FrontendClient", "ServeFrontend"]
