from .engine import FlushPolicy, ServeEngine, prefill_step, serve_step
from .compress import (CompressionService, DecompressionService,
                       StreamCoalescer)

__all__ = ["FlushPolicy", "ServeEngine", "prefill_step", "serve_step",
           "CompressionService", "DecompressionService", "StreamCoalescer"]
