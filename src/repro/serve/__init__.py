from .engine import FlushPolicy, ServeEngine, prefill_step, serve_step
from .compress import CompressionService, StreamCoalescer

__all__ = ["FlushPolicy", "ServeEngine", "prefill_step", "serve_step",
           "CompressionService", "StreamCoalescer"]
