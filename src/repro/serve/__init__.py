from .engine import ServeEngine, prefill_step, serve_step

__all__ = ["ServeEngine", "prefill_step", "serve_step"]
