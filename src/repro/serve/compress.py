"""Compression-as-a-service: the IDEALEM path of the serving layer.

``ServeEngine`` serves LM decode traffic; ``CompressionService`` is the
sibling endpoint for telemetry ingest (DESIGN.md Sec. 5): many concurrent
client streams, each an ``IdealemSession`` whose FIFO dictionary survives
between requests, so hit rates match offline one-shot compression no matter
how the stream is chunked over the wire.

Request lifecycle:

  svc = CompressionService(mode="std", block_size=32, num_dict=255)
  svc.open_stream("pmu-7")            # or channels=C for batched sensors
  seg = svc.feed("pmu-7", chunk)      # append-mode segment bytes (may be b"")
  seg = svc.close_stream("pmu-7")     # final segment (tail samples)

Concatenating every returned segment yields a stream that
``repro.core.stream.decode_stream`` decodes identically to one-shot
``IdealemCodec.encode`` over the full signal.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import numpy as np

from repro.core import IdealemCodec
from repro.core.session import IdealemSession, SessionStats

__all__ = ["CompressionService"]


class CompressionService:
    """Multi-stream host endpoint over persistent ``IdealemSession`` state."""

    def __init__(self, **codec_defaults):
        self._defaults = codec_defaults
        self._streams: Dict[str, IdealemSession] = {}
        self._closed: Dict[str, Union[SessionStats, List[SessionStats]]] = {}

    @property
    def active_streams(self) -> List[str]:
        return sorted(self._streams)

    def open_stream(self, stream_id: str, channels: Optional[int] = None,
                    dtype=np.float64, **codec_overrides) -> None:
        """Register a stream; codec kwargs override the service defaults."""
        if stream_id in self._streams:
            raise KeyError(f"stream {stream_id!r} already open")
        codec = IdealemCodec(**{**self._defaults, **codec_overrides})
        self._streams[stream_id] = codec.session(channels=channels,
                                                 dtype=dtype)
        self._closed.pop(stream_id, None)

    def feed(self, stream_id: str, chunk) -> Union[bytes, List[bytes]]:
        """Compress the next chunk of an open stream; returns segment bytes
        (one per channel for batched streams)."""
        return self._session(stream_id).feed(chunk)

    def close_stream(self, stream_id: str) -> Union[bytes, List[bytes]]:
        """Finalize a stream: emits the tail-carrying final segment and
        retires the session (stats remain queryable)."""
        sess = self._session(stream_id)
        seg = sess.finish()
        self._closed[stream_id] = sess.stats
        del self._streams[stream_id]
        return seg

    def stats(self, stream_id: Optional[str] = None) -> dict:
        """Per-stream stats dict, or the aggregate over all streams."""
        if stream_id is not None:
            st = (self._streams[stream_id].stats
                  if stream_id in self._streams else self._closed[stream_id])
            return self._stats_dict(st)
        agg = SessionStats()
        for st in list(self._closed.values()) + [
                s.stats for s in self._streams.values()]:
            for one in (st if isinstance(st, list) else [st]):
                agg.blocks += one.blocks
                agg.hits += one.hits
                agg.segments += one.segments
                agg.bytes_in += one.bytes_in
                agg.bytes_out += one.bytes_out
        return agg.as_dict()

    # ------------------------------------------------------------- internals
    def _session(self, stream_id: str) -> IdealemSession:
        try:
            return self._streams[stream_id]
        except KeyError:
            raise KeyError(f"stream {stream_id!r} is not open") from None

    @staticmethod
    def _stats_dict(st):
        if isinstance(st, list):
            return {"channels": [one.as_dict() for one in st]}
        return st.as_dict()
