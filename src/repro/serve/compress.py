"""Compression-as-a-service: the IDEALEM path of the serving layer.

``ServeEngine`` serves LM decode traffic; ``CompressionService`` is the
sibling endpoint for telemetry ingest (DESIGN.md Sec. 5): many concurrent
client streams, each an ``IdealemSession`` whose FIFO dictionary survives
between requests, so hit rates match offline one-shot compression no matter
how the stream is chunked over the wire.

Request lifecycle:

  svc = CompressionService(mode="std", block_size=32, num_dict=255)
  svc.open_stream("pmu-7")            # or channels=C for batched sensors
  seg = svc.feed("pmu-7", chunk)      # append-mode segment bytes (may be b"")
  seg = svc.close_stream("pmu-7")     # final segment (tail samples)

Concatenating every returned segment yields a stream that
``repro.core.stream.decode_stream`` decodes identically to one-shot
``IdealemCodec.encode`` over the full signal.

``CompressionService`` dispatches one device scan per feed per stream --
right for few fat streams.  ``StreamCoalescer`` (DESIGN.md Sec. 6) is the
heavy-traffic endpoint: it accumulates ``submit()`` payloads from many
live streams and, when its ``FlushPolicy`` trips, cuts ONE padded device
batch (streams stacked on the channel axis, ragged block counts masked),
then scatters the encoded segments back per stream.  Per-stream bytes are
identical to what the per-stream service would emit; an ``EncodePlan``
shards the batch's channel axis across devices.

``DecompressionService`` (DESIGN.md Sec. 7) is the symmetric READ path:
range requests against packed containers (``repro.store``), answered from
an LRU of parsed segments, with concurrent requests coalesced into one
padded batched reconstruct per flush -- the same ``FlushPolicy`` (count,
block and ``max_age_s`` deadline triggers) on both sides of the codec.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import (Callable, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro import obs
from repro.core import IdealemCodec
from repro.core.session import (IdealemSession, MixedCohort, SessionStats,
                                _mixed_matcher_name)

from .engine import FlushPolicy
from .pipeline import StagePipeline, SyncExecutor, ThreadStageExecutor

# ---------------------------------------------------------------- telemetry
# Serve-layer registry metrics (ISSUE 8, DESIGN.md Sec. 12).  Handles are
# module-level so hot paths never repeat the family lookup; values are
# process-wide aggregates across service instances (per-instance detail
# stays on each service's ``stats`` dict, which these mirror).
_M_STAGE_SECONDS = {
    stage: obs.registry().histogram(
        "repro_serve_stage_seconds",
        "pipelined decode stage latency per flush batch",
        labels={"stage": stage})
    for stage in ("plan", "gather", "reconstruct", "emit")
}
_M_SERVE = {
    key: obs.registry().counter(f"repro_serve_{key}_total", help_text)
    for key, help_text in {
        "requests": "range requests answered",
        "blocks_out": "blocks reconstructed and handed out",
        "flushes": "decode flush batches cut",
        "failed_requests": "requests quarantined into last_errors",
        "cache_hits": "parsed-segment LRU hits",
        "cache_misses": "parsed-segment LRU misses (chunk walked)",
        "dispatches": "reconstruct engine dispatches",
    }.items()
}
_M_INFLIGHT = obs.registry().gauge(
    "repro_serve_inflight",
    "reconstruct batches in flight (most recent pipeline activity)")
_M_FLUSH_AGE = obs.registry().histogram(
    "repro_serve_flush_age_seconds",
    "age of the oldest pending request when its batch was cut")
_M_ENC_FLUSHES = obs.registry().counter(
    "repro_encode_flushes_total", "coalescer device flush batches")
_M_ENC_FLUSH_SECONDS = obs.registry().histogram(
    "repro_encode_flush_seconds", "coalescer flush wall time")
_M_ENC_FLUSH_BLOCKS = obs.registry().histogram(
    "repro_encode_flush_blocks", "blocks encoded per coalescer flush",
    buckets=tuple(float(1 << p) for p in range(0, 17, 2)))
_M_STREAMS_OPEN = {
    kind: obs.registry().gauge(
        "repro_encode_streams_open", "open encode streams",
        labels={"kind": kind})
    for kind in ("session", "coalesced")
}


def _staged(stage: str, seq: int, **attrs):
    """Span + stage-latency histogram around one pipeline stage body.
    The injected ``trace(stage, seq)`` hook fires at stage *start* only,
    so it cannot time; this wrapper is where durations come from."""
    return _StagedTimer(stage, seq, attrs)


class _StagedTimer:
    __slots__ = ("stage", "seq", "attrs", "_span", "_t0")

    def __init__(self, stage, seq, attrs):
        self.stage, self.seq, self.attrs = stage, seq, attrs

    def __enter__(self):
        self._span = obs.span(f"serve.{self.stage}",
                              attrs={"seq": self.seq, **self.attrs})
        self._span.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dt = time.perf_counter() - self._t0
        if exc_type is None:
            _M_STAGE_SECONDS[self.stage].observe(dt)
        return self._span.__exit__(exc_type, exc, tb)

__all__ = ["CompressionService", "StreamCoalescer", "DecompressionService"]


class _PlannedStore(NamedTuple):
    """One store's share of a flush batch after the *plan* stage: its
    requests, their walked windows, and the shared codec-parameter key."""

    store_id: str
    pkey: tuple                    # (mode, block_size, dtype str, range, eb)
    requests: list                 # [(rid, channel, start, stop), ...]
    ranges: list                   # [(channel, start, stop), ...]
    header: object
    windows: list


class _Unit(NamedTuple):
    """One reconstruct dispatch after the *gather* stage: a padded plan
    plus how to slice each request back out at *emit*."""

    backend: str                   # resolved concrete backend
    block_size: int
    items: list                    # [(rid, n_blocks), ...] in plan order
    plan: object                   # decode.DecodePlan
    nbm: int                       # padded per-request block count


def _fold_stats(agg: SessionStats, st: SessionStats) -> None:
    agg.blocks += st.blocks
    agg.hits += st.hits
    agg.segments += st.segments
    agg.bytes_in += st.bytes_in
    agg.bytes_out += st.bytes_out


class CompressionService:
    """Multi-stream host endpoint over persistent ``IdealemSession`` state."""

    def __init__(self, **codec_defaults):
        self._defaults = codec_defaults
        self._streams: Dict[str, IdealemSession] = {}
        self._closed: Dict[str, Union[SessionStats, List[SessionStats]]] = {}
        # closed streams whose id was reopened: per-id stats are replaced,
        # but their traffic must stay in the service aggregate
        self._retired = SessionStats()

    @property
    def active_streams(self) -> List[str]:
        return sorted(self._streams)

    def open_stream(self, stream_id: str, channels: Optional[int] = None,
                    dtype=np.float64, container: bool = False,
                    **codec_overrides) -> None:
        """Register a stream; codec kwargs override the service defaults.

        ``container=True`` makes ``close_stream`` return the whole stream
        as one indexed random-access container (``repro.store``) instead of
        the final segment -- the encode->store->range-decode round trip."""
        if stream_id in self._streams:
            raise KeyError(f"stream {stream_id!r} already open")
        codec = IdealemCodec(**{**self._defaults, **codec_overrides})
        self._streams[stream_id] = codec.session(channels=channels,
                                                 dtype=dtype,
                                                 container=container)
        _M_STREAMS_OPEN["session"].inc()
        old = self._closed.pop(stream_id, None)
        if old is not None:
            for one in (old if isinstance(old, list) else [old]):
                _fold_stats(self._retired, one)

    def feed(self, stream_id: str, chunk) -> Union[bytes, List[bytes]]:
        """Compress the next chunk of an open stream; returns segment bytes
        (one per channel for batched streams)."""
        return self._session(stream_id).feed(chunk)

    def close_stream(self, stream_id: str) -> Union[bytes, List[bytes]]:
        """Finalize a stream: emits the tail-carrying final segment (or,
        for ``container=True`` streams, the packed container over every
        segment) and retires the session (stats remain queryable)."""
        sess = self._session(stream_id)
        seg = sess.finish()
        self._closed[stream_id] = sess.stats
        del self._streams[stream_id]
        _M_STREAMS_OPEN["session"].dec()
        return seg

    def handle(self, req) -> "object":
        """Serve one wire-typed :class:`repro.api.CompressRequest` -- the
        SAME object the network front end decodes off the wire -- and
        return its :class:`repro.api.FeedResult` with per-call stat
        deltas.  Single-channel streams only (the wire shape)."""
        from repro import api
        sess = self._session(req.stream_id)
        st = sess.stats
        if isinstance(st, list):
            from repro.errors import ApiError
            raise ApiError(
                "handle() serves single-channel streams; use feed() for "
                "batched multi-channel sessions")
        before = (st.blocks, st.hits, st.bytes_in, st.bytes_out)
        seg = sess.feed(np.asarray(req.samples))
        after = (st.blocks, st.hits, st.bytes_in, st.bytes_out)
        d = tuple(a - b for a, b in zip(after, before))
        return api.FeedResult(stream_id=req.stream_id, segment=seg,
                              blocks=d[0], hits=d[1], bytes_in=d[2],
                              bytes_out=d[3])

    def stats(self, stream_id: Optional[str] = None) -> dict:
        """Per-stream stats dict, or the aggregate over all streams."""
        if stream_id is not None:
            st = (self._streams[stream_id].stats
                  if stream_id in self._streams else self._closed[stream_id])
            return self._stats_dict(st)
        agg = SessionStats()
        _fold_stats(agg, self._retired)
        for st in list(self._closed.values()) + [
                s.stats for s in self._streams.values()]:
            for one in (st if isinstance(st, list) else [st]):
                _fold_stats(agg, one)
        return agg.as_dict()

    # ------------------------------------------------------------- internals
    def _session(self, stream_id: str) -> IdealemSession:
        try:
            return self._streams[stream_id]
        except KeyError:
            raise KeyError(f"stream {stream_id!r} is not open") from None

    @staticmethod
    def _stats_dict(st):
        if isinstance(st, list):
            return {"channels": [one.as_dict() for one in st]}
        return st.as_dict()


class StreamCoalescer:
    """Batch many live streams into one padded device encode per step.

    Every open stream owns a channel slot in one batched ``DictState``
    cohort (slots are reset on reuse, so a recycled slot behaves like a
    fresh dictionary).  ``submit`` only stages bytes host-side; the device
    is touched once per ``flush`` -- triggered by the ``FlushPolicy`` or
    called explicitly -- which cuts a single ``(capacity, nb, n)`` scan
    with ragged streams padded and masked, then scatters each stream's
    segment bytes back.

    One codec configuration per coalescer: heterogeneous configs cannot
    share a scan (route them to separate coalescers or the plain
    ``CompressionService``).  Adaptive codecs DO coalesce: each stream's
    selector may diverge its mode/threshold, and the flush routes the
    whole cohort through one masked mixed-mode scan (``MixedCohort``,
    DESIGN.md Sec. 13) instead of rejecting the config -- reference or
    fused matchers only.

    ``plan`` (``repro.launch.encode_plan.EncodePlan``) shards the slot
    axis over its mesh; capacity is then pinned to the plan's padded
    channel count.  Without a plan the slot table doubles on demand.
    ``block_bucket`` rounds the padded scan length up so recurring traffic
    reuses a handful of compiled shapes.
    """

    def __init__(self, policy: Optional[FlushPolicy] = None, plan=None,
                 capacity: int = 64, block_bucket: int = 32,
                 dtype=np.float64, clock: Optional[Callable[[], float]] = None,
                 **codec_kwargs):
        self._codec = IdealemCodec(**codec_kwargs)
        if self._codec.backend == "numpy":
            raise ValueError("StreamCoalescer batches on device; use "
                             "CompressionService for the numpy backend")
        self._adaptive = bool(getattr(self._codec, "adaptive", False))
        if self._adaptive and _mixed_matcher_name(self._codec) is None:
            raise ValueError(
                "adaptive coalescing needs the reference or fused matcher "
                "(the batched mixed scan has no masked variant of "
                f"{self._codec.matcher!r})")
        if plan is not None and plan.channels != plan.padded_channels:
            raise ValueError("coalescer plans must be made for a padded "
                             "channel count (channels % devices == 0)")
        if (self._adaptive and plan is not None
                and getattr(plan, "dict_shards", 1) > 1):
            raise ValueError("adaptive coalescing shards the slot axis "
                             "only; build the plan with dict_shards=1")
        self.policy = policy or FlushPolicy()
        self.plan = plan
        self._capacity = plan.padded_channels if plan is not None else capacity
        self._bucket = max(1, block_bucket)
        self._dtype = np.dtype(dtype)
        self._sessions: Dict[str, IdealemSession] = {}
        self._slots: Dict[str, int] = {}
        self._free = list(range(self._capacity))[::-1]  # pop() -> lowest
        self._pending: Dict[str, List[np.ndarray]] = {}
        # per-stream staged samples (carried tail + pending chunks) plus
        # aggregate flush-pressure counters, kept incrementally so submit()
        # stays O(1) no matter how many streams are open
        self._buffered: Dict[str, int] = {}
        self._ready_streams = 0
        self._ready_blocks = 0
        self._state = None  # batched DictState over capacity slots (static)
        self._mixed = None  # MixedCohort over capacity slots (adaptive)
        self._closed: Dict[str, SessionStats] = {}
        self._retired = SessionStats()  # closed ids later reopened
        # deadline trigger (FlushPolicy.max_age_s): per-stream timestamp of
        # the oldest staged payload, so partial flushes (close_stream) don't
        # leave survivors aged by a departed stream's older submissions; the
        # clock is injectable for deterministic tests
        self._clock = clock if clock is not None else time.monotonic
        self._staged_ts: Dict[str, float] = {}

    @property
    def active_streams(self) -> List[str]:
        return sorted(self._sessions)

    @property
    def capacity(self) -> int:
        return self._capacity

    @property
    def block_size(self) -> int:
        return self._codec.block_size

    @property
    def pending_blocks(self) -> int:
        """Whole blocks staged host-side awaiting a flush, summed over
        open streams -- the tenancy layer's admission pressure signal
        (``repro.serve.tenancy``)."""
        return self._ready_blocks

    def staged_samples(self, stream_id: str) -> int:
        """Samples staged for one stream (tail included), host-side."""
        if stream_id not in self._sessions:
            raise KeyError(f"stream {stream_id!r} is not open")
        return self._buffered[stream_id]

    # ------------------------------------------------------------- lifecycle
    def open_stream(self, stream_id: str) -> None:
        if stream_id in self._sessions:
            raise KeyError(f"stream {stream_id!r} already open")
        if not self._free:
            self._grow()
        slot = self._free.pop()
        self._reset_slot(slot)
        self._sessions[stream_id] = self._codec.session(dtype=self._dtype)
        self._slots[stream_id] = slot
        self._pending[stream_id] = []
        self._buffered[stream_id] = 0
        _M_STREAMS_OPEN["coalesced"].inc()
        old = self._closed.pop(stream_id, None)
        if old is not None:
            _fold_stats(self._retired, old)

    def submit(self, stream_id: str, chunk) -> Optional[Dict[str, bytes]]:
        """Stage a chunk; returns the flush result when the policy trips
        (segments for every flushed stream, keyed by stream id), else
        ``None``.  No device work happens before the flush."""
        if stream_id not in self._sessions:
            raise KeyError(f"stream {stream_id!r} is not open")
        arr = np.asarray(chunk)
        if arr.ndim != 1:
            raise ValueError("coalesced streams feed 1-D chunks")
        self._pending[stream_id].append(arr)
        if len(arr) and stream_id not in self._staged_ts:
            self._staged_ts[stream_id] = self._clock()
        B = self._codec.block_size
        old = self._buffered[stream_id]
        new = old + len(arr)
        self._buffered[stream_id] = new
        self._ready_blocks += new // B - old // B
        if old // B == 0 and new // B > 0:
            self._ready_streams += 1
        if self.policy.should_flush(self._ready_streams, self._ready_blocks,
                                    self._age()):
            return self.flush()
        return None

    def poll(self) -> Optional[Dict[str, bytes]]:
        """Deadline tick for the ``max_age_s`` trigger: callers with a
        latency SLO call this from their timer loop; flushes (and returns
        the segments) iff the policy's deadline has expired."""
        if self.policy.should_flush(self._ready_streams, self._ready_blocks,
                                    self._age()):
            return self.flush()
        return None

    def flush(self) -> Dict[str, bytes]:
        """Encode all pending blocks in one padded device batch and return
        each flushed stream's segment bytes."""
        return self._flush(list(self._sessions))

    def close_stream(self, stream_id: str) -> bytes:
        """Flush the stream's pending samples, emit its tail-carrying final
        segment, and recycle its slot."""
        sess = self._sessions.get(stream_id)
        if sess is None:
            raise KeyError(f"stream {stream_id!r} is not open")
        flushed = self._flush([stream_id]).get(stream_id, b"")
        final = sess.finish()
        self._closed[stream_id] = sess.stats
        self._free.append(self._slots.pop(stream_id))
        del self._sessions[stream_id]
        del self._pending[stream_id]
        del self._buffered[stream_id]
        self._staged_ts.pop(stream_id, None)
        _M_STREAMS_OPEN["coalesced"].dec()
        return flushed + final

    def stats(self, stream_id: Optional[str] = None) -> dict:
        if stream_id is not None:
            st = (self._sessions[stream_id].stats
                  if stream_id in self._sessions
                  else self._closed[stream_id])
            return st.as_dict()
        agg = SessionStats()
        _fold_stats(agg, self._retired)
        for st in list(self._closed.values()) + [
                s.stats for s in self._sessions.values()]:
            _fold_stats(agg, st)
        return agg.as_dict()

    # ------------------------------------------------------------- internals
    def _age(self) -> Optional[float]:
        if not self._staged_ts:
            return None
        return self._clock() - min(self._staged_ts.values())

    def _reset_slot(self, slot: int) -> None:
        """A recycled slot must look like a fresh dictionary: clearing the
        per-entry validity and the FIFO counter is sufficient (stale block
        values are never consulted while invalid, and inserts overwrite)."""
        if self._mixed is not None:
            self._mixed.reset_lane(slot)
        if self._state is None:
            return
        st = self._state
        self._state = st._replace(
            valid=st.valid.at[slot].set(False),
            count=st.count.at[slot].set(0),
        )

    def _grow(self) -> None:
        if self.plan is not None:
            raise RuntimeError(
                f"coalescer at plan-pinned capacity {self._capacity}")
        import jax.numpy as jnp
        old = self._capacity
        self._capacity = old * 2
        self._free.extend(range(self._capacity - 1, old - 1, -1))
        if self._mixed is not None:
            self._mixed.grow(self._capacity)
        if self._state is not None:
            pad = ((0, old),)
            st = self._state
            self._state = st._replace(
                sorted_blocks=jnp.pad(st.sorted_blocks, pad + ((0, 0),) * 2),
                dmin=jnp.pad(st.dmin, pad + ((0, 0),)),
                dmax=jnp.pad(st.dmax, pad + ((0, 0),)),
                valid=jnp.pad(st.valid, pad + ((0, 0),)),
                count=jnp.pad(st.count, pad),
                # channel-axis pad is safe even when the raw dict axis is
                # empty (error-bounded mode off): (C, 0, n) -> (2C, 0, n)
                raw_blocks=jnp.pad(st.raw_blocks, pad + ((0, 0),) * 2),
            )

    def _init_state(self, n_lem: int):
        import jax
        from repro.core.encoder import init_state
        st = init_state(
            self._codec.num_dict, n_lem, channels=self._capacity,
            raw=getattr(self._codec, "error_bound", None) is not None)
        if self.plan is not None:
            st = jax.device_put(st, self.plan.state_sharding())
        return st

    def _flush(self, stream_ids: List[str]) -> Dict[str, bytes]:
        t0 = time.perf_counter()
        with obs.span("encode.flush", attrs={"streams": len(stream_ids)}):
            out = self._flush_impl(stream_ids)
        if out:
            _M_ENC_FLUSHES.inc()
            _M_ENC_FLUSH_SECONDS.observe(time.perf_counter() - t0)
        return out

    def _flush_impl(self, stream_ids: List[str]) -> Dict[str, bytes]:
        if self._adaptive:
            return self._flush_adaptive(stream_ids)
        import jax.numpy as jnp
        from repro.core.encoder import (encode_decisions_batched,
                                        encode_decisions_dsharded,
                                        encode_decisions_sharded)
        prepared = {}
        B = self._codec.block_size
        for sid in stream_ids:
            chunks = self._pending[sid]
            if not chunks:
                continue  # nothing staged; the (< block) tail stays put
            self._pending[sid] = []
            self._staged_ts.pop(sid, None)
            ready = self._buffered[sid] // B
            self._buffered[sid] %= B  # the tail carries over
            self._ready_blocks -= ready
            if ready:
                self._ready_streams -= 1
            prep = self._sessions[sid].prepare(np.concatenate(chunks))
            if prep is not None:
                prepared[sid] = prep
        if not prepared:
            return {}

        cdc = self._codec
        n_lem = cdc._lem_n()
        _M_ENC_FLUSH_BLOCKS.observe(sum(p.nb for p in prepared.values()))
        nb_max = max(p.nb for p in prepared.values())
        nb_pad = -(-nb_max // self._bucket) * self._bucket
        batch = np.zeros((self._capacity, nb_pad, n_lem), dtype=np.float32)
        valid = np.zeros((self._capacity, nb_pad), dtype=bool)
        for sid, prep in prepared.items():
            slot = self._slots[sid]
            batch[slot, :prep.nb] = prep.payloads[0]
            valid[slot, :prep.nb] = True

        if self._state is None:
            self._state = self._init_state(n_lem)
        kw = dict(
            num_dict=cdc.num_dict, d_crit=float(cdc.d_crit),
            rel_tol=float(cdc.rel_tol), use_minmax=cdc.use_minmax,
            use_ks=cdc.use_ks,
        )
        eb = getattr(cdc, "error_bound", None)
        if eb is not None:
            kw["error_bound"] = float(eb)
            kw["error_cumulative"] = cdc.mode == "delta"
        matcher = getattr(cdc, "matcher", None)
        if cdc.backend == "pallas":
            # fused single-dispatch kernel by default (decisions bitwise
            # equal to the composed ops matcher); codec matcher overrides
            kw["matcher"] = matcher or "fused"
        elif matcher:
            kw["matcher"] = matcher
        bj, vj = jnp.asarray(batch), jnp.asarray(valid)
        if self.plan is not None:
            if getattr(self.plan, "dict_shards", 1) > 1:
                (h, s, o), self._state = encode_decisions_dsharded(
                    bj, mesh=self.plan.mesh, ch_axis=self.plan.axis_name,
                    dict_axis=self.plan.dict_axis, state=self._state,
                    valid=vj, **kw)
            else:
                (h, s, o), self._state = encode_decisions_sharded(
                    bj, mesh=self.plan.mesh, axis_name=self.plan.axis_name,
                    state=self._state, valid=vj, **kw)
        else:
            (h, s, o), self._state = encode_decisions_batched(
                bj, state=self._state, valid=vj, **kw)
        h, s, o = (np.asarray(v) for v in (h, s, o))

        out = {}
        for sid, prep in prepared.items():
            slot, nb = self._slots[sid], prep.nb
            dec = (h[slot, :nb], s[slot, :nb], o[slot, :nb])
            out[sid] = self._sessions[sid].commit(prep, [dec])[0]
        return out

    def _flush_adaptive(self, stream_ids: List[str]) -> Dict[str, bytes]:
        """Adaptive flush: each stream runs its per-stream feed cycle
        (selector switch at the flush boundary, observe, prepare) but the
        decide is ONE shared ``MixedCohort`` dispatch over the padded
        cohort -- slots carry per-stream mode/width/threshold as masked
        lanes (DESIGN.md Sec. 13), so heterogeneous streams no longer fall
        back to one dispatch per stream."""
        prepared = {}
        B = self._codec.block_size
        for sid in stream_ids:
            chunks = self._pending[sid]
            if not chunks:
                continue  # nothing staged; the (< block) tail stays put
            self._pending[sid] = []
            self._staged_ts.pop(sid, None)
            ready = self._buffered[sid] // B
            self._buffered[sid] %= B  # the tail carries over
            self._ready_blocks -= ready
            if ready:
                self._ready_streams -= 1
            sess = self._sessions[sid]
            arr = np.concatenate(chunks)
            # switches commit at the flush boundary (statistics through the
            # previous flushes), exactly like IdealemSession._feed_adaptive
            ev = sess._selectors[0].decide(sess._stats[0].blocks)
            if ev is not None:
                sess._apply_switch(0, ev)
                if self._mixed is not None:
                    self._mixed.reset_lane(self._slots[sid])
            sess._selectors[0].observe(arr)
            prep = sess.prepare(arr)
            if prep is not None:
                prepared[sid] = prep
        if not prepared:
            return {}

        _M_ENC_FLUSH_BLOCKS.observe(sum(p.nb for p in prepared.values()))
        if self._mixed is None:
            cdc = self._codec
            eb = getattr(cdc, "error_bound", None)
            self._mixed = MixedCohort(
                cdc.num_dict, self._capacity, rel_tol=float(cdc.rel_tol),
                use_minmax=cdc.use_minmax, use_ks=cdc.use_ks,
                error_bound=None if eb is None else float(eb),
                matcher=_mixed_matcher_name(cdc), plan=self.plan)
        nb_max = max(p.nb for p in prepared.values())
        nb_pad = -(-nb_max // self._bucket) * self._bucket
        entries = []
        for sid, prep in prepared.items():
            sess = self._sessions[sid]
            cdc = sess._codecs[0]
            entries.append((self._slots[sid], np.asarray(prep.payloads[0]),
                            float(sess._d_crit[0]), cdc.mode == "delta",
                            getattr(cdc, "error_bound", None) is not None))
        dec = self._mixed.decide(entries, nb_pad=nb_pad)
        return {sid: self._sessions[sid].commit(
                    prep, [dec[self._slots[sid]]])[0]
                for sid, prep in prepared.items()}


class DecompressionService:
    """The read-side sibling of ``StreamCoalescer`` (DESIGN.md Secs. 7-8):
    serve block-range reads out of packed containers (``repro.store``).

    Containers are ``attach``\\ ed under an id; ``read`` answers one range
    immediately, ``submit``/``flush`` coalesce many concurrent range
    requests -- ragged, across stores and channels -- into ONE padded
    reconstruct dispatch per compatible group, mirroring how the write
    side cuts one padded scan per flush.  ``backend`` selects the
    reconstruction backend (``repro.core.decode.BACKENDS``): on a device
    backend all compatible requests of a flush -- even across different
    containers -- merge into a single device dispatch (per-store parse +
    gather stays on the host; the byte-identity fallback rule of the
    engine applies).  The same ``FlushPolicy`` decides when to stop
    accumulating: ``max_batch_blocks`` bounds the padded batch,
    ``max_batch_streams`` the number of waiting requests, ``max_age_s``
    the deadline (measured with an injectable clock, like the coalescer).

    Parsed segments are kept in a per-service LRU keyed by ``(container
    identity, chunk)`` -- ``Container.cache_token``, i.e. ``(path,
    generation)`` for file-backed containers -- so two attaches of the
    same archive (or two ``Container`` instances over the same file) share
    walks instead of re-parsing.  Eviction is by total cached blocks so
    fat segments don't dodge the budget.  Decoded output is NOT cached
    (it is range-shaped and cheap to rebuild from parsed segments).

    Flushes are *pipelined* (DESIGN.md Sec. 9): each flush is explicit
    plan -> gather -> reconstruct -> emit stages, with the reconstruct
    stage handed to a stage executor (``repro.serve.pipeline``).  With
    ``FlushPolicy.pipeline_depth == 1`` (the default) the stages alternate
    and a flush returns its own batch's answers, byte-identical to the
    pre-pipeline service.  With depth 2 the service plans/gathers batch
    N+1 on the host while a worker thread reconstructs batch N; a flush
    then returns the answers of the batch that just *completed*, and
    ``drain()`` (or ``close()``) collects whatever is still in flight.
    Per-store quarantine survives every stage boundary: plan/gather
    failures are recorded when the batch is cut, reconstruct failures when
    its batch is emitted -- ``last_errors`` either way, and only the
    failing group's requests.  ``executor`` is injectable (any object with
    ``submit(fn, *args) -> future`` and ``shutdown()``), and ``trace`` --
    a ``(stage, flush_seq)`` callable -- observes stage transitions, so
    tests can force and assert orderings deterministically.

    ``backend="auto"`` (the default) routes every dispatch to the
    measured-best backend for its (mode, dtype, size-bucket)
    (``repro.core.decode.resolve_backend``: first use probes numpy vs jax
    vs pallas, the choice is cached and optionally persisted).
    """

    def __init__(self, policy: Optional[FlushPolicy] = None,
                 cache_blocks: int = 1 << 16,
                 clock: Optional[Callable[[], float]] = None,
                 backend: str = "auto",
                 executor=None,
                 trace: Optional[Callable[[str, int], None]] = None):
        from repro.core import decode as decode_mod
        from repro.store import Container  # noqa: F401 (import check only)
        if backend != "auto" and backend not in decode_mod.BACKENDS:
            raise ValueError(f"unknown decode backend {backend!r}")
        self.policy = policy or FlushPolicy()
        self.backend = backend
        self._cache_blocks = cache_blocks
        self._clock = clock if clock is not None else time.monotonic
        self._stores: Dict[str, "Container"] = {}
        self._seeds: Dict[str, int] = {}
        self._cache: "OrderedDict[Tuple[tuple, int], object]" = OrderedDict()
        self._cached_blocks = 0
        # pending request: (id, store, channel, start, stop, submit ts);
        # FIFO order makes the head the batch's oldest for the deadline
        self._pending: List[Tuple[str, str, int, int, int, float]] = []
        self._pending_blocks = 0
        if executor is None:
            executor = (ThreadStageExecutor() if self.policy.pipeline_depth > 1
                        else SyncExecutor())
        self._pipe = StagePipeline(executor, self.policy.pipeline_depth)
        self._trace = trace if trace is not None else (lambda stage, seq: None)
        self._flush_seq = 0
        self._closed = False
        # answers emitted outside a normal collection point (a pipeline
        # quiesce before a cold autotune probe), delivered with the next
        # flush/drain/poll return
        self._early_out: Dict[str, np.ndarray] = {}
        self.stats = {"requests": 0, "blocks_out": 0, "flushes": 0,
                      "failed_requests": 0, "cache_hits": 0,
                      "cache_misses": 0, "dispatches": 0, "inflight_peak": 0}
        self.last_errors: Dict[str, Exception] = {}

    # ------------------------------------------------------------- lifecycle
    def attach(self, store_id: str, container, seed: int = 0) -> None:
        """Register a container (bytes or ``repro.store.Container``) for
        serving.  ``seed`` pins the decoder's hit-permutation stream."""
        from repro.store import Container
        if store_id in self._stores:
            raise KeyError(f"store {store_id!r} already attached")
        if not isinstance(container, Container):
            container = Container(container)
        self._stores[store_id] = container
        self._seeds[store_id] = seed

    def detach(self, store_id: str) -> None:
        token = self._store(store_id).cache_token
        del self._stores[store_id]
        del self._seeds[store_id]
        # evict the departing container's parsed chunks -- unless another
        # attached store shares the same file generation and still wants them
        live = {c.cache_token for c in self._stores.values()}
        if token not in live:
            self._cache = OrderedDict(
                (k, v) for k, v in self._cache.items() if k[0] != token)
            self._cached_blocks = sum(len(p.is_hit)
                                      for p in self._cache.values())
        # staged requests against the departing store cannot be answered:
        # record them in last_errors (same contract as a failed flush
        # group) instead of dropping them silently
        dropped = [r for r in self._pending if r[1] == store_id]
        for rid, *_ in dropped:
            self.last_errors[rid] = KeyError(
                f"store {store_id!r} detached with request pending")
        self._acct("failed_requests", len(dropped))
        self._pending = [r for r in self._pending if r[1] != store_id]
        self._pending_blocks = sum(r[4] - r[3] for r in self._pending)

    @property
    def attached_stores(self) -> List[str]:
        return sorted(self._stores)

    # ------------------------------------------------------------ read paths
    def read(self, store_id: str, start_block: int, stop_block: int,
             channel: int = 0) -> np.ndarray:
        """Synchronous single-range read through the segment cache."""
        from repro.store import decode_range
        store = self._store(store_id)
        out = decode_range(store, start_block, stop_block, channel=channel,
                           seed=self._seeds[store_id],
                           parse=self._parse_for(store_id),
                           backend=self.backend)
        self._acct("requests")
        self._acct("blocks_out", stop_block - start_block)
        return out

    def handle(self, req) -> "object":
        """Serve one wire-typed :class:`repro.api.DecodeRangeRequest`
        synchronously (through the segment cache, same path as
        :meth:`read`) and return its :class:`repro.api.RangeResult`.
        Batched/pipelined serving goes through ``submit``/``flush``; the
        front end's decode mux feeds those from the same request type."""
        from repro import api
        values = self.read(req.store_id, req.start_block, req.stop_block,
                           channel=req.channel)
        return api.RangeResult(request_id=req.request_id, values=values)

    def read_channels(self, store_id: str,
                      channels: Optional[Sequence[int]] = None
                      ) -> Dict[int, np.ndarray]:
        """Full decode of whole channels (tails included), batched."""
        from repro.store import decode_channels
        store = self._store(store_id)
        out = decode_channels(store, channels,
                              seed=self._seeds[store_id],
                              parse=self._parse_for(store_id),
                              backend=self.backend)
        self._acct("requests", len(out))
        self._acct("blocks_out",
                   sum(store.total_blocks(c) for c in out))
        return out

    def submit(self, request_id: str, store_id: str, start_block: int,
               stop_block: int, channel: int = 0
               ) -> Optional[Dict[str, np.ndarray]]:
        """Stage a range request; when the flush policy trips, returns the
        flush's answers (keyed by request id) -- at ``pipeline_depth`` 1
        that is this very batch; at depth > 1 it is whatever batch(es)
        just COMPLETED, so correlate by request id, not by call.  Returns
        ``None`` while the policy holds."""
        self._check_open()
        store = self._store(store_id)
        total = store.total_blocks(channel)
        if not (0 <= start_block < stop_block <= total):
            raise IndexError(
                f"block range [{start_block}, {stop_block}) outside "
                f"[0, {total}) of {store_id!r} channel {channel}")
        if request_id in self._live_request_ids():
            raise KeyError(f"request {request_id!r} already pending")
        self._pending.append(
            (request_id, store_id, channel, start_block, stop_block,
             self._clock()))
        self._pending_blocks += stop_block - start_block
        if self.policy.should_flush(len(self._pending), self._pending_blocks,
                                    self._age()):
            return self.flush()
        return None

    def poll(self) -> Optional[Dict[str, np.ndarray]]:
        """Deadline tick (``FlushPolicy.max_age_s``), like the coalescer's.
        Also delivers (without blocking) any pipelined batch that finished
        reconstructing since the last call, so a submit/poll timer loop
        never strands a completed batch's answers."""
        if self._pending and self.policy.should_flush(
                len(self._pending), self._pending_blocks, self._age()):
            return self.flush()
        ready = {**self._take_early(), **self._collect_ready()}
        return ready or None

    def flush(self) -> Dict[str, np.ndarray]:
        """Cut the pending batch through the staged pipeline and return the
        answers of every batch that COMPLETED (DESIGN.md Sec. 9).

        The four stages: *plan* -- per store, seek + walk the covering
        chunks (``store.plan_windows``); a store that fails here (corrupt
        chunk, racing detach) fails ALONE: its requests are reported in
        ``last_errors`` and every other store proceeds.  *gather* -- one
        shared byte gather per store (``store.gather_parts``), then parts
        sharing codec parameters and seed are merged ACROSS stores and
        padded into one plan per compatible group (``decode.pad_parts``).
        On a host-routed group, requests are additionally split by pow-2
        length buckets (mirroring the write side's ``block_bucket``) so
        one long request does not pad every short one; a device dispatch
        amortizes its own padding, so device groups merge buckets -- but
        not without limit: a merged group whose padded size exceeds both
        the policy block budget and 4x its real work re-splits by length
        bucket.  *reconstruct* -- one engine dispatch per group
        (``stats["dispatches"]``), run by the stage executor: inline at
        ``pipeline_depth`` 1, on the worker thread (overlapping the next
        batch's plan/gather) at depth 2.  *emit* -- slice each request's
        blocks back out, account stats, quarantine reconstruct failures.

        With depth 1 the returned dict is this batch's answers -- the
        alternating path.  With depth > 1 it is the answers of the OLDEST
        in-flight batch(es); call :meth:`drain` for the rest.

        ``last_errors`` accumulates (detach records dropped requests there
        too); callers correlating answers by id should ``pop`` entries they
        have handled."""
        self._check_open()
        age = self._age()
        if age is not None:  # flush age at cut: how long the oldest waited
            _M_FLUSH_AGE.observe(age)
        pending, self._pending = self._pending, []
        self._pending_blocks = 0
        out: Dict[str, np.ndarray] = self._take_early()
        if not pending:
            # nothing new to cut, but completed in-flight batches must not
            # be stranded behind an explicit flush
            out.update(self._collect_ready())
            return out
        self._flush_seq += 1
        seq = self._flush_seq
        units = self._stage_gather(seq, self._stage_plan(seq, pending))
        completed = self._pipe.push((seq, units),
                                    self._stage_reconstruct, seq, units)
        self._acct("flushes")
        self.stats["inflight_peak"] = max(
            self.stats["inflight_peak"], self._pipe.inflight + len(completed))
        _M_INFLIGHT.set(self._pipe.inflight)
        for (seq_done, batch_units), outcomes, exc in completed:
            out.update(self._stage_emit(seq_done, batch_units, outcomes, exc))
        out.update(self._take_early())  # batches drained by a probe quiesce
        return out

    def drain(self) -> Dict[str, np.ndarray]:
        """Collect every in-flight batch's answers (blocking).  With
        ``pipeline_depth > 1`` a flush returns only completed batches;
        call this to quiesce the pipeline (shutdown, end of a burst).  The
        depth-1 pipeline never has anything in flight, so this is a no-op
        there."""
        out: Dict[str, np.ndarray] = self._take_early()
        for (seq_done, batch_units), outcomes, exc in self._pipe.drain():
            out.update(self._stage_emit(seq_done, batch_units, outcomes, exc))
        _M_INFLIGHT.set(self._pipe.inflight)
        return out

    def close(self) -> Dict[str, np.ndarray]:
        """Flush the pending batch, drain the pipeline, and shut the stage
        executor down.  Returns every answer not yet handed out.  The
        service is unusable afterwards: ``submit``/``flush`` raise (work
        queued onto a dead executor would hang forever); repeated
        ``close()`` calls are safe no-ops."""
        if self._closed:
            return {}
        out = self.flush()
        out.update(self.drain())
        self._pipe.executor.shutdown()
        self._closed = True
        return out

    @property
    def inflight(self) -> int:
        """Reconstruct batches currently in flight (bounded by
        ``FlushPolicy.pipeline_depth - 1`` between calls)."""
        return self._pipe.inflight

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("DecompressionService is closed")

    def _acct(self, key: str, n: int = 1) -> None:
        """Bump a service stat and its registry mirror: the ``stats`` dict
        keeps its pinned per-instance shape, the ``repro_serve_*_total``
        counters aggregate across instances for the exporters."""
        self.stats[key] += n
        _M_SERVE[key].inc(n)

    def _collect_ready(self) -> Dict[str, np.ndarray]:
        """Emit every in-flight batch that has already finished
        reconstructing (non-blocking, oldest first)."""
        out: Dict[str, np.ndarray] = {}
        for (seq_done, batch_units), outcomes, exc in \
                self._pipe.collect_ready():
            out.update(self._stage_emit(seq_done, batch_units, outcomes, exc))
        return out

    def _take_early(self) -> Dict[str, np.ndarray]:
        out, self._early_out = self._early_out, {}
        return out

    def _live_request_ids(self) -> set:
        """Ids that may not be reused yet: staged requests plus every
        request inside an in-flight batch (its answer or error has not
        been handed out, so a duplicate would collide in the result
        dict)."""
        ids = {r[0] for r in self._pending}
        for _seq, units in self._pipe.metas():
            for u in units:
                ids.update(rid for rid, _ in u.items)
        return ids

    # --------------------------------------------------------- flush stages
    def _stage_plan(self, seq: int, pending) -> List["_PlannedStore"]:
        """Host stage 1: group requests by (store, codec parameters) and
        seek + walk each store's covering chunks.  Failing stores are
        quarantined here -- recorded in ``last_errors`` when the batch is
        cut, before any reconstruction of it runs."""
        with _staged("plan", seq, requests=len(pending)):
            return self._plan_impl(seq, pending)

    def _plan_impl(self, seq: int, pending) -> List["_PlannedStore"]:
        from repro.store import plan_windows
        self._trace("plan", seq)
        by_store: Dict[tuple, List[Tuple[str, int, int, int]]] = {}
        headers: Dict[Tuple[str, int], object] = {}  # per-flush header memo
        for rid, sid, channel, start, stop, _ts in pending:
            try:
                hdr = headers.get((sid, channel))
                if hdr is None:
                    hdr = headers[(sid, channel)] = self._stores[
                        sid].header_of(
                        int(self._stores[sid].chunks_of(channel)[0]))
            except Exception as e:  # corrupt header / racing detach
                self.last_errors[rid] = e
                self._acct("failed_requests")
                continue
            pkey = (hdr.mode, hdr.block_size, np.dtype(hdr.dtype).str,
                    hdr.value_range,
                    bool(getattr(hdr, "error_bounded", False)))
            by_store.setdefault((sid,) + pkey, []).append(
                (rid, channel, start, stop))

        planned = []
        for (sid, *pkey), reqs in by_store.items():
            ranges = [(c, i, j) for _, c, i, j in reqs]
            try:
                hdr, windows = plan_windows(self._stores[sid], ranges,
                                            parse=self._parse_for(sid))
            except Exception as e:  # quarantine this store's requests
                for rid, _, _, _ in reqs:
                    self.last_errors[rid] = e
                self._acct("failed_requests", len(reqs))
                continue
            planned.append(_PlannedStore(sid, tuple(pkey), reqs, ranges,
                                         hdr, windows))
        return planned

    def _stage_gather(self, seq: int,
                      planned: List["_PlannedStore"]) -> List["_Unit"]:
        """Host stage 2: one shared byte gather per store, then group
        compatible parts across stores, resolve each group's backend
        (``"auto"`` = measured-best) and pad each group into ONE plan."""
        with _staged("gather", seq, stores=len(planned)):
            return self._gather_impl(seq, planned)

    def _gather_impl(self, seq: int,
                     planned: List["_PlannedStore"]) -> List["_Unit"]:
        from repro.core import decode as decode_mod
        from repro.store import gather_parts
        self._trace("gather", seq)
        pregroups: Dict[tuple, List[Tuple[str, int, object]]] = {}
        for ps in planned:
            try:
                parts = gather_parts(self._stores[ps.store_id], ps.header,
                                     ps.windows, ps.ranges)
            except Exception as e:  # quarantine this store's requests
                for rid, _, _, _ in ps.requests:
                    self.last_errors[rid] = e
                self._acct("failed_requests", len(ps.requests))
                continue
            pre = (ps.pkey, self._seeds[ps.store_id])
            for (rid, _, i, j), part in zip(ps.requests, parts):
                pregroups.setdefault(pre, []).append((rid, j - i, part))

        # resolve the backend per MERGED group at its true dispatch size
        # (the sum of the group's requested blocks): a flush of many small
        # requests dispatches as one large batch, and it is that batch --
        # not any single request -- the autotuner must route
        groups: Dict[tuple, List[Tuple[str, int, object]]] = {}
        for (pkey, seed), items in pregroups.items():
            mode, B, dt_str, vr, _eb = pkey
            total = sum(n for _, n, _ in items)
            if (self.backend == "auto" and self._pipe.inflight
                    and not decode_mod.autotune_cached(mode, dt_str, total)):
                # cold combination: quiesce the pipeline before the timing
                # probe -- an in-flight reconstruct would contend with the
                # measurements and poison the persisted choice.  The
                # drained batches' answers are delivered with this flush.
                for (sq, bu), oc, ex in self._pipe.drain():
                    self._early_out.update(
                        self._stage_emit(sq, bu, oc, ex))
            eff = decode_mod.resolve_backend(self.backend, mode, dt_str,
                                             total, vr, B)
            if eff == "numpy":
                # host path: split by pow-2 length buckets (padding
                # control, mirroring the write side's block_bucket)
                for it in items:
                    groups.setdefault(
                        (pkey, seed, decode_mod._pow2(it[1]), eff),
                        []).append(it)
            else:
                groups[(pkey, seed, 0, eff)] = items

        # a merged (device) group must not let one huge request pad many
        # tiny ones: beyond both the policy block budget and 4x the real
        # work, re-split by pow-2 length bucket before dispatch
        split: List[Tuple[tuple, List[Tuple[str, int, object]]]] = []
        for gkey, items in groups.items():
            lens = [n for _, n, _ in items]
            padded = len(items) * max(lens)
            if (len(items) > 1 and padded > sum(lens) * 4
                    and padded > self.policy.max_batch_blocks):
                subs: Dict[int, List[Tuple[str, int, object]]] = {}
                for it in items:
                    subs.setdefault(decode_mod._pow2(it[1]),
                                    []).append(it)
                split.extend((gkey, sub) for sub in subs.values())
            else:
                split.append((gkey, items))

        units: List[_Unit] = []
        for ((mode, B, dt_str, vr, eb), seed, _bucket, eff), items in split:
            try:
                plan, nbm = decode_mod.pad_parts(
                    mode, B, np.dtype(dt_str), vr,
                    [part for _, _, part in items], seed=seed, no_perm=eb)
            except Exception as e:
                for rid, _, _ in items:
                    self.last_errors[rid] = e
                self._acct("failed_requests", len(items))
                continue
            units.append(_Unit(eff, B, [(rid, n) for rid, n, _ in items],
                               plan, nbm))
        return units

    def _stage_reconstruct(self, seq: int, units: List["_Unit"]) -> list:
        """Device stage: one engine dispatch per unit.  Runs under the
        stage executor -- possibly on its worker thread, overlapping the
        next batch's host stages -- so it must not touch shared service
        state: failures are captured per unit and accounted at emit.
        (The span/histogram wrapper is thread-safe for the same reason:
        registry and tracer state are lock- and thread-local-guarded.)"""
        with _staged("reconstruct", seq, units=len(units)):
            return self._reconstruct_impl(seq, units)

    def _reconstruct_impl(self, seq: int, units: List["_Unit"]) -> list:
        self._trace("reconstruct", seq)
        from repro.core import decode as decode_mod
        outcomes = []
        for u in units:
            try:
                body = decode_mod.reconstruct(u.plan, backend=u.backend)
            except Exception as e:
                outcomes.append((u, None, e))
            else:
                outcomes.append((u, body, None))
        return outcomes

    def _stage_emit(self, seq: int, units: List["_Unit"], outcomes,
                    exc: Optional[BaseException]) -> Dict[str, np.ndarray]:
        """Host stage 4: slice each request's blocks out of its unit's
        padded body, account stats, and quarantine reconstruct failures.
        Runs in the caller's thread when the batch is collected."""
        with _staged("emit", seq, units=len(units)):
            return self._emit_impl(seq, units, outcomes, exc)

    def _emit_impl(self, seq: int, units: List["_Unit"], outcomes,
                   exc: Optional[BaseException]) -> Dict[str, np.ndarray]:
        self._trace("emit", seq)
        out: Dict[str, np.ndarray] = {}
        if exc is not None:  # the whole reconstruct stage died
            outcomes = [(u, None, exc) for u in units]
        for u, body, u_exc in outcomes or []:
            if u_exc is not None:
                for rid, _ in u.items:
                    self.last_errors[rid] = u_exc
                self._acct("failed_requests", len(u.items))
                continue
            body = body.reshape(len(u.items), u.nbm, u.block_size)
            self._acct("dispatches")
            for r, (rid, n) in enumerate(u.items):
                out[rid] = body[r, :n].ravel()
                self._acct("blocks_out", n)
            self._acct("requests", len(u.items))
        return out

    # ------------------------------------------------------------- internals
    def _store(self, store_id: str):
        try:
            return self._stores[store_id]
        except KeyError:
            raise KeyError(f"store {store_id!r} is not attached") from None

    def _parse_for(self, store_id: str):
        """LRU-caching wrapper around ``repro.store.parse_chunk``, keyed on
        the container's identity (``cache_token``) so a re-attach -- or a
        second ``Container`` over the same file -- reuses cached walks."""
        from repro.store import parse_chunk
        token = self._store(store_id).cache_token

        def parse(store, chunk):
            key = (token, chunk)
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self._acct("cache_hits")
                return hit
            self._acct("cache_misses")
            parsed = parse_chunk(store, chunk)
            self._cache[key] = parsed
            self._cached_blocks += len(parsed.is_hit)
            while self._cache and self._cached_blocks > self._cache_blocks:
                _, old = self._cache.popitem(last=False)
                self._cached_blocks -= len(old.is_hit)
            return parsed

        return parse

    def _age(self) -> Optional[float]:
        if not self._pending:
            return None
        return self._clock() - self._pending[0][5]
