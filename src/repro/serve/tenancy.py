"""Multi-tenant state and admission control for the serving front end.

One :class:`Tenant` owns everything a paying client touches: its encode
streams (per-stream ``IdealemSession``, or slots in per-config
``StreamCoalescer`` cohorts for coalesced streams), its attached decode
containers behind one ``DecompressionService``, and its admission state
(stream/store counts, staged blocks, a bytes/s token bucket).

Admission is *typed*: every rejection raises a ``repro.errors`` class
carrying the protocol code and HTTP status the front end answers with --
``QuotaExceededError`` (429: shed load), ``RateLimitedError`` (429 with
``retry_after_s``), ``OverloadedError`` (503: global backpressure, see
``repro.serve.frontend``).  Nothing here touches a socket; the module is
synchronous and clock-injectable, so quota/backpressure behaviour is unit
testable without a server.

Streams come in two service shapes, chosen at open:

* ``coalesce=False`` (default): the stream owns an ``IdealemSession`` and
  each feed dispatches immediately -- segment bytes come back on the
  feed's own response, byte-identical to a direct session fed the same
  chunks (the loadgen's zero-byte-diff check).
* ``coalesce=True``: the stream occupies a slot in the tenant's
  per-config ``StreamCoalescer``; feeds stage host-side and the policy
  (or the front end's deadline tick) cuts one padded device batch for the
  whole cohort.  Segments produced by a background flush buffer on the
  stream until the client's next call collects them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import api
from repro.errors import (ApiError, NotFoundError, QuotaExceededError,
                          RateLimitedError)

__all__ = ["TenantQuota", "TokenBucket", "TenantStream", "Tenant",
           "TenantRegistry"]


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits.  ``None`` disables a limit."""

    max_streams: int = 64
    max_stores: int = 16
    max_staged_blocks: int = 4096        # staged in coalescer cohorts
    max_bytes_per_s: Optional[float] = None
    burst_bytes: Optional[float] = None  # bucket depth; default 1s of rate
    max_store_bytes: int = 64 << 20      # attached container size cap

    def to_json(self) -> dict:
        return {k: getattr(self, k) for k in (
            "max_streams", "max_stores", "max_staged_blocks",
            "max_bytes_per_s", "burst_bytes", "max_store_bytes")}

    @classmethod
    def from_json(cls, doc: dict) -> "TenantQuota":
        if not isinstance(doc, dict):
            raise ApiError("TenantQuota: expected object")
        known = {f.name for f in cls.__dataclass_fields__.values()}  # type: ignore[attr-defined]
        extra = set(doc) - known
        if extra:
            raise ApiError(f"TenantQuota: unknown field(s) {sorted(extra)}")
        return cls(**doc)


class TokenBucket:
    """Bytes/s admission: a classic token bucket with injectable clock.

    ``take(n)`` either debits ``n`` tokens or raises
    :class:`RateLimitedError` with the refill time; a request larger than
    the bucket's depth can never succeed and raises
    :class:`QuotaExceededError` instead (retrying is futile)."""

    def __init__(self, rate: float, burst: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if rate <= 0:
            raise ValueError("token bucket rate must be positive")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else float(rate)
        self._tokens = self.burst
        self._clock = clock if clock is not None else time.monotonic
        self._last = self._clock()

    def _refill(self) -> None:
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now

    def take(self, n: float) -> None:
        if n > self.burst:
            raise QuotaExceededError(
                f"request of {n:.0f} bytes exceeds the burst capacity "
                f"{self.burst:.0f} of this tenant's rate limit")
        self._refill()
        if self._tokens >= n:
            self._tokens -= n
            return
        raise RateLimitedError(
            f"bytes/s budget exhausted ({self.rate:.0f} B/s)",
            retry_after_s=(n - self._tokens) / self.rate)

    @property
    def tokens(self) -> float:
        self._refill()
        return self._tokens


@dataclass
class TenantStream:
    """One open wire stream: its session (direct) or coalescer slot
    (coalesced), plus segments a background flush produced that the
    client has not collected yet."""

    stream_id: str
    config: api.CodecConfig
    coalesced: bool
    session: object = None               # IdealemSession (direct streams)
    pending_segments: List[bytes] = field(default_factory=list)
    # cumulative stat snapshot at the last feed, for per-call deltas
    last_stats: tuple = (0, 0, 0, 0)     # blocks, hits, bytes_in, bytes_out

    def collect(self) -> bytes:
        """Drain segments produced since the client's last call (deadline
        flushes of coalesced streams land here)."""
        if not self.pending_segments:
            return b""
        out = b"".join(self.pending_segments)
        self.pending_segments.clear()
        return out


class Tenant:
    """All serving state of one tenant id; see the module docstring."""

    def __init__(self, tenant_id: str, quota: TenantQuota,
                 clock: Optional[Callable[[], float]] = None,
                 policy=None, decode_backend: str = "auto"):
        from .engine import FlushPolicy
        self.id = tenant_id
        self.quota = quota
        self._clock = clock if clock is not None else time.monotonic
        self.policy = policy if policy is not None else FlushPolicy()
        self.streams: Dict[str, TenantStream] = {}
        # one coalescer per codec config (a cohort shares one scan shape)
        self.coalescers: Dict[api.CodecConfig, object] = {}
        self._decomp = None
        self._decode_backend = decode_backend
        self.bucket = (TokenBucket(quota.max_bytes_per_s, quota.burst_bytes,
                                   clock=self._clock)
                       if quota.max_bytes_per_s else None)
        self.store_ids: Dict[str, int] = {}  # id -> container byte size

    # ----------------------------------------------------------- admission
    def admit_open_stream(self) -> None:
        if len(self.streams) >= self.quota.max_streams:
            raise QuotaExceededError(
                f"tenant {self.id!r} at max_streams="
                f"{self.quota.max_streams}")

    def admit_attach(self, nbytes: int) -> None:
        if len(self.store_ids) >= self.quota.max_stores:
            raise QuotaExceededError(
                f"tenant {self.id!r} at max_stores={self.quota.max_stores}")
        if nbytes > self.quota.max_store_bytes:
            raise QuotaExceededError(
                f"container of {nbytes} bytes exceeds max_store_bytes="
                f"{self.quota.max_store_bytes}")

    def admit_bytes(self, nbytes: int) -> None:
        if self.bucket is not None:
            self.bucket.take(float(nbytes))

    def admit_staged(self, add_blocks: int) -> None:
        if (self.staged_blocks + add_blocks) > self.quota.max_staged_blocks:
            raise QuotaExceededError(
                f"tenant {self.id!r} would stage "
                f"{self.staged_blocks + add_blocks} blocks "
                f"(max_staged_blocks={self.quota.max_staged_blocks})")

    @property
    def staged_blocks(self) -> int:
        """Whole blocks staged host-side across the tenant's coalescer
        cohorts, waiting for a flush -- the admission pressure signal."""
        return sum(c.pending_blocks for c in self.coalescers.values())

    # ------------------------------------------------------------ lifecycle
    def open_stream(self, stream_id: str, config: api.CodecConfig,
                    coalesce: bool = False) -> TenantStream:
        from repro.core import IdealemCodec
        if stream_id in self.streams:
            raise ApiError(f"stream {stream_id!r} already open")
        self.admit_open_stream()
        if coalesce:
            if config.backend == "numpy":
                raise ApiError("coalesced streams batch on a device "
                               "backend; open with coalesce=false or a "
                               "jax/pallas config")
            coal = self.coalescers.get(config)
            if coal is None:
                from .compress import StreamCoalescer
                coal = StreamCoalescer(policy=self.policy,
                                       clock=self._clock, **config.kwargs())
                self.coalescers[config] = coal
            coal.open_stream(stream_id)
            st = TenantStream(stream_id, config, coalesced=True)
        else:
            codec = IdealemCodec.from_config(config)
            st = TenantStream(stream_id, config, coalesced=False,
                              session=codec.session())
        self.streams[stream_id] = st
        return st

    def stream(self, stream_id: str) -> TenantStream:
        st = self.streams.get(stream_id)
        if st is None:
            raise NotFoundError(
                f"tenant {self.id!r} has no open stream {stream_id!r}")
        return st

    def feed(self, req: api.CompressRequest) -> api.FeedResult:
        """Apply one wire feed; typed admission first, then the stream's
        service shape (direct dispatch vs coalesced staging)."""
        st = self.stream(req.stream_id)
        arr = np.asarray(req.samples)
        self.admit_bytes(arr.nbytes)
        if st.coalesced:
            coal = self.coalescers[st.config]
            staged = coal.staged_samples(req.stream_id)
            B = coal.block_size
            self.admit_staged((staged + len(arr)) // B - staged // B)
            flushed = coal.submit(req.stream_id, arr) or {}
            self._scatter_flush(flushed)
            seg = st.collect()
            return self._result(st, seg)
        seg = st.collect() + st.session.feed(arr)
        return self._result(st, seg)

    def close_stream(self, stream_id: str) -> api.FeedResult:
        st = self.stream(stream_id)
        if st.coalesced:
            coal = self.coalescers[st.config]
            seg = st.collect() + coal.close_stream(stream_id)
        else:
            seg = st.collect() + st.session.finish()
        res = self._result(st, seg, final=True)
        del self.streams[stream_id]
        return res

    def poll_flushes(self) -> int:
        """Deadline tick: run every coalescer's ``poll`` (the
        ``FlushPolicy.max_age_s`` trigger) and buffer resulting segments
        on their streams.  Returns the number of streams that flushed."""
        n = 0
        for coal in self.coalescers.values():
            flushed = coal.poll() or {}
            self._scatter_flush(flushed)
            n += len(flushed)
        return n

    def flush_all(self) -> int:
        """Force-flush every coalescer cohort (global backpressure relief
        and shutdown path)."""
        n = 0
        for coal in self.coalescers.values():
            flushed = coal.flush() or {}
            self._scatter_flush(flushed)
            n += len(flushed)
        return n

    def set_policy(self, policy) -> None:
        """Swap the flush policy on every owned coalescer and the decode
        service -- the control loop's actuation point."""
        self.policy = policy
        for coal in self.coalescers.values():
            coal.policy = policy
        if self._decomp is not None:
            self._decomp.policy = policy

    def _scatter_flush(self, flushed: Dict[str, bytes]) -> None:
        for sid, seg in flushed.items():
            if seg and sid in self.streams:
                self.streams[sid].pending_segments.append(seg)

    def _result(self, st: TenantStream, seg: bytes,
                final: bool = False) -> api.FeedResult:
        if st.coalesced:
            coal = self.coalescers[st.config]
            try:
                d = coal.stats(st.stream_id)
            except KeyError:  # already closed and retired
                d = coal.stats()
        else:
            d = st.session.stats.as_dict()
        now = (d["blocks"], d["hits"], d["bytes_in"], d["bytes_out"])
        delta = tuple(a - b for a, b in zip(now, st.last_stats))
        st.last_stats = now
        return api.FeedResult(
            stream_id=st.stream_id, segment=seg, blocks=delta[0],
            hits=delta[1], bytes_in=delta[2], bytes_out=delta[3],
            final=final)

    # ----------------------------------------------------------- decode side
    @property
    def decomp(self):
        if self._decomp is None:
            from .compress import DecompressionService
            self._decomp = DecompressionService(
                policy=self.policy, clock=self._clock,
                backend=self._decode_backend)
        return self._decomp

    def attach_store(self, store_id: str, blob: bytes, seed: int = 0) -> None:
        self.admit_attach(len(blob))
        self.decomp.attach(store_id, blob, seed=seed)
        self.store_ids[store_id] = len(blob)

    def detach_store(self, store_id: str) -> None:
        if store_id not in self.store_ids:
            raise NotFoundError(
                f"tenant {self.id!r} has no store {store_id!r}")
        self.decomp.detach(store_id)
        del self.store_ids[store_id]

    def close(self) -> None:
        """Retire the tenant: flush cohorts, close the decode pipeline."""
        for sid in list(self.streams):
            self.close_stream(sid)
        if self._decomp is not None:
            self._decomp.close()


class TenantRegistry:
    """Tenant table: default quota, per-tenant overrides, lazy creation.

    The front end asks :meth:`get` on every request; unknown tenants are
    created with the default quota (admission caps still bound them) --
    authentication is out of scope, isolation is the point."""

    def __init__(self, default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 policy=None, decode_backend: str = "auto",
                 max_tenants: int = 1024):
        self.default_quota = default_quota or TenantQuota()
        self.quotas = dict(quotas or {})
        self.tenants: Dict[str, Tenant] = {}
        self._clock = clock
        self._policy = policy
        self._decode_backend = decode_backend
        self.max_tenants = max_tenants

    def get(self, tenant_id: str, create: bool = True) -> Tenant:
        t = self.tenants.get(tenant_id)
        if t is None:
            if not create:
                raise NotFoundError(f"unknown tenant {tenant_id!r}")
            if len(self.tenants) >= self.max_tenants:
                raise QuotaExceededError(
                    f"server at max_tenants={self.max_tenants}")
            t = Tenant(tenant_id,
                       self.quotas.get(tenant_id, self.default_quota),
                       clock=self._clock, policy=self._policy,
                       decode_backend=self._decode_backend)
            self.tenants[tenant_id] = t
        return t

    @property
    def staged_blocks(self) -> int:
        """Staged blocks across every tenant -- the global backpressure
        signal the front end maps to 503."""
        return sum(t.staged_blocks for t in self.tenants.values())

    def set_policy(self, policy) -> None:
        self._policy = policy
        for t in self.tenants.values():
            t.set_policy(policy)

    def poll_flushes(self) -> int:
        return sum(t.poll_flushes() for t in self.tenants.values())

    def close(self) -> None:
        for t in self.tenants.values():
            t.close()
