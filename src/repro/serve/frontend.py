"""Multi-tenant asyncio network front end (DESIGN.md Sec. 14).

The layer the ROADMAP's "millions of users" needs above the in-process
services: one asyncio HTTP/1.1 server multiplexing per-tenant
``IdealemSession``/``StreamCoalescer``/``DecompressionService`` machinery
(``repro.serve.tenancy``) behind typed admission control, with the wire
speaking exactly the ``repro.api`` request/response types the in-process
``handle()`` calls take -- same validation, same JSON, one schema.

Protocol: HTTP/1.1 with JSON-lines bodies.  Every request body is one
JSON document per line; every response body is one JSON document per
line, 1:1 with the request lines.  A single-line request behaves like
plain JSON-over-HTTP (status = that document's outcome); a multi-line
``/v1/feed`` body is the streaming ingest form -- each line an
independent ``CompressRequest``, failures carried per line as protocol
error documents while the neighbours proceed.  The tenant is the
``x-tenant`` header.  Routes:

  POST /v1/open     {"stream_id", "config"?: CodecConfig, "coalesce"?: bool}
  POST /v1/feed     CompressRequest            (JSON-lines batchable)
  POST /v1/close    {"stream_id"}           -> final FeedResult
  POST /v1/collect  {"stream_id"}           -> FeedResult (buffered segs)
  POST /v1/attach   {"store_id", "container": b64, "seed"?: int}
  POST /v1/detach   {"store_id"}
  POST /v1/decode   DecodeRangeRequest      -> RangeResult (batched mux)
  GET  /v1/stats    GET /v1/control    GET /metrics    GET /healthz

Admission: quota exhaustion and rate limits answer 429, global
backpressure answers 503 (``Retry-After`` set when known) -- the typed
``repro.errors`` classes carry the mapping, and every rejection counts in
``repro_frontend_rejections_total{code=...}``.  Backpressure *feeds* the
``FlushPolicy``: staged coalescer blocks are the policy's flush pressure,
and when the global staged total crosses the server budget the front end
force-flushes the fattest tenants before rejecting anybody.

Decode requests batch through a per-tenant mux: each wire request stages
into the tenant's ``DecompressionService`` (plan -> gather -> reconstruct
-> emit pipeline, histograms and all) and awaits its answer as an asyncio
future; the policy or the deadline tick cuts the batch.  The control loop
(``repro.serve.control``) ticks on the same timer and broadcasts adapted
policies to every tenant.

Byte identity: a direct stream's segments are produced by the tenant's
own ``IdealemSession`` fed exactly the wire chunks, so concatenated
front-end segments equal a direct session's output byte-for-byte -- the
loadgen (``scripts/loadgen.py``) and the golden-corpus integration test
pin this.
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro import api, obs
from repro.errors import (ApiError, NotFoundError, OverloadedError,
                          ReproError, error_from_payload, error_payload)

from .control import ControlLoop
from .engine import FlushPolicy
from .tenancy import TenantQuota, TenantRegistry

__all__ = ["ServeFrontend", "FrontendClient"]

_MAX_LINE = 16 << 10          # request line / single header cap
_MAX_HEADERS = 64

# ---------------------------------------------------------------- telemetry
_M_REQS = {}


def _m_requests(route: str):
    m = _M_REQS.get(route)
    if m is None:
        m = _M_REQS[route] = obs.registry().counter(
            "repro_frontend_requests_total", "front-end requests by route",
            labels={"route": route})
    return m


_M_LATENCY = {}


def _m_latency(route: str):
    m = _M_LATENCY.get(route)
    if m is None:
        m = _M_LATENCY[route] = obs.registry().histogram(
            "repro_frontend_request_seconds",
            "front-end request wall time by route", labels={"route": route})
    return m


_M_REJECT = {}


def _m_reject(code: str):
    m = _M_REJECT.get(code)
    if m is None:
        m = _M_REJECT[code] = obs.registry().counter(
            "repro_frontend_rejections_total",
            "typed admission/backpressure rejections by protocol code",
            labels={"code": code})
    return m


_M_CONNS = obs.registry().gauge(
    "repro_frontend_open_connections", "live front-end connections")
_M_TENANTS = obs.registry().gauge(
    "repro_frontend_tenants", "tenants the front end has state for")
_M_STAGED = obs.registry().gauge(
    "repro_frontend_staged_blocks",
    "blocks staged across every tenant's coalescer cohorts")
_M_BYTES = {
    d: obs.registry().counter(
        f"repro_frontend_bytes_{d}_total", f"front-end HTTP body bytes {d}")
    for d in ("in", "out")
}
_M_FORCE_FLUSH = obs.registry().counter(
    "repro_frontend_backpressure_flushes_total",
    "cohort flushes forced by global backpressure before rejecting")


class _DecodeMux:
    """Per-tenant bridge between wire decode requests and the batched
    ``DecompressionService``: stage, await the batch, resolve futures."""

    def __init__(self, tenant, loop: asyncio.AbstractEventLoop):
        self.tenant = tenant
        self.loop = loop
        self.futures: Dict[str, asyncio.Future] = {}
        self._seq = 0

    def submit(self, req: api.DecodeRangeRequest) -> asyncio.Future:
        rid = req.request_id
        if not rid:
            self._seq += 1
            rid = f"{self.tenant.id}:{self._seq}"
            req = api.DecodeRangeRequest(req.store_id, req.start_block,
                                         req.stop_block, req.channel, rid)
        if rid in self.futures:
            raise ApiError(f"request_id {rid!r} already pending")
        fut = self.loop.create_future()
        self.futures[rid] = fut
        svc = self.tenant.decomp
        try:
            answers = svc.submit(rid, req.store_id, req.start_block,
                                 req.stop_block, channel=req.channel)
        except Exception:
            self.futures.pop(rid, None)
            raise
        self._settle(svc, answers)
        return fut

    def poll(self) -> None:
        if self.tenant._decomp is None:
            return
        svc = self.tenant.decomp
        self._settle(svc, svc.poll())

    def drain(self) -> None:
        if self.tenant._decomp is None:
            return
        svc = self.tenant.decomp
        self._settle(svc, svc.flush())
        self._settle(svc, svc.drain())

    def _settle(self, svc, answers: Optional[Dict[str, np.ndarray]]) -> None:
        for rid, arr in (answers or {}).items():
            fut = self.futures.pop(rid, None)
            if fut is not None and not fut.done():
                fut.set_result(arr)
        if svc.last_errors:
            for rid in list(svc.last_errors):
                fut = self.futures.pop(rid, None)
                if fut is not None:
                    if not fut.done():
                        fut.set_exception(svc.last_errors.pop(rid))
                    else:
                        svc.last_errors.pop(rid)


class ServeFrontend:
    """The asyncio server; see the module docstring.

    ``clock`` is injectable (deadline flushes and token buckets measure
    with it) and the background timer can be disabled
    (``tick_interval_s=None``) so tests drive :meth:`tick` manually.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 policy: Optional[FlushPolicy] = None,
                 default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_config: Optional[api.CodecConfig] = None,
                 control: Optional[ControlLoop] = None,
                 run_control: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 decode_backend: str = "auto",
                 max_staged_blocks_total: Optional[int] = None,
                 tick_interval_s: Optional[float] = 0.005,
                 control_interval_s: float = 0.25,
                 request_timeout_s: float = 30.0,
                 max_body_bytes: int = 64 << 20):
        self.host = host
        self._want_port = port
        self.policy = policy if policy is not None else FlushPolicy(
            max_batch_blocks=1024, max_batch_streams=64, max_age_s=0.01)
        self.default_config = default_config or api.CodecConfig()
        self.tenants = TenantRegistry(
            default_quota=default_quota, quotas=quotas, clock=clock,
            policy=self.policy, decode_backend=decode_backend)
        self.control = control if control is not None else (
            ControlLoop(policy=self.policy) if run_control else None)
        self._clock = clock if clock is not None else time.monotonic
        self.max_staged_blocks_total = (
            max_staged_blocks_total if max_staged_blocks_total is not None
            else self.policy.max_batch_blocks * 8)
        self.tick_interval_s = tick_interval_s
        self.control_interval_s = control_interval_s
        self.request_timeout_s = request_timeout_s
        self.max_body_bytes = max_body_bytes
        self._server: Optional[asyncio.AbstractServer] = None
        self._ticker_task: Optional[asyncio.Task] = None
        self._muxes: Dict[str, _DecodeMux] = {}
        self._last_control = self._clock()
        self._closed = False

    # -------------------------------------------------------------- lifecycle
    @property
    def port(self) -> int:
        assert self._server is not None, "frontend not started"
        return self._server.sockets[0].getsockname()[1]

    async def start(self) -> "ServeFrontend":
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self._want_port)
        if self.tick_interval_s is not None:
            self._ticker_task = asyncio.get_running_loop().create_task(
                self._ticker())
        return self

    async def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._ticker_task is not None:
            self._ticker_task.cancel()
            try:
                await self._ticker_task
            except asyncio.CancelledError:
                pass
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for mux in self._muxes.values():
            mux.drain()
        self.tenants.close()

    async def __aenter__(self) -> "ServeFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # ------------------------------------------------------------------ tick
    async def _ticker(self) -> None:
        while True:
            await asyncio.sleep(self.tick_interval_s)
            try:
                self.tick()
            except Exception:  # pragma: no cover - keep the loop alive
                pass

    def tick(self) -> None:
        """One maintenance step: deadline-flush cohorts (the policy's
        ``max_age_s`` trigger), deliver completed decode batches, and --
        on its slower cadence -- run the control loop and broadcast any
        policy change to every tenant."""
        self.tenants.poll_flushes()
        for mux in self._muxes.values():
            mux.poll()
        _M_STAGED.set(self.tenants.staged_blocks)
        _M_TENANTS.set(len(self.tenants.tenants))
        if self.control is not None and (
                self._clock() - self._last_control
                >= self.control_interval_s):
            self._last_control = self._clock()
            decision = self.control.tick()
            if decision.changed:
                self.policy = decision.policy
                self.tenants.set_policy(decision.policy)

    # ------------------------------------------------------------ connection
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        _M_CONNS.inc()
        try:
            while True:
                try:
                    req = await self._read_request(reader)
                except (asyncio.IncompleteReadError, ConnectionError):
                    break
                if req is None:
                    break
                method, path, headers, body = req
                t0 = time.perf_counter()
                status, ctype, payload, extra = await self._dispatch(
                    method, path, headers, body)
                route = f"{method} {path.split('?')[0]}"
                _m_requests(route).inc()
                _m_latency(route).observe(time.perf_counter() - t0)
                keep = headers.get("connection", "keep-alive") != "close"
                head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}",
                        f"content-type: {ctype}",
                        f"content-length: {len(payload)}",
                        f"connection: {'keep-alive' if keep else 'close'}"]
                head += [f"{k}: {v}" for k, v in extra]
                writer.write(("\r\n".join(head) + "\r\n\r\n").encode()
                             + payload)
                _M_BYTES["out"].inc(len(payload))
                await writer.drain()
                if not keep:
                    break
        finally:
            _M_CONNS.dec()
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):  # pragma: no cover
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line:
            return None
        if len(line) > _MAX_LINE:
            raise ConnectionError("request line too long")
        try:
            method, path, _version = line.decode("ascii").split()
        except ValueError:
            raise ConnectionError("malformed request line")
        headers: Dict[str, str] = {}
        for _ in range(_MAX_HEADERS):
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            if len(h) > _MAX_LINE:
                raise ConnectionError("header too long")
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        else:
            raise ConnectionError("too many headers")
        length = int(headers.get("content-length", "0") or "0")
        if length > self.max_body_bytes:
            raise ConnectionError("body too large")
        body = await reader.readexactly(length) if length else b""
        _M_BYTES["in"].inc(len(body))
        return method.upper(), path, headers, body

    # -------------------------------------------------------------- dispatch
    async def _dispatch(self, method: str, path: str,
                        headers: Dict[str, str], body: bytes
                        ) -> Tuple[int, str, bytes, list]:
        try:
            if method == "GET":
                return self._dispatch_get(path)
            if method != "POST":
                raise ApiError(f"unsupported method {method}")
            if path not in _POST_ROUTES:
                raise NotFoundError(f"no route {path!r}")
            tenant_id = headers.get("x-tenant")
            if not tenant_id:
                raise ApiError("missing x-tenant header")
            lines = [ln for ln in body.split(b"\n") if ln.strip()]
            if not lines:
                raise ApiError("empty request body")
            if len(lines) > 1 and path != "/v1/feed":
                raise ApiError("JSON-lines batching is /v1/feed only")
            docs = []
            for ln in lines:
                try:
                    docs.append(json.loads(ln))
                except ValueError as exc:
                    raise ApiError(f"bad JSON: {exc}") from None
            outs = []
            status = 200
            for doc in docs:
                try:
                    outs.append(await self._apply(path, tenant_id, doc))
                except Exception as exc:  # noqa: BLE001 - typed below
                    st, payload = self._error(exc)
                    if len(docs) == 1:
                        status = st
                    outs.append(payload)
            payload = ("\n".join(json.dumps(o) for o in outs) + "\n").encode()
            extra = []
            if status in (429, 503) and len(outs) == 1:
                retry = outs[0].get("error", {}).get("retry_after_s")
                extra.append(("retry-after",
                              f"{max(retry or 0.05, 0.001):.3f}"))
            return status, "application/json", payload, extra
        except ReproError as exc:
            st, payload = self._error(exc)
            return (st, "application/json",
                    (json.dumps(payload) + "\n").encode(), [])
        except Exception as exc:  # pragma: no cover - defensive
            return (500, "application/json",
                    (json.dumps(error_payload(exc)) + "\n").encode(), [])

    def _dispatch_get(self, path: str) -> Tuple[int, str, bytes, list]:
        if path == "/healthz":
            return 200, "application/json", b'{"ok": true}\n', []
        if path == "/metrics":
            return (200, "text/plain; version=0.0.4",
                    obs.to_prometheus().encode(), [])
        if path == "/v1/stats":
            doc = {
                "tenants": {
                    t.id: {
                        "streams": sorted(t.streams),
                        "stores": sorted(t.store_ids),
                        "staged_blocks": t.staged_blocks,
                    } for t in self.tenants.tenants.values()},
                "staged_blocks_total": self.tenants.staged_blocks,
                "max_staged_blocks_total": self.max_staged_blocks_total,
            }
            return (200, "application/json",
                    (json.dumps(doc) + "\n").encode(), [])
        if path == "/v1/control":
            doc = {"policy": self.policy.as_dict(),
                   "control": (None if self.control is None
                               else self.control.status())}
            return (200, "application/json",
                    (json.dumps(doc) + "\n").encode(), [])
        raise NotFoundError(f"no route {path!r}")

    def _error(self, exc: Exception) -> Tuple[int, dict]:
        if isinstance(exc, ReproError):
            status = exc.http_status
        elif isinstance(exc, KeyError):
            status = 404
        elif isinstance(exc, (ValueError, IndexError, TypeError)):
            status = 400
        else:
            status = 500
        payload = error_payload(exc)
        if not isinstance(exc, ReproError):
            # preserve the typed 4xx split for non-Repro exceptions
            payload["error"]["code"] = ("not_found" if status == 404 else
                                        "bad_request" if status == 400 else
                                        "internal")
        code = payload["error"]["code"]
        if status in (429, 503) or code in ("quota_exceeded", "rate_limited",
                                            "overloaded"):
            _m_reject(code).inc()
        return status, payload

    # ---------------------------------------------------------------- routes
    async def _apply(self, path: str, tenant_id: str, doc: object) -> dict:
        tenant = self.tenants.get(tenant_id)
        if path == "/v1/open":
            if not isinstance(doc, dict):
                raise ApiError("open: expected object")
            extra = set(doc) - {"stream_id", "config", "coalesce"}
            if extra:
                raise ApiError(f"open: unknown field(s) {sorted(extra)}")
            sid = doc.get("stream_id")
            if not isinstance(sid, str) or not sid:
                raise ApiError("open: stream_id must be a non-empty string")
            cfg = (self.default_config if doc.get("config") is None
                   else api.CodecConfig.from_json(doc["config"]))
            tenant.open_stream(sid, cfg, coalesce=bool(doc.get("coalesce",
                                                               False)))
            return {"stream_id": sid, "coalesce": bool(doc.get("coalesce",
                                                               False)),
                    "config": cfg.to_json()}
        if path == "/v1/feed":
            req = api.CompressRequest.from_json(doc)
            self._admit_global(tenant)
            return tenant.feed(req).to_json()
        if path == "/v1/close":
            sid = self._stream_id(doc, "close")
            return tenant.close_stream(sid).to_json()
        if path == "/v1/collect":
            sid = self._stream_id(doc, "collect")
            st = tenant.stream(sid)
            return api.FeedResult(stream_id=sid,
                                  segment=st.collect()).to_json()
        if path == "/v1/attach":
            if not isinstance(doc, dict):
                raise ApiError("attach: expected object")
            extra = set(doc) - {"store_id", "container", "seed"}
            if extra:
                raise ApiError(f"attach: unknown field(s) {sorted(extra)}")
            store_id = doc.get("store_id")
            if not isinstance(store_id, str) or not store_id:
                raise ApiError("attach: store_id must be a non-empty string")
            blob = api.decode_bytes(doc.get("container"), "attach.container")
            tenant.attach_store(store_id, blob, seed=int(doc.get("seed", 0)))
            return {"store_id": store_id, "bytes": len(blob)}
        if path == "/v1/detach":
            store_id = doc.get("store_id") if isinstance(doc, dict) else None
            if not isinstance(store_id, str):
                raise ApiError("detach: store_id must be a string")
            tenant.detach_store(store_id)
            return {"store_id": store_id, "detached": True}
        if path == "/v1/decode":
            req = api.DecodeRangeRequest.from_json(doc)
            mux = self._mux(tenant)
            fut = mux.submit(req)
            try:
                values = await asyncio.wait_for(fut, self.request_timeout_s)
            except asyncio.TimeoutError:
                mux.futures.pop(req.request_id, None)
                raise OverloadedError(
                    "decode batch did not complete within "
                    f"{self.request_timeout_s}s") from None
            return api.RangeResult(
                request_id=req.request_id or "", values=values).to_json()
        raise NotFoundError(f"no route {path!r}")  # pragma: no cover

    @staticmethod
    def _stream_id(doc: object, what: str) -> str:
        sid = doc.get("stream_id") if isinstance(doc, dict) else None
        if not isinstance(sid, str) or not sid:
            raise ApiError(f"{what}: stream_id must be a non-empty string")
        return sid

    def _mux(self, tenant) -> _DecodeMux:
        mux = self._muxes.get(tenant.id)
        if mux is None:
            mux = self._muxes[tenant.id] = _DecodeMux(
                tenant, asyncio.get_running_loop())
        return mux

    def _admit_global(self, tenant) -> None:
        """Global backpressure ahead of per-tenant quotas: when every
        tenant's staged blocks together cross the server budget, first
        force-flush (the backpressure -> FlushPolicy feedback), and only
        reject if the pipeline is still saturated."""
        staged = self.tenants.staged_blocks
        if staged < self.max_staged_blocks_total:
            return
        _M_FORCE_FLUSH.inc()
        for t in sorted(self.tenants.tenants.values(),
                        key=lambda t: -t.staged_blocks):
            if t.staged_blocks == 0:
                break
            t.flush_all()
            if self.tenants.staged_blocks \
                    < self.max_staged_blocks_total:
                return
        raise OverloadedError(
            f"{staged} blocks staged across tenants (budget "
            f"{self.max_staged_blocks_total}); flush could not relieve it",
            retry_after_s=self.policy.max_age_s)


_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            429: "Too Many Requests", 500: "Internal Server Error",
            503: "Service Unavailable"}
_POST_ROUTES = {"/v1/open", "/v1/feed", "/v1/close", "/v1/collect",
                "/v1/attach", "/v1/detach", "/v1/decode"}


class FrontendClient:
    """Minimal asyncio client for the front end's protocol -- the test
    suite's and loadgen's wire driver.  One instance = one keep-alive
    connection = one tenant."""

    def __init__(self, host: str, port: int, tenant: str):
        self.host, self.port, self.tenant = host, port, tenant
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    async def connect(self) -> "FrontendClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        return self

    async def aclose(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionError, OSError):
                pass
            self._reader = self._writer = None

    async def __aenter__(self) -> "FrontendClient":
        return await self.connect()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # ------------------------------------------------------------ transport
    async def request_raw(self, method: str, path: str, body: bytes = b"",
                          ctype: str = "application/json"
                          ) -> Tuple[int, Dict[str, str], bytes]:
        if self._writer is None:
            await self.connect()
        head = (f"{method} {path} HTTP/1.1\r\n"
                f"host: {self.host}:{self.port}\r\n"
                f"x-tenant: {self.tenant}\r\n"
                f"content-type: {ctype}\r\n"
                f"content-length: {len(body)}\r\n\r\n")
        self._writer.write(head.encode() + body)
        await self._writer.drain()
        status_line = await self._reader.readline()
        if not status_line:
            raise ConnectionError("server closed the connection")
        status = int(status_line.split()[1])
        headers: Dict[str, str] = {}
        while True:
            h = await self._reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        length = int(headers.get("content-length", "0"))
        payload = (await self._reader.readexactly(length)) if length else b""
        return status, headers, payload

    async def post(self, path: str, doc: dict) -> dict:
        """Single-document POST; typed errors re-raised client-side."""
        status, _h, payload = await self.request_raw(
            "POST", path, (json.dumps(doc) + "\n").encode())
        out = json.loads(payload.decode())
        if status != 200 or "error" in out:
            raise error_from_payload(out)
        return out

    async def post_lines(self, path: str, docs) -> list:
        """JSON-lines POST (/v1/feed): one request, one response doc per
        line; per-line protocol errors come back as error docs, not
        raises."""
        body = ("\n".join(json.dumps(d) for d in docs) + "\n").encode()
        _status, _h, payload = await self.request_raw("POST", path, body)
        return [json.loads(ln) for ln in payload.decode().splitlines()
                if ln.strip()]

    # ------------------------------------------------------------ verb sugar
    async def open(self, stream_id: str,
                   config: Optional[api.CodecConfig] = None,
                   coalesce: bool = False) -> dict:
        doc = {"stream_id": stream_id, "coalesce": coalesce}
        if config is not None:
            doc["config"] = config.to_json()
        return await self.post("/v1/open", doc)

    async def feed(self, stream_id: str, samples) -> api.FeedResult:
        req = api.CompressRequest(stream_id=stream_id,
                                  samples=np.asarray(samples))
        return api.FeedResult.from_json(
            await self.post("/v1/feed", req.to_json()))

    async def close_stream(self, stream_id: str) -> api.FeedResult:
        return api.FeedResult.from_json(
            await self.post("/v1/close", {"stream_id": stream_id}))

    async def collect(self, stream_id: str) -> api.FeedResult:
        return api.FeedResult.from_json(
            await self.post("/v1/collect", {"stream_id": stream_id}))

    async def attach(self, store_id: str, container: bytes,
                     seed: int = 0) -> dict:
        return await self.post("/v1/attach", {
            "store_id": store_id, "container": api.encode_bytes(container),
            "seed": seed})

    async def decode(self, store_id: str, start_block: int, stop_block: int,
                     channel: int = 0,
                     request_id: str = "") -> api.RangeResult:
        req = api.DecodeRangeRequest(store_id, start_block, stop_block,
                                     channel, request_id)
        return api.RangeResult.from_json(
            await self.post("/v1/decode", req.to_json()))

    async def metrics(self) -> str:
        status, _h, payload = await self.request_raw("GET", "/metrics")
        if status != 200:
            raise ConnectionError(f"/metrics -> {status}")
        return payload.decode()

    async def stats(self) -> dict:
        status, _h, payload = await self.request_raw("GET", "/v1/stats")
        return json.loads(payload.decode())

    async def control(self) -> dict:
        status, _h, payload = await self.request_raw("GET", "/v1/control")
        return json.loads(payload.decode())
