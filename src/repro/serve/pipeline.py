"""Stage execution for the pipelined serving decode path (DESIGN.md Sec. 9).

A ``DecompressionService`` flush is four explicit stages:

    plan        host   seek + walk the covering chunks  (store.plan_windows)
    gather      host   one shared byte gather + padding (store.gather_parts,
                       decode.pad_parts)
    reconstruct device the unified engine dispatch      (decode.reconstruct)
    emit        host   slice answers per request, account stats/errors

Plan and gather run in the caller's thread at flush time; reconstruct is
handed to a *stage executor*; emit runs in the caller's thread when the
batch is collected.  ``StagePipeline`` bounds how many reconstruct batches
may be in flight (``FlushPolicy.pipeline_depth``): with depth 1 the
executor resolves inline and a flush returns its own answers -- the
alternating path, byte-identical to the pre-pipeline service.  With depth
2 the service plans/gathers batch N+1 on the host while the executor's
worker thread reconstructs batch N -- the overlap the ROADMAP asks for --
and a flush returns the answers of the batch that just *completed*.

Executors are injectable (``DecompressionService(executor=...)``), so
tests can substitute a deterministic fake whose futures run lazily at
collection time and prove the stage ordering without real threads.  Any
object with ``submit(fn, *args) -> future`` (future: ``result()``) and
``shutdown()`` is an executor.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, List, Optional, Tuple

from repro import obs

__all__ = ["StageFuture", "SyncExecutor", "ThreadStageExecutor",
           "StagePipeline"]

# A batch whose reconstruct stage was *lost* (the stage raised, or the
# executor died under it).  ``last_errors`` on the service tells the
# operator which requests; this counter makes the event scrapeable.
_M_STAGE_ERRORS = obs.registry().counter(
    "repro_serve_stage_errors_total",
    "in-flight batches collected with a stage exception")


class StageFuture:
    """Minimal completed-or-failed future: ``result()`` returns the stage's
    value or re-raises its exception."""

    __slots__ = ("_value", "_exc", "_event")

    def __init__(self):
        self._value = None
        self._exc: Optional[BaseException] = None
        self._event = threading.Event()

    def set_result(self, value) -> None:
        self._value = value
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self):
        self._event.wait()
        if self._exc is not None:
            raise self._exc
        return self._value


class SyncExecutor:
    """Inline executor: the stage runs in ``submit`` itself.  Depth-1
    pipelines use this -- the classic alternating flush."""

    def submit(self, fn: Callable, *args) -> StageFuture:
        fut = StageFuture()
        try:
            fut.set_result(fn(*args))
        except BaseException as e:  # delivered at result(), like a thread
            fut.set_exception(e)
        return fut

    def shutdown(self) -> None:
        pass


class ThreadStageExecutor:
    """One daemon worker thread draining a FIFO of stages.

    A single worker keeps device dispatch serialized (batches never race
    for the accelerator) while the caller thread stays free to plan and
    gather the next batch -- double-buffering, not fan-out.

    ``shutdown()`` is idempotent and safe after a worker death:
    ``DecompressionService.close()`` may run it twice (its own ``close``
    plus a ``with``-exit) or after the worker thread is already gone, and
    must never block or raise.  ``submit`` after shutdown -- or onto a
    dead worker -- delivers a failed future instead of enqueueing work
    nobody will run (a silent hang at ``result()``)."""

    def __init__(self, name: str = "repro-decode-pipeline"):
        self._queue: "queue.SimpleQueue" = queue.SimpleQueue()
        self._shutdown = False
        self._thread = threading.Thread(target=self._worker, name=name,
                                        daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            fut, fn, args = item
            try:
                fut.set_result(fn(*args))
            except BaseException as e:
                fut.set_exception(e)

    @property
    def alive(self) -> bool:
        return not self._shutdown and self._thread.is_alive()

    def submit(self, fn: Callable, *args) -> StageFuture:
        fut = StageFuture()
        if not self.alive:
            fut.set_exception(RuntimeError(
                "ThreadStageExecutor is shut down (or its worker died); "
                "stage not submitted"))
            return fut
        self._queue.put((fut, fn, args))
        return fut

    def shutdown(self) -> None:
        if self._shutdown:
            return
        self._shutdown = True
        if self._thread.is_alive():
            self._queue.put(None)


class StagePipeline:
    """Bounded window of in-flight reconstruct batches.

    ``push(meta, fn, *args)`` submits one batch's reconstruct stage and
    then collects (blocking, oldest first) until at most ``depth - 1``
    batches remain in flight -- so depth 1 collects the batch it just
    pushed, and depth 2 returns the *previous* batch while the new one
    runs.  ``drain()`` collects everything still in flight (shutdown, or
    a caller that wants answers now).  Collected batches come back as
    ``(meta, value, exc)`` -- a stage that raised is delivered, not
    swallowed, so the service can quarantine its requests.
    """

    def __init__(self, executor, depth: int = 1):
        if depth < 1:
            raise ValueError("pipeline depth must be >= 1")
        self.executor = executor
        self.depth = depth
        self._inflight: List[Tuple[Any, StageFuture]] = []

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def metas(self) -> List[Any]:
        """Metas of the batches currently in flight, oldest first (the
        service uses this to know which request ids are still live)."""
        return [meta for meta, _ in self._inflight]

    def push(self, meta, fn: Callable, *args
             ) -> List[Tuple[Any, Any, Optional[BaseException]]]:
        self._inflight.append((meta, self.executor.submit(fn, *args)))
        out = []
        while len(self._inflight) > self.depth - 1:
            out.append(self._collect())
        out.extend(self.collect_ready())  # finished early: deliver now
        return out

    def collect_ready(self) -> List[Tuple[Any, Any, Optional[BaseException]]]:
        """Collect batches that have ALREADY completed, oldest first,
        without blocking (collection is in-order: a finished batch behind
        an unfinished one waits so answers never reorder).  Futures
        without a ``done()`` (minimal injected fakes) are treated as not
        ready -- they surface at the depth window or ``drain()``."""
        out = []
        while (self._inflight
               and getattr(self._inflight[0][1], "done", lambda: False)()):
            out.append(self._collect())
        return out

    def drain(self) -> List[Tuple[Any, Any, Optional[BaseException]]]:
        out = []
        while self._inflight:
            out.append(self._collect())
        return out

    def _collect(self) -> Tuple[Any, Any, Optional[BaseException]]:
        meta, fut = self._inflight.pop(0)
        try:
            return meta, fut.result(), None
        except Exception as e:
            _M_STAGE_ERRORS.inc()
            return meta, None, e
