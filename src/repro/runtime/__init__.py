from .driver import FaultTolerantTrainer, FaultInjector, SimulatedFailure

__all__ = ["FaultTolerantTrainer", "FaultInjector", "SimulatedFailure"]
