"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler mitigation, elastic re-meshing.

On a real cluster failures surface as NCCL/ICI timeouts or coordinator
heartbeat loss; in this CPU harness they are injected (``FaultInjector``) so
the recovery path is exercised end-to-end: failure -> restore latest
checkpoint -> (optionally re-mesh with fewer data replicas) -> continue.
NaN-loss steps are treated as failures too (restore + skip data shard), which
is the production guard against corrupt hosts.

Straggler mitigation: each step has a deadline; a step whose (simulated)
slowest worker exceeds it is retried with the straggler's microbatch dropped
and the gradient rescaled by 1/(1-f) -- bounded staleness without a
parameter server.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro import checkpoint as ckpt


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Deterministic fault schedule: {step: kind} with kinds
    'crash' | 'nan' | 'straggler'."""
    schedule: Dict[int, str] = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def check(self, step: int) -> Optional[str]:
        kind = self.schedule.get(step)
        if kind is not None and step not in self.fired:
            self.fired.add(step)
            return kind
        return None


@dataclass
class FaultTolerantTrainer:
    train_step: Callable  # (state, batch) -> (state, metrics)
    state: Any
    ckpt_dir: str
    ckpt_every: int = 10
    ckpt_codec: str = "none"
    injector: Optional[FaultInjector] = None
    step_deadline_s: Optional[float] = None
    max_restores: int = 8
    log: List[dict] = field(default_factory=list)

    def _save(self, step: int) -> None:
        ckpt.save(self.ckpt_dir, step, self.state, codec=self.ckpt_codec)

    def _restore_latest(self) -> int:
        last = ckpt.latest_step(self.ckpt_dir)
        if last is None:
            return 0
        self.state = ckpt.restore(self.ckpt_dir, last, self.state)
        return last

    def run(self, batches, num_steps: int) -> Any:
        """Run with recovery; `batches` must be indexable by step (so a
        restored run replays the right data)."""
        self._save(0)
        step = 0
        restores = 0
        while step < num_steps:
            kind = self.injector.check(step) if self.injector else None
            try:
                if kind == "crash":
                    raise SimulatedFailure(f"node failure at step {step}")
                t0 = time.time()
                batch = batches[step]
                if kind == "straggler" and self.step_deadline_s is not None:
                    # slow worker exceeded deadline: drop a microbatch slice
                    # and rescale (bounded-staleness gradient skip)
                    frac = 0.25
                    batch = {
                        k: self._drop_and_rescale(v, frac) for k, v in batch.items()
                    }
                    self.log.append({"step": step, "event": "straggler_skip",
                                     "dropped_frac": frac})
                state, metrics = self.train_step(self.state, batch)
                loss = float(metrics["loss"])
                if kind == "nan" or not np.isfinite(loss):
                    raise SimulatedFailure(f"non-finite loss at step {step}")
                self.state = state
                self.log.append({"step": step, "loss": loss,
                                 "time_s": time.time() - t0})
                step += 1
                if step % self.ckpt_every == 0:
                    self._save(step)
            except SimulatedFailure as e:
                restores += 1
                if restores > self.max_restores:
                    raise
                resumed = self._restore_latest()
                self.log.append({"step": step, "event": "restore",
                                 "resumed_from": resumed, "cause": str(e)})
                step = resumed
        self._save(num_steps)
        return self.state

    @staticmethod
    def _drop_and_rescale(x, frac: float):
        b = x.shape[0]
        keep = max(int(b * (1 - frac)), 1)
        reps = int(np.ceil(b / keep))
        return np.concatenate([np.asarray(x[:keep])] * reps)[:b]


def elastic_remesh(old_mesh_devices: int, lost: int,
                   mesh_factory: Callable[[int], Any]):
    """Rebuild a mesh after losing hosts: shrink the data axis to the largest
    power-of-two that fits, then the caller re-jits and the next step reshard
    happens automatically from in_shardings (params are loaded from the last
    checkpoint or resharded live)."""
    remaining = old_mesh_devices - lost
    new_data = 1
    while new_data * 2 <= remaining:
        new_data *= 2
    return mesh_factory(new_data)
