"""repro -- IDEALEM statistical-similarity data reduction, at scale.

Curated public surface (``repro.__all__``).  Attribute access is lazy
(PEP 562): ``import repro`` pulls only the dependency-light wire layer
(``repro.api``, ``repro.errors``); the codec/device stack loads on first
use of a name that needs it, so clients of the wire types never pay the
jax import.

Layers (DESIGN.md Sec. 1, 14):

* ``repro.api``    -- wire-typed requests/responses + ``CodecConfig``
* ``repro.errors`` -- the ``ReproError`` hierarchy + protocol codes
* ``repro.core``   -- codec, sessions, decode engine, KS machinery
* ``repro.store``  -- indexed random-access containers
* ``repro.serve``  -- services, coalescer, front end, control loop
* ``repro.obs``    -- metrics registry, spans, exporters, SLOs
"""
from __future__ import annotations

import importlib

# name -> defining submodule; the curated public surface.
_PUBLIC = {
    # wire API (dependency-light)
    "CodecConfig": "repro.api",
    "CompressRequest": "repro.api",
    "FeedResult": "repro.api",
    "DecodeRangeRequest": "repro.api",
    "RangeResult": "repro.api",
    # error hierarchy
    "ReproError": "repro.errors",
    "StreamFormatError": "repro.errors",
    "ContainerFormatError": "repro.errors",
    "AutotuneCacheError": "repro.errors",
    "KernelShapeError": "repro.errors",
    "ApiError": "repro.errors",
    "AdmissionError": "repro.errors",
    "QuotaExceededError": "repro.errors",
    "RateLimitedError": "repro.errors",
    "OverloadedError": "repro.errors",
    "NotFoundError": "repro.errors",
    # codec core
    "IdealemCodec": "repro.core",
    "IdealemSession": "repro.core",
    "SessionStats": "repro.core",
    "critical_distance": "repro.core",
    "ks_pvalue": "repro.core",
    "ks_statistic": "repro.core",
    # store
    "Container": "repro.store",
    "ContainerWriter": "repro.store",
    "pack": "repro.store",
    "decode_range": "repro.store",
    "decode_ranges": "repro.store",
    "decode_channels": "repro.store",
    # serving
    "FlushPolicy": "repro.serve",
    "CompressionService": "repro.serve",
    "DecompressionService": "repro.serve",
    "StreamCoalescer": "repro.serve",
    "ServeFrontend": "repro.serve",
    "FrontendClient": "repro.serve",
    "TenantQuota": "repro.serve",
    "TenantRegistry": "repro.serve",
    "ControlLoop": "repro.serve",
}

# public submodules, importable both as attributes and via ``import repro.x``
_SUBMODULES = ("api", "errors", "core", "store", "serve", "obs", "kernels",
               "launch", "baselines", "data", "models")

__all__ = sorted(_PUBLIC) + list(_SUBMODULES)


def __getattr__(name: str):
    target = _PUBLIC.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value  # cache: next access skips this hook
        return value
    if name in _SUBMODULES:
        module = importlib.import_module(f"repro.{name}")
        globals()[name] = module
        return module
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
