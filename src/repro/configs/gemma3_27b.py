"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504,
vocab=262144, 5:1 local:global attention (window 1024), 128k context.
[hf:google/gemma-3-27b family]"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    local_global_ratio=5,
    local_window=1024,
    head_dim=128,
    rope_theta=1e6,
    act="gelu",
)

SMOKE = FULL.replace(
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, local_global_ratio=2, local_window=8, head_dim=16,
)
