"""Architecture registry: the 10 assigned archs (+ the paper's own config).

Each module defines ``FULL`` (the exact published config) and ``SMOKE``
(a reduced same-family config for CPU tests).  Shapes are defined here too:
every LM arch pairs with train_4k / prefill_32k / decode_32k / long_500k.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

ARCHS = [
    "granite_moe_1b_a400m",
    "mixtral_8x22b",
    "granite_3_8b",
    "gemma3_27b",
    "stablelm_12b",
    "glm4_9b",
    "zamba2_1_2b",
    "rwkv6_3b",
    "llama_3_2_vision_90b",
    "whisper_tiny",
]

# canonical ids as assigned (dash form) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-3-8b": "granite_3_8b",
    "gemma3-27b": "gemma3_27b",
    "stablelm-12b": "stablelm_12b",
    "glm4-9b": "glm4_9b",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-3b": "rwkv6_3b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-tiny": "whisper_tiny",
})


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with sub-quadratic context handling run long_500k; pure full-attention
# archs skip it (DESIGN.md Sec. 5)
LONG_CONTEXT_OK = {
    "mixtral_8x22b",      # SWA
    "gemma3_27b",         # 5:1 local:global
    "zamba2_1_2b",        # hybrid SSM (+ windowed shared attn)
    "rwkv6_3b",           # attention-free
}


def get_config(arch: str, smoke: bool = False):
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.FULL


def cells(arch: Optional[str] = None) -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) dry-run cells, honoring the long_500k skip list."""
    out = []
    for a in ([ALIASES.get(arch, arch)] if arch else ARCHS):
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_OK:
                continue
            out.append((a, s))
    return tuple(out)
