"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672,
vocab=128256; cross-attention image layers every 5th layer; vision frontend
is a STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision family]"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1024,
    rope_theta=5e5,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=128, cross_attn_every=2, num_image_tokens=8,
)
