"""whisper-tiny [audio]: 4L d_model=384 6H d_ff=1536 vocab=51865; enc-dec
with conv frontend STUB (input_specs provides precomputed frame embeddings,
1500 frames).  [arXiv:2212.04356]"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,           # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
)

SMOKE = FULL.replace(
    num_layers=2, encoder_layers=2, encoder_seq=16, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=128,
)
