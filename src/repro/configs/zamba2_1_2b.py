"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192,
ssm_state=64; Mamba2 backbone + weight-shared attention block applied every
6 layers.  Shared attn uses a 4096 sliding window so the 500k decode cell is
feasible (DESIGN.md Sec. 6, adaptation #4).  [arXiv:2411.15242]"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    sliding_window=4096,
    rope_theta=1e4,
)

SMOKE = FULL.replace(
    num_layers=7, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=128, ssm_state=16, ssm_head_dim=16, attn_every=3,
    sliding_window=16, ssm_chunk=8,
)
