"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960,
vocab=65536; Finch data-dependent decay.  [arXiv:2404.05892]"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,       # d_model / 64 wkv heads
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    ssm_head_dim=64,
    rwkv_chunk=32,
)

SMOKE = FULL.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=128, ssm_head_dim=16, rwkv_chunk=8,
)
