"""The paper's own experiment configuration (Table I): IDEALEM parameters
for uPMU magnitude (standard mode) and phase angle (residual/delta mode)."""
from repro.core import IdealemCodec

# MAG channels: standard mode, B=32, D=255, alpha=0.01 (Sec. VII-A)
MAG = dict(mode="std", block_size=32, num_dict=255, alpha=0.01, rel_tol=0.5)

# ANG channels: residual mode, B=112, D=255, alpha=0.01, range [0, 360)
ANG_RESIDUAL = dict(mode="residual", block_size=112, num_dict=255, alpha=0.01,
                    rel_tol=0.5, value_range=(0.0, 360.0))
ANG_DELTA = dict(mode="delta", block_size=112, num_dict=255, alpha=0.01,
                 rel_tol=0.5, value_range=(0.0, 360.0))


def mag_codec(**kw) -> IdealemCodec:
    return IdealemCodec(**{**MAG, **kw})


def ang_codec(delta: bool = False, **kw) -> IdealemCodec:
    base = ANG_DELTA if delta else ANG_RESIDUAL
    return IdealemCodec(**{**base, **kw})
