"""Exporters: Prometheus text exposition, JSON snapshot, and a parser.

``to_prometheus`` serializes a :class:`~repro.obs.metrics.MetricsRegistry`
into the text exposition format (``# HELP`` / ``# TYPE`` headers,
cumulative ``_bucket{le=...}`` rows, ``_sum`` / ``_count``).
``parse_prometheus`` reads that format back into a flat
``{(name, label_items): value}`` map -- the round-trip check used by the
golden-format tests and ``scripts/obs_tool.py selfcheck``.

``to_json`` bundles the registry snapshot with the span-ring snapshot
into one JSON-ready document; ``benchmarks/run.py --json`` embeds it as
the ``metrics_snapshot`` section so bench artifacts carry the same
telemetry the live system exports.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from dataclasses import dataclass, field

from .metrics import Histogram, MetricsRegistry, registry as default_registry
from .trace import SpanTracer, tracer as default_tracer

__all__ = ["to_prometheus", "to_json", "parse_prometheus", "selfcheck",
           "histogram_quantile", "quantile", "quantile_from_parsed",
           "SloSpec", "SloResult", "evaluate_slos"]

SNAPSHOT_VERSION = 1


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing ``.0`` so
    counter rows read naturally; +Inf spelled the exposition way."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 2 ** 53:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _labels_str(items: Tuple[Tuple[str, str], ...],
                extra: Optional[Tuple[Tuple[str, str], ...]] = None) -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in items]
    if extra:
        parts += [f'{k}="{_escape(v)}"' for k, v in extra]
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus(reg: Optional[MetricsRegistry] = None) -> str:
    reg = reg if reg is not None else default_registry()
    lines = []
    for fam in sorted(reg.families(), key=lambda f: f.name):
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for items, child in sorted(fam.children.items()):
            if fam.kind == "histogram":
                counts = child.bucket_counts()
                cum = 0
                for bound, c in zip(child.bounds, counts[:-1]):
                    cum += c
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_labels_str(items, (('le', _fmt(bound)),))}"
                        f" {cum}")
                cum += counts[-1]
                lines.append(
                    f"{fam.name}_bucket"
                    f"{_labels_str(items, (('le', '+Inf'),))} {cum}")
                lines.append(
                    f"{fam.name}_sum{_labels_str(items)} {_fmt(child.sum)}")
                lines.append(
                    f"{fam.name}_count{_labels_str(items)} {child.count}")
            else:
                lines.append(
                    f"{fam.name}{_labels_str(items)} {_fmt(child.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def to_json(reg: Optional[MetricsRegistry] = None,
            trc: Optional[SpanTracer] = None,
            include_spans: bool = True) -> dict:
    reg = reg if reg is not None else default_registry()
    trc = trc if trc is not None else default_tracer()
    doc = {"version": SNAPSHOT_VERSION, "metrics": reg.snapshot()}
    if include_spans:
        doc["spans"] = trc.snapshot()
    return doc


def _parse_labels(s: str) -> Tuple[Tuple[str, str], ...]:
    # exposition label block: {k="v",k2="v2"} with \\ \n \" escapes
    items = []
    i = 0
    while i < len(s):
        eq = s.index("=", i)
        key = s[i:eq].lstrip(",").strip()
        assert s[eq + 1] == '"', f"malformed label value at {s[eq:]!r}"
        j = eq + 2
        val = []
        while s[j] != '"':
            if s[j] == "\\":
                nxt = s[j + 1]
                val.append({"n": "\n", "\\": "\\", '"': '"'}[nxt])
                j += 2
            else:
                val.append(s[j])
                j += 1
        items.append((key, "".join(val)))
        i = j + 1
    return tuple(sorted(items))


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple[Tuple[str, str],
                                                         ...]], float]:
    """Exposition text -> ``{(sample_name, label_items): value}``.
    Histogram series keep their expanded ``_bucket``/``_sum``/``_count``
    names and the ``le`` label, exactly as exposed."""
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name = line[:line.index("{")]
            rest = line[line.index("{") + 1:]
            labels_s, _, value_s = rest.rpartition("}")
            items = _parse_labels(labels_s)
        else:
            name, _, value_s = line.partition(" ")
            items = ()
        value_s = value_s.strip()
        if value_s == "+Inf":
            value = math.inf
        elif value_s == "-Inf":
            value = -math.inf
        else:
            value = float(value_s)
        out[(name, items)] = value
    return out


# ------------------------------------------------------------ SLO evaluation
# Quantile estimation over fixed-bucket histograms, Prometheus
# histogram_quantile-style: find the bucket the target rank falls in and
# interpolate linearly inside it.  This is what the serving control loop
# (repro.serve.control) steers on and what the loadgen SLO gate asserts,
# so both read the SAME math from here (ISSUE 10).

def histogram_quantile(bounds, counts, q: float) -> Optional[float]:
    """Estimate the ``q``-quantile from per-bucket (non-cumulative)
    ``counts`` -- one count per finite upper ``bound`` plus a trailing
    +Inf slot, exactly :meth:`Histogram.bucket_counts` shape.  Returns
    ``None`` on an empty histogram.  Ranks landing in the +Inf bucket
    clamp to the largest finite bound (the estimate is then a floor)."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    total = sum(counts)
    if total <= 0:
        return None
    rank = q * total
    cum = 0.0
    for i, c in enumerate(counts[:-1]):
        cum += c
        if cum >= rank and c > 0:
            hi = bounds[i]
            lo = bounds[i - 1] if i > 0 else 0.0
            frac = (rank - (cum - c)) / c
            return lo + (hi - lo) * frac
    return float(bounds[-1]) if bounds else None


def quantile(name: str, q: float,
             labels: Optional[Dict[str, str]] = None,
             reg: Optional[MetricsRegistry] = None) -> Optional[float]:
    """``q``-quantile of a live registry histogram child (``None`` when
    the family/child does not exist or holds no observations)."""
    reg = reg if reg is not None else default_registry()
    items = tuple(sorted((labels or {}).items()))
    for fam in reg.families():
        if fam.name == name and fam.kind == "histogram":
            child = fam.children.get(items)
            if isinstance(child, Histogram):
                return histogram_quantile(child.bounds,
                                          child.bucket_counts(), q)
    return None


def quantile_from_parsed(parsed, name: str, q: float,
                         labels: Optional[Dict[str, str]] = None
                         ) -> Optional[float]:
    """``q``-quantile from :func:`parse_prometheus` output -- the scrape
    side of the same estimate (cumulative ``le`` series converted back to
    per-bucket counts first)."""
    want = dict(labels or {})
    series = []
    for (sample, items), value in parsed.items():
        if sample != f"{name}_bucket":
            continue
        d = dict(items)
        le = d.pop("le", None)
        if le is None or d != want:
            continue
        bound = math.inf if le == "+Inf" else float(le)
        series.append((bound, value))
    if not series:
        return None
    series.sort()
    bounds = [b for b, _ in series if not math.isinf(b)]
    cum = [v for _, v in series]
    counts = [cum[0]] + [cum[i] - cum[i - 1] for i in range(1, len(cum))]
    return histogram_quantile(bounds, counts, q)


@dataclass(frozen=True)
class SloSpec:
    """One latency/size objective: ``quantile`` of histogram ``name``
    (optionally a labeled child) must stay <= ``max_value``."""

    name: str
    quantile: float
    max_value: float
    labels: Dict[str, str] = field(default_factory=dict)

    def describe(self) -> str:
        lbl = ("{" + ",".join(f"{k}={v}" for k, v in
                              sorted(self.labels.items())) + "}"
               if self.labels else "")
        return f"p{self.quantile * 100:g} {self.name}{lbl}"


@dataclass(frozen=True)
class SloResult:
    spec: SloSpec
    value: Optional[float]  # None: histogram absent/empty (not a breach)
    ok: bool

    def describe(self) -> str:
        v = "n/a" if self.value is None else f"{self.value:.6g}"
        verdict = "ok" if self.ok else "BREACH"
        return (f"{self.spec.describe()} = {v} "
                f"(<= {self.spec.max_value:.6g}) {verdict}")


def evaluate_slos(specs, reg: Optional[MetricsRegistry] = None,
                  parsed=None) -> list:
    """Evaluate SLO specs against a live registry (default) or a parsed
    scrape (``parsed=parse_prometheus(text)``).  An absent or empty
    histogram yields ``value=None, ok=True`` -- no traffic is not a
    breach; gate on traffic separately if it should be."""
    out = []
    for spec in specs:
        if parsed is not None:
            v = quantile_from_parsed(parsed, spec.name, spec.quantile,
                                     spec.labels)
        else:
            v = quantile(spec.name, spec.quantile, spec.labels, reg)
        out.append(SloResult(spec, v, v is None or v <= spec.max_value))
    return out


def selfcheck(reg: Optional[MetricsRegistry] = None,
              trc: Optional[SpanTracer] = None) -> list:
    """Exporter round trip on a registry (default: a scratch one with all
    three instrument kinds populated).  Returns a list of problem
    strings; empty means healthy."""
    problems = []
    if reg is None:
        reg = MetricsRegistry()
        reg.counter("repro_check_ops_total", "ops",
                    labels={"op": 'weird"\\label\n'}).inc(3)
        reg.gauge("repro_check_depth", "depth").set(-2.5)
        h = reg.histogram("repro_check_lat_seconds", "lat")
        for v in (1e-6, 3e-4, 0.25, 99.0):
            h.observe(v)
    text = to_prometheus(reg)
    try:
        parsed = parse_prometheus(text)
    except Exception as exc:  # pragma: no cover - defensive
        return [f"exposition does not parse: {exc!r}"]
    # every sample the registry holds must survive the round trip exactly
    for fam in reg.families():
        for items, child in fam.children.items():
            if fam.kind == "histogram":
                counts = child.bucket_counts()
                want = {("_count", items): float(child.count),
                        ("_sum", items): child.sum}
                for (suffix, it), v in want.items():
                    got = parsed.get((fam.name + suffix, it))
                    if got != v:
                        problems.append(
                            f"{fam.name}{suffix}{dict(it)}: {got} != {v}")
                inf_key = (fam.name + "_bucket",
                           tuple(sorted(items + (("le", "+Inf"),))))
                if parsed.get(inf_key) != float(sum(counts)):
                    problems.append(f"{fam.name}_bucket le=+Inf mismatch")
            else:
                got = parsed.get((fam.name, items))
                if got != child.value:
                    problems.append(
                        f"{fam.name}{dict(items)}: {got} != {child.value}")
    # the JSON document must be round-trippable too
    import json
    try:
        json.loads(json.dumps(to_json(reg, trc)))
    except (TypeError, ValueError) as exc:
        problems.append(f"JSON snapshot not serializable: {exc!r}")
    return problems
