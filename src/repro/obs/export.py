"""Exporters: Prometheus text exposition, JSON snapshot, and a parser.

``to_prometheus`` serializes a :class:`~repro.obs.metrics.MetricsRegistry`
into the text exposition format (``# HELP`` / ``# TYPE`` headers,
cumulative ``_bucket{le=...}`` rows, ``_sum`` / ``_count``).
``parse_prometheus`` reads that format back into a flat
``{(name, label_items): value}`` map -- the round-trip check used by the
golden-format tests and ``scripts/obs_tool.py selfcheck``.

``to_json`` bundles the registry snapshot with the span-ring snapshot
into one JSON-ready document; ``benchmarks/run.py --json`` embeds it as
the ``metrics_snapshot`` section so bench artifacts carry the same
telemetry the live system exports.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from .metrics import MetricsRegistry, registry as default_registry
from .trace import SpanTracer, tracer as default_tracer

__all__ = ["to_prometheus", "to_json", "parse_prometheus", "selfcheck"]

SNAPSHOT_VERSION = 1


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing ``.0`` so
    counter rows read naturally; +Inf spelled the exposition way."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 2 ** 53:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _labels_str(items: Tuple[Tuple[str, str], ...],
                extra: Optional[Tuple[Tuple[str, str], ...]] = None) -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in items]
    if extra:
        parts += [f'{k}="{_escape(v)}"' for k, v in extra]
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus(reg: Optional[MetricsRegistry] = None) -> str:
    reg = reg if reg is not None else default_registry()
    lines = []
    for fam in sorted(reg.families(), key=lambda f: f.name):
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for items, child in sorted(fam.children.items()):
            if fam.kind == "histogram":
                counts = child.bucket_counts()
                cum = 0
                for bound, c in zip(child.bounds, counts[:-1]):
                    cum += c
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_labels_str(items, (('le', _fmt(bound)),))}"
                        f" {cum}")
                cum += counts[-1]
                lines.append(
                    f"{fam.name}_bucket"
                    f"{_labels_str(items, (('le', '+Inf'),))} {cum}")
                lines.append(
                    f"{fam.name}_sum{_labels_str(items)} {_fmt(child.sum)}")
                lines.append(
                    f"{fam.name}_count{_labels_str(items)} {child.count}")
            else:
                lines.append(
                    f"{fam.name}{_labels_str(items)} {_fmt(child.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def to_json(reg: Optional[MetricsRegistry] = None,
            trc: Optional[SpanTracer] = None,
            include_spans: bool = True) -> dict:
    reg = reg if reg is not None else default_registry()
    trc = trc if trc is not None else default_tracer()
    doc = {"version": SNAPSHOT_VERSION, "metrics": reg.snapshot()}
    if include_spans:
        doc["spans"] = trc.snapshot()
    return doc


def _parse_labels(s: str) -> Tuple[Tuple[str, str], ...]:
    # exposition label block: {k="v",k2="v2"} with \\ \n \" escapes
    items = []
    i = 0
    while i < len(s):
        eq = s.index("=", i)
        key = s[i:eq].lstrip(",").strip()
        assert s[eq + 1] == '"', f"malformed label value at {s[eq:]!r}"
        j = eq + 2
        val = []
        while s[j] != '"':
            if s[j] == "\\":
                nxt = s[j + 1]
                val.append({"n": "\n", "\\": "\\", '"': '"'}[nxt])
                j += 2
            else:
                val.append(s[j])
                j += 1
        items.append((key, "".join(val)))
        i = j + 1
    return tuple(sorted(items))


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple[Tuple[str, str],
                                                         ...]], float]:
    """Exposition text -> ``{(sample_name, label_items): value}``.
    Histogram series keep their expanded ``_bucket``/``_sum``/``_count``
    names and the ``le`` label, exactly as exposed."""
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name = line[:line.index("{")]
            rest = line[line.index("{") + 1:]
            labels_s, _, value_s = rest.rpartition("}")
            items = _parse_labels(labels_s)
        else:
            name, _, value_s = line.partition(" ")
            items = ()
        value_s = value_s.strip()
        if value_s == "+Inf":
            value = math.inf
        elif value_s == "-Inf":
            value = -math.inf
        else:
            value = float(value_s)
        out[(name, items)] = value
    return out


def selfcheck(reg: Optional[MetricsRegistry] = None,
              trc: Optional[SpanTracer] = None) -> list:
    """Exporter round trip on a registry (default: a scratch one with all
    three instrument kinds populated).  Returns a list of problem
    strings; empty means healthy."""
    problems = []
    if reg is None:
        reg = MetricsRegistry()
        reg.counter("repro_check_ops_total", "ops",
                    labels={"op": 'weird"\\label\n'}).inc(3)
        reg.gauge("repro_check_depth", "depth").set(-2.5)
        h = reg.histogram("repro_check_lat_seconds", "lat")
        for v in (1e-6, 3e-4, 0.25, 99.0):
            h.observe(v)
    text = to_prometheus(reg)
    try:
        parsed = parse_prometheus(text)
    except Exception as exc:  # pragma: no cover - defensive
        return [f"exposition does not parse: {exc!r}"]
    # every sample the registry holds must survive the round trip exactly
    for fam in reg.families():
        for items, child in fam.children.items():
            if fam.kind == "histogram":
                counts = child.bucket_counts()
                want = {("_count", items): float(child.count),
                        ("_sum", items): child.sum}
                for (suffix, it), v in want.items():
                    got = parsed.get((fam.name + suffix, it))
                    if got != v:
                        problems.append(
                            f"{fam.name}{suffix}{dict(it)}: {got} != {v}")
                inf_key = (fam.name + "_bucket",
                           tuple(sorted(items + (("le", "+Inf"),))))
                if parsed.get(inf_key) != float(sum(counts)):
                    problems.append(f"{fam.name}_bucket le=+Inf mismatch")
            else:
                got = parsed.get((fam.name, items))
                if got != child.value:
                    problems.append(
                        f"{fam.name}{dict(items)}: {got} != {child.value}")
    # the JSON document must be round-trippable too
    import json
    try:
        json.loads(json.dumps(to_json(reg, trc)))
    except (TypeError, ValueError) as exc:
        problems.append(f"JSON snapshot not serializable: {exc!r}")
    return problems
