"""Span tracer: monotonic-clock timed spans with nesting and a ring.

``span("encode.flush", attrs={...})`` is a context manager: it stamps
``time.perf_counter()`` on entry and exit, records parent/child nesting
through a thread-local stack (each thread has its own span stack, so
pipeline worker threads nest correctly and independently), and appends
the finished span to a bounded ring buffer -- old spans fall off, the
tracer never grows without bound.

Two record kinds share the ring:

* spans -- have a duration, a parent, and an ok/error status (an
  exception propagating out of the ``with`` body marks the span
  ``error`` and re-raises);
* events -- zero-duration structured facts (``event()``), e.g. the
  adaptive selector's mode-switch :class:`~repro.core.select.SelectionEvent`.

Exporters registered via ``add_exporter`` are called synchronously with
each finished record (Span instance); an exporter that raises is
dropped from the list rather than poisoning the hot path.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "SpanTracer", "tracer", "span", "event"]


class Span:
    __slots__ = ("name", "attrs", "span_id", "parent_id", "thread",
                 "start_s", "duration_s", "status", "kind")

    def __init__(self, name: str, attrs: Optional[Dict], span_id: int,
                 parent_id: Optional[int], thread: str, start_s: float,
                 duration_s: float, status: str, kind: str) -> None:
        self.name = name
        self.attrs = attrs or {}
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.start_s = start_s
        self.duration_s = duration_s
        self.status = status
        self.kind = kind

    def as_dict(self) -> dict:
        return {"name": self.name, "attrs": dict(self.attrs),
                "span_id": self.span_id, "parent_id": self.parent_id,
                "thread": self.thread, "start_s": self.start_s,
                "duration_s": self.duration_s, "status": self.status,
                "kind": self.kind}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, {self.duration_s * 1e6:.1f}us, "
                f"{self.status})")


class SpanTracer:
    """Bounded-retention tracer; see the module docstring."""

    def __init__(self, capacity: int = 2048, enabled: bool = True) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = enabled
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._exporters: List[Callable[[Span], None]] = []

    # ------------------------------------------------------------- recording
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _finish(self, rec: Span) -> None:
        with self._lock:
            self._ring.append(rec)
            exporters = list(self._exporters)
        for fn in exporters:
            try:
                fn(rec)
            except Exception:
                self.remove_exporter(fn)

    @contextmanager
    def span(self, name: str, attrs: Optional[Dict] = None):
        if not self.enabled:
            yield None
            return
        stack = self._stack()
        parent_id = stack[-1] if stack else None
        span_id = next(self._ids)
        stack.append(span_id)
        start = time.perf_counter()
        status = "ok"
        try:
            yield span_id
        except BaseException:
            status = "error"
            raise
        finally:
            dur = time.perf_counter() - start
            stack.pop()
            self._finish(Span(name, attrs, span_id, parent_id,
                              threading.current_thread().name, start, dur,
                              status, "span"))

    def event(self, name: str, attrs: Optional[Dict] = None) -> None:
        """Zero-duration structured record, nested under the current span
        of the calling thread (if any)."""
        if not self.enabled:
            return
        stack = self._stack()
        self._finish(Span(name, attrs, next(self._ids),
                          stack[-1] if stack else None,
                          threading.current_thread().name,
                          time.perf_counter(), 0.0, "ok", "event"))

    # ------------------------------------------------------------- consumers
    def add_exporter(self, fn: Callable[[Span], None]) -> None:
        with self._lock:
            if fn not in self._exporters:
                self._exporters.append(fn)

    def remove_exporter(self, fn: Callable[[Span], None]) -> None:
        with self._lock:
            try:
                self._exporters.remove(fn)
            except ValueError:
                pass

    def records(self, name: Optional[str] = None,
                kind: Optional[str] = None) -> List[Span]:
        """Finished records, oldest first, optionally filtered."""
        with self._lock:
            recs = list(self._ring)
        if name is not None:
            recs = [r for r in recs if r.name == name]
        if kind is not None:
            recs = [r for r in recs if r.kind == kind]
        return recs

    def snapshot(self) -> List[dict]:
        return [r.as_dict() for r in self.records()]

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()


# Process-default tracer, sibling of the default metrics registry.
_DEFAULT = SpanTracer()


def tracer() -> SpanTracer:
    return _DEFAULT


def span(name: str, attrs: Optional[Dict] = None):
    """``with obs.span("serve.plan", attrs={"seq": 3}): ...`` against the
    default tracer."""
    return _DEFAULT.span(name, attrs)


def event(name: str, attrs: Optional[Dict] = None) -> None:
    _DEFAULT.event(name, attrs)
