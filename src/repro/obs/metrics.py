"""Dependency-free metrics registry: counters, gauges, histograms.

The runtime telemetry substrate (ISSUE 8).  Three instrument kinds,
Prometheus-shaped so the text exposition in :mod:`repro.obs.export` is a
direct serialization:

* :class:`Counter` -- monotone float accumulator (``inc``).
* :class:`Gauge` -- settable level (``set`` / ``inc`` / ``dec``).
* :class:`Histogram` -- fixed-bucket distribution with cumulative bucket
  counts, ``sum`` and ``count``.  The default bucket ladder is
  log-spaced for latencies (1 us .. 10 s, half-decade steps).

Instruments hang off a :class:`MetricsRegistry` in *families*: one family
per metric name, one child per label-set.  ``registry()`` returns the
process-default registry that all repro layers write into; tests build
private registries when they need isolation.

Concurrency: a registry lock guards family/child creation, and every
child carries its own lock for value updates -- writers on different
metrics never contend.  ``set_enabled(False)`` turns every write into an
early return (the metrics-off arm of the overhead bench).

Naming scheme (DESIGN.md Sec. 12): ``repro_<layer>_<name>``, counters
suffixed ``_total``, latency histograms suffixed ``_seconds``.
"""
from __future__ import annotations

import bisect
import math
import re
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS", "registry", "set_enabled",
]

# 1 us .. 10 s in half-decade steps: wide enough for a pallas dispatch and
# a cold jit compile alike, small enough (15 buckets) to export everywhere.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 2.0), 10) for e in range(-12, 3))

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

LabelItems = Tuple[Tuple[str, str], ...]


def _label_items(labels: Optional[Dict[str, str]]) -> LabelItems:
    if not labels:
        return ()
    items = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
    for k, _ in items:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name: {k!r}")
    return items


class _Child:
    """Common base: one (name, label-set) instrument with its own lock."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str,
                 labels: LabelItems) -> None:
        self._registry = registry
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()


class Counter(_Child):
    """Monotone accumulator.  ``inc`` with a negative amount raises."""

    kind = "counter"

    def __init__(self, registry, name, labels) -> None:
        super().__init__(registry, name, labels)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge(_Child):
    """Settable level (in-flight depth, open streams, ...)."""

    kind = "gauge"

    def __init__(self, registry, name, labels) -> None:
        super().__init__(registry, name, labels)
        self._value = 0.0

    def set(self, value: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram(_Child):
    """Fixed-bucket distribution.  ``bucket_counts`` are per-bucket (not
    cumulative); the exporter cumulates for the ``le`` convention.  A
    value lands in the first bucket whose upper bound is >= value
    (Prometheus ``le`` semantics); larger values land in +Inf."""

    kind = "histogram"

    def __init__(self, registry, name, labels,
                 buckets: Sequence[float]) -> None:
        super().__init__(registry, name, labels)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError("histogram buckets must be sorted and unique")
        if any(not math.isfinite(b) for b in bounds):
            raise ValueError("histogram buckets must be finite (+Inf is "
                             "implicit)")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # last slot = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        if not self._registry.enabled:
            return
        value = float(value)
        idx = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def bucket_counts(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(self._counts)

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._sum = 0.0
            self._count = 0


class _Family:
    def __init__(self, name: str, kind: str, help: str,
                 buckets: Optional[Tuple[float, ...]]) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = buckets
        self.children: Dict[LabelItems, _Child] = {}


class MetricsRegistry:
    """Families of named instruments; see the module docstring."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    # ------------------------------------------------------------ factories
    def _child(self, name: str, kind: str, help: str,
               labels: Optional[Dict[str, str]],
               buckets: Optional[Sequence[float]] = None) -> _Child:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name: {name!r}")
        items = _label_items(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(
                    name, kind, help,
                    tuple(float(b) for b in buckets) if buckets else None)
                self._families[name] = fam
            else:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}")
                if kind == "histogram" and buckets is not None \
                        and fam.buckets != tuple(float(b) for b in buckets):
                    raise ValueError(
                        f"metric {name!r} already registered with different "
                        "buckets")
                if help and not fam.help:
                    fam.help = help
            child = fam.children.get(items)
            if child is None:
                if kind == "counter":
                    child = Counter(self, name, items)
                elif kind == "gauge":
                    child = Gauge(self, name, items)
                else:
                    child = Histogram(self, name, items,
                                      fam.buckets or DEFAULT_LATENCY_BUCKETS)
                fam.children[items] = child
            return child

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._child(name, "counter", help, labels)  # type: ignore

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._child(name, "gauge", help, labels)  # type: ignore

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        return self._child(name, "histogram", help, labels,  # type: ignore
                           buckets)

    # ------------------------------------------------------------ inspection
    def families(self) -> Iterable[_Family]:
        with self._lock:
            return list(self._families.values())

    def snapshot(self) -> dict:
        """Point-in-time value dump: ``{name: {"kind", "help", "values"}}``
        where ``values`` is a list of ``{"labels": {...}, ...}`` entries
        (counters/gauges carry ``value``; histograms carry ``sum``,
        ``count`` and per-bucket ``buckets`` keyed by upper bound, with
        ``"+Inf"`` last).  Plain dicts/floats only -- JSON-ready."""
        out: dict = {}
        for fam in self.families():
            values = []
            for items, child in sorted(fam.children.items()):
                entry: dict = {"labels": dict(items)}
                if isinstance(child, Histogram):
                    counts = child.bucket_counts()
                    with child._lock:
                        entry["sum"] = child._sum
                        entry["count"] = child._count
                    entry["buckets"] = {
                        **{repr(b): c for b, c in
                           zip(child.bounds, counts[:-1])},
                        "+Inf": counts[-1]}
                else:
                    entry["value"] = child.value  # type: ignore[attr-defined]
                values.append(entry)
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "values": values}
        return out

    def get_value(self, name: str,
                  labels: Optional[Dict[str, str]] = None) -> float:
        """Convenience for tests/tools: current value of a counter/gauge
        (0.0 when the family or child does not exist yet)."""
        items = _label_items(labels)
        with self._lock:
            fam = self._families.get(name)
            child = fam.children.get(items) if fam else None
        if child is None or isinstance(child, Histogram):
            return 0.0
        return child.value  # type: ignore[attr-defined]

    def reset(self) -> None:
        """Zero every instrument, keeping families and handles alive (a
        cached ``Counter`` reference stays valid across resets)."""
        for fam in self.families():
            with self._lock:
                children = list(fam.children.values())
            for child in children:
                child.reset()  # type: ignore[attr-defined]


# Process-default registry: all repro layers write here.  Kept module
# level (not per-session) so one snapshot sees encode, decode, store and
# serving at once -- the acceptance shape of ISSUE 8.
_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _DEFAULT


def set_enabled(flag: bool) -> bool:
    """Toggle the default registry's writes; returns the previous state.
    The metrics-off arm of the overhead bench."""
    prev = _DEFAULT.enabled
    _DEFAULT.enabled = bool(flag)
    return prev
