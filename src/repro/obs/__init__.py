"""repro.obs -- unified telemetry: metrics registry + span tracer.

Dependency-free (stdlib only) so every layer of the repo can import it
without cycles: ``core``, ``store``, ``serve`` and the benchmarks all
write into the process-default :func:`registry` and :func:`tracer`, and
one snapshot sees the whole system (DESIGN.md Sec. 12).

    from repro import obs

    obs.registry().counter("repro_encode_flushes_total").inc()
    with obs.span("encode.flush", attrs={"streams": 8}):
        ...
    text = obs.to_prometheus()          # Prometheus exposition
    doc = obs.to_json()                 # JSON snapshot (metrics + spans)

``set_enabled(False)`` short-circuits every metric write (and span
recording via ``tracer().enabled``) -- the metrics-off arm of the
overhead bench ``benchmarks/bench_obs_overhead.py``.
"""
from .metrics import (                                        # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry,
    DEFAULT_LATENCY_BUCKETS, registry, set_enabled,
)
from .trace import Span, SpanTracer, tracer, span, event      # noqa: F401
from .export import (                                         # noqa: F401
    to_prometheus, to_json, parse_prometheus, selfcheck,
    histogram_quantile, quantile, quantile_from_parsed,
    SloSpec, SloResult, evaluate_slos,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS", "registry", "set_enabled",
    "Span", "SpanTracer", "tracer", "span", "event",
    "to_prometheus", "to_json", "parse_prometheus", "selfcheck",
    "histogram_quantile", "quantile", "quantile_from_parsed",
    "SloSpec", "SloResult", "evaluate_slos",
]
