"""Simplified reimplementations of the paper's comparison codecs.

The paper (Sec. II-C, Table I) compares IDEALEM against ZFP, ISABELA and SZ.
The original C packages are not available offline, so we reimplement each
algorithm's skeleton faithfully enough for Table I/II-style comparisons:

  zfp_like     -- block transform coding: 4-sample blocks, block-floating-
                  point, ZFP's orthogonal lifting transform, tolerance
                  quantization, entropy stage (zstd stand-in for embedded
                  group coding).
  isabela_like -- window sort -> monotone curve -> cubic B-spline fit +
                  sorted-index permutation (delta + entropy coded) +
                  per-point error correction.
  sz_like      -- multi-model prediction (preceding / linear / quadratic),
                  error-bound quantization codes, entropy stage (zstd
                  stand-in for Huffman).

All three are Euclidean-error-bounded, unlike IDEALEM.  Absolute ratios
differ from the paper's C binaries; orderings and qualitative behaviour
reproduce (see EXPERIMENTS.md).
"""
from .zfp_like import ZfpLikeCodec
from .isabela_like import IsabelaLikeCodec
from .sz_like import SzLikeCodec

__all__ = ["ZfpLikeCodec", "IsabelaLikeCodec", "SzLikeCodec"]
