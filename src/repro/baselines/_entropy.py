"""Entropy-coder shim for the baseline codecs.

The baselines use zstd as their stand-in entropy stage (Huffman/range coder
in the real SZ/ISABELA/zfp pipelines).  ``zstandard`` is an optional wheel,
though, and the frontier benchmark must run everywhere the repo's own codec
runs -- so this shim prefers zstd and falls back to stdlib zlib.  A 1-byte
tag records which coder produced the payload, so blobs decode correctly on
any host regardless of which coder was available at encode time (zstd blobs
still need zstd to decode, and raise ImportError otherwise).
"""
from __future__ import annotations

import zlib

try:  # optional dependency: prefer zstd when the wheel is present
    import zstandard as _zstd
except ImportError:  # pragma: no cover - environment-dependent
    _zstd = None

__all__ = ["compress", "decompress", "HAVE_ZSTD"]

HAVE_ZSTD = _zstd is not None

_TAG_ZSTD = b"Z"
_TAG_ZLIB = b"L"


def compress(data: bytes, level: int = 9) -> bytes:
    if _zstd is not None:
        return _TAG_ZSTD + _zstd.ZstdCompressor(level=level).compress(data)
    return _TAG_ZLIB + zlib.compress(data, level)


def decompress(blob: bytes) -> bytes:
    tag, body = blob[:1], blob[1:]
    if tag == _TAG_ZLIB:
        return zlib.decompress(body)
    if tag == _TAG_ZSTD:
        if _zstd is None:
            raise ImportError(
                "blob was entropy-coded with zstd but the zstandard wheel "
                "is not installed")
        return _zstd.ZstdDecompressor().decompress(body)
    raise ValueError(f"unknown entropy-coder tag {tag!r}")
