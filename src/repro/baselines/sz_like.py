"""SZ-like codec (simplified; Di & Cappello 2016 / Tao 2017 skeleton).

Streaming multi-model prediction from *decoded* history (so decode is exact
within the bound): preceding-neighbor, linear, and quadratic extrapolation.
The best predictor's error is quantized into 2^q bins of width 2*bound; in-
range codes are entropy-coded (zstd stand-in for Huffman); out-of-range
values are stored raw ("unpredictable data").  Error bound is relative to
the global value range, as in the paper's SZ configuration.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

from . import _entropy

_MAGIC = b"SZLK"


@dataclass
class SzLikeCodec:
    rel_bound_ratio: float = 1e-3  # of global range
    quant_bits: int = 12

    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x, dtype=np.float64)
        n = len(x)
        rng = float(np.max(x) - np.min(x)) if n else 0.0
        bound = max(self.rel_bound_ratio * rng, 1e-300)
        half = 1 << (self.quant_bits - 1)
        codes = np.zeros(n, dtype=np.int32)
        raw_vals = []
        d0 = d1 = d2 = 0.0  # rolling decoded history (floats: hot loop)
        xl = x.tolist()
        for i in range(n):
            p0 = d2
            p1 = 2.0 * d2 - d1
            p2 = 3.0 * d2 - 3.0 * d1 + d0
            if i < 3:
                p1 = p1 if i >= 2 else p0
                p2 = p0
            xi = xl[i]
            e0, e1, e2 = xi - p0, xi - p1, xi - p2
            a0, a1, a2 = abs(e0), abs(e1), abs(e2)
            if a0 <= a1 and a0 <= a2:
                best, err, pred = 0, e0, p0
            elif a1 <= a2:
                best, err, pred = 1, e1, p1
            else:
                best, err, pred = 2, e2, p2
            q = int(round(err / (2 * bound)))
            if -half + 1 <= q <= half - 1 and i > 0:
                codes[i] = (best << self.quant_bits) | (q + half)
                val = pred + q * 2 * bound
            else:
                codes[i] = 0  # escape
                raw_vals.append(xi)
                val = xi
            d0, d1, d2 = d1, d2, val
        bcodes = _entropy.compress(codes.astype(np.int32).tobytes())
        braw = _entropy.compress(np.asarray(raw_vals).tobytes())
        hdr = struct.pack("<4sIddII", _MAGIC, n, bound, rng, len(bcodes), len(braw))
        return hdr + bcodes + braw

    def decode(self, blob: bytes) -> np.ndarray:
        magic, n, bound, _rng, lc, lr = struct.unpack_from("<4sIddII", blob, 0)
        assert magic == _MAGIC
        off = struct.calcsize("<4sIddII")
        codes = np.frombuffer(_entropy.decompress(blob[off:off + lc]),
                              dtype=np.int32)
        off += lc
        raw = np.frombuffer(_entropy.decompress(blob[off:off + lr]),
                            dtype=np.float64)
        half = 1 << (self.quant_bits - 1)
        out = np.zeros(n)
        d0 = d1 = d2 = 0.0
        rp = 0
        cl = codes.tolist()
        rl = raw.tolist()
        for i in range(n):
            c = cl[i]
            if c == 0:
                val = rl[rp]; rp += 1
            else:
                best = c >> self.quant_bits
                q = (c & ((1 << self.quant_bits) - 1)) - half
                p0 = d2
                p1 = 2.0 * d2 - d1
                p2 = 3.0 * d2 - 3.0 * d1 + d0
                if i < 3:
                    p1 = p1 if i >= 2 else p0
                    p2 = p0
                val = (p0, p1, p2)[best] + q * 2 * bound
            out[i] = val
            d0, d1, d2 = d1, d2, val
        return out

    @staticmethod
    def compression_ratio(x: np.ndarray, blob: bytes) -> float:
        return x.nbytes / len(blob)
