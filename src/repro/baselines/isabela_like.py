"""ISABELA-like codec (simplified; Lakshminarasimhan et al. 2011 skeleton).

Per window of W samples: sort (monotone curve) -> cubic B-spline fit with K
coefficients (scipy.splrep) -> store knots/coefficients + the sorted-index
permutation (the Achilles heel the paper points out: index storage caps the
ratio) + per-point corrections where the relative error bound is violated.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

from . import _entropy
from scipy.interpolate import splev, splrep

_MAGIC = b"ISBL"


@dataclass
class IsabelaLikeCodec:
    window: int = 512
    num_coeff: int = 15
    error_rate: float = 5.0  # relative error bound, percent (per point)

    def _fit(self, sw: np.ndarray):
        t = np.linspace(0, 1, len(sw))
        # knots chosen so coefficient count ~= num_coeff
        nk = max(self.num_coeff - 4, 1)
        knots = np.linspace(0, 1, nk + 2)[1:-1]
        tck = splrep(t, sw, t=knots, k=3)
        return tck

    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x, dtype=np.float64)
        n = len(x)
        out = bytearray(struct.pack("<4sIIId", _MAGIC, n, self.window,
                                    self.num_coeff, self.error_rate))
        idx_parts, coef_parts, corr_parts = [], [], []
        n_windows = 0
        for s in range(0, n, self.window):
            w = x[s:s + self.window]
            if len(w) < 8:  # tiny tail: store raw
                corr_parts.append(np.concatenate([[len(w)], np.arange(len(w)), w]))
                idx_parts.append(np.arange(len(w), dtype=np.int32))
                coef_parts.append(np.zeros(0))
                n_windows += 1
                continue
            order = np.argsort(w, kind="stable")
            sw = w[order]
            tck = self._fit(sw)
            t = np.linspace(0, 1, len(sw))
            approx = splev(t, tck)
            scale = np.maximum(np.abs(sw), 1e-30)
            bad = np.abs(approx - sw) / scale > self.error_rate / 100.0
            corr_idx = np.nonzero(bad)[0]
            corr_parts.append(np.concatenate(
                [[len(corr_idx)], corr_idx.astype(np.float64), sw[corr_idx]]))
            coef_parts.append(np.concatenate(
                [[float(len(tck[0]))], tck[0], tck[1], [float(len(sw))]]))
            idx_parts.append(order.astype(np.int32))
            n_windows += 1
        idx = np.concatenate(idx_parts) if idx_parts else np.zeros(0, np.int32)
        coef = np.concatenate(coef_parts) if coef_parts else np.zeros(0)
        corr = np.concatenate(corr_parts) if corr_parts else np.zeros(0)
        bidx = _entropy.compress(
            np.diff(idx, prepend=0).astype(np.int32).tobytes())
        bcoef = _entropy.compress(coef.tobytes())
        bcorr = _entropy.compress(corr.tobytes())
        out += struct.pack("<IIII", n_windows, len(bidx), len(bcoef), len(bcorr))
        out += bidx + bcoef + bcorr
        return bytes(out)

    def decode(self, blob: bytes) -> np.ndarray:
        magic, n, window, num_coeff, err = struct.unpack_from("<4sIIId", blob, 0)
        assert magic == _MAGIC
        off = struct.calcsize("<4sIIId")
        n_windows, li, lc, lr = struct.unpack_from("<IIII", blob, off)
        off += struct.calcsize("<IIII")
        idx = np.cumsum(np.frombuffer(_entropy.decompress(blob[off:off + li]),
                                      dtype=np.int32)); off += li
        coef = np.frombuffer(_entropy.decompress(blob[off:off + lc]),
                             dtype=np.float64); off += lc
        corr = np.frombuffer(_entropy.decompress(blob[off:off + lr]),
                             dtype=np.float64); off += lr
        out = np.zeros(n)
        ip = cp = rp = 0
        pos = 0
        for _ in range(n_windows):
            wlen = min(window, n - pos)
            ncorr = int(corr[rp]); rp += 1
            cidx = corr[rp:rp + ncorr].astype(np.int64); rp += ncorr
            cval = corr[rp:rp + ncorr]; rp += ncorr
            if wlen < 8:
                w = np.zeros(wlen)
                w[cidx] = cval
                out[pos:pos + wlen] = w
                ip += wlen
                pos += wlen
                continue
            n_knots = int(coef[cp]); cp += 1
            knots = coef[cp:cp + n_knots]; cp += n_knots
            c = coef[cp:cp + n_knots]; cp += n_knots  # splrep pads c to len(t)
            m = int(coef[cp]); cp += 1
            t = np.linspace(0, 1, m)
            sw = splev(t, (knots, c, 3))
            sw[cidx] = cval
            order = idx[ip:ip + m]; ip += m
            w = np.zeros(m)
            w[order] = sw
            out[pos:pos + m] = w
            pos += m
        return out

    @staticmethod
    def compression_ratio(x: np.ndarray, blob: bytes) -> float:
        return x.nbytes / len(blob)
