"""ZFP-like 1-D fixed-accuracy codec (simplified; Lindstrom 2014 skeleton).

Pipeline per 4-sample block: ZFP's orthogonal-ish decorrelating transform
(the documented 1-D matrix) -> uniform quantization to the user tolerance
(DC coefficient delta-coded across blocks) -> zstd entropy stage (stand-in
for ZFP's embedded bit-plane group coding).  Euclidean-error-bounded, like
the real ZFP and unlike IDEALEM.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

from . import _entropy

_MAGIC = b"ZFPL"

# ZFP's 1-D decorrelating transform (forward), rows = output coefficients.
_M = np.array(
    [
        [4, 4, 4, 4],
        [5, 1, -1, -5],
        [-4, 4, 4, -4],
        [-2, 6, -6, 2],
    ],
    dtype=np.float64,
) / 16.0
_MINV = np.linalg.inv(_M)


@dataclass
class ZfpLikeCodec:
    tolerance: float = 1e-3

    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x, dtype=np.float64)
        n = len(x)
        if n == 0:
            comp = _entropy.compress(b"")
            return struct.pack("<4sIId", _MAGIC, 0, len(comp), self.tolerance) + comp
        pad = (-n) % 4
        xp = np.pad(x, (0, pad), mode="edge") if pad else x
        coeff = xp.reshape(-1, 4) @ _M.T
        q = np.round(coeff / self.tolerance).astype(np.int64)
        q[:, 0] = np.concatenate([[q[0, 0]], np.diff(q[:, 0])])
        comp = _entropy.compress(q.tobytes())
        return struct.pack("<4sIId", _MAGIC, n, len(comp), self.tolerance) + comp

    def decode(self, blob: bytes) -> np.ndarray:
        magic, n, clen, tol = struct.unpack_from("<4sIId", blob, 0)
        assert magic == _MAGIC
        off = struct.calcsize("<4sIId")
        raw = _entropy.decompress(blob[off:off + clen])
        q = np.frombuffer(raw, dtype=np.int64).reshape(-1, 4).copy()
        q[:, 0] = np.cumsum(q[:, 0])
        blocks = (q * tol) @ _MINV.T
        return blocks.reshape(-1)[:n]

    @staticmethod
    def compression_ratio(x: np.ndarray, blob: bytes) -> float:
        return x.nbytes / len(blob)
