"""Time-series quality measures (paper Table II) and spectral analysis helpers.

Measures:
  #1 number of local maxima (peaks)
  #2 mean distance (in samples) between consecutive peaks
  #3 mean absolute difference between consecutive peak values
  #4 mean absolute jump size |x[i+1]-x[i]|
  #5 number of jumps larger than 10% of (max-min) of the series
  #6 percentage of points outside the Tukey box-plot whiskers (1.5 IQR)
"""
from __future__ import annotations

from typing import Dict

import numpy as np

__all__ = ["peaks", "quality_measures", "amplitude_spectrum", "spectral_band_error"]


def peaks(x: np.ndarray) -> np.ndarray:
    """Indices of strict local maxima."""
    x = np.asarray(x)
    if len(x) < 3:
        return np.zeros((0,), dtype=np.int64)
    mid = x[1:-1]
    mask = (mid > x[:-2]) & (mid > x[2:])
    return np.nonzero(mask)[0] + 1


def quality_measures(x: np.ndarray) -> Dict[str, float]:
    x = np.asarray(x, dtype=np.float64)
    p = peaks(x)
    jumps = np.abs(np.diff(x))
    rng = float(np.max(x) - np.min(x)) if len(x) else 0.0
    q1, q3 = np.percentile(x, [25, 75]) if len(x) else (0.0, 0.0)
    iqr = q3 - q1
    lo, hi = q1 - 1.5 * iqr, q3 + 1.5 * iqr
    return {
        "m1_num_peaks": float(len(p)),
        "m2_mean_peak_dist": float(np.mean(np.diff(p))) if len(p) > 1 else 0.0,
        "m3_mean_peak_value_dist": float(np.mean(np.abs(np.diff(x[p])))) if len(p) > 1 else 0.0,
        "m4_mean_jump": float(np.mean(jumps)) if len(jumps) else 0.0,
        "m5_num_big_jumps": float(np.sum(jumps > 0.1 * rng)) if rng > 0 else 0.0,
        "m6_pct_outliers": float(100.0 * np.mean((x < lo) | (x > hi))) if len(x) else 0.0,
    }


def amplitude_spectrum(x: np.ndarray) -> np.ndarray:
    """Single-sided DFT amplitude spectrum, DC excluded (paper Sec. VII-C)."""
    f = np.abs(np.fft.rfft(np.asarray(x, dtype=np.float64)))
    return f[1:]


def spectral_band_error(orig: np.ndarray, recon: np.ndarray, low_frac: float = 0.05):
    """Relative log-amplitude error in the low band vs the full band.

    The paper's claim: low-frequency components (the ones that matter for the
    application domain) are well preserved; high-frequency amplitudes may be
    boosted by the random permutation (std mode).
    """
    a, b = amplitude_spectrum(orig), amplitude_spectrum(recon)
    n = min(len(a), len(b))
    a, b = a[:n] + 1e-12, b[:n] + 1e-12
    k = max(int(low_frac * n), 1)
    err = np.abs(np.log10(b) - np.log10(a))
    return {
        "low_band_logerr": float(np.mean(err[:k])),
        "full_band_logerr": float(np.mean(err)),
        "high_band_logerr": float(np.mean(err[n // 2:])),
    }
