"""Residual and delta transforms for non-stationary data (paper Sec. IV-A).

Each block b_j = (x_{jB}, ..., x_{jB+B-1}) keeps its first sample as the
*base value*; the LEM processing then runs on the B-1 transformed values:

  residual:  x^r_{jB+k} = x_{jB+k} - x_{jB}          (eq. 4)
  delta:     x^d_{jB+k} = x_{jB+k} - x_{jB+k-1}      (eq. 6)

Bounded ranges (e.g. phase angles in [0, 360)): transformed values are wrapped
into [-(rmax-rmin)/2, +(rmax-rmin)/2) and reconstructed values into
[rmin, rmax) (paper Sec. IV-A, the 359deg -> 1deg = +2 example).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "wrap_centered",
    "wrap_range",
    "residual_forward",
    "residual_inverse",
    "delta_forward",
    "delta_inverse",
]


def wrap_centered(v, rmin: float, rmax: float):
    """Wrap transformed values into [-(rmax-rmin)/2, +(rmax-rmin)/2)."""
    w = rmax - rmin
    return jnp.mod(v + 0.5 * w, w) - 0.5 * w


def wrap_range(v, rmin: float, rmax: float):
    """Wrap reconstructed values into [rmin, rmax)."""
    w = rmax - rmin
    return jnp.mod(v - rmin, w) + rmin


def residual_forward(blocks, value_range: Optional[Tuple[float, float]] = None):
    """blocks (..., B) -> (bases (...,), residuals (..., B-1))."""
    blocks = jnp.asarray(blocks)
    base = blocks[..., 0]
    res = blocks[..., 1:] - base[..., None]
    if value_range is not None:
        res = wrap_centered(res, *value_range)
    return base, res


def residual_inverse(base, res, value_range: Optional[Tuple[float, float]] = None):
    """(bases (...,), residuals (..., B-1)) -> blocks (..., B)."""
    vals = jnp.concatenate(
        [jnp.asarray(base)[..., None], jnp.asarray(base)[..., None] + res], axis=-1
    )
    if value_range is not None:
        vals = wrap_range(vals, *value_range)
    return vals


def delta_forward(blocks, value_range: Optional[Tuple[float, float]] = None):
    """blocks (..., B) -> (bases (...,), deltas (..., B-1))."""
    blocks = jnp.asarray(blocks)
    base = blocks[..., 0]
    d = blocks[..., 1:] - blocks[..., :-1]
    if value_range is not None:
        d = wrap_centered(d, *value_range)
    return base, d


def delta_inverse(base, deltas, value_range: Optional[Tuple[float, float]] = None):
    """(bases (...,), deltas (..., B-1)) -> blocks (..., B) via cumsum."""
    base = jnp.asarray(base)[..., None]
    vals = jnp.concatenate([base, base + jnp.cumsum(deltas, axis=-1)], axis=-1)
    if value_range is not None:
        vals = wrap_range(vals, *value_range)
    return vals


# ---------------------------------------------------------------- numpy twins
# (used by the host-side stream codec / reference encoder; identical math)

def np_wrap_centered(v, rmin, rmax):
    w = rmax - rmin
    return np.mod(v + 0.5 * w, w) - 0.5 * w


def np_wrap_range(v, rmin, rmax):
    w = rmax - rmin
    return np.mod(v - rmin, w) + rmin
