"""IDEALEM core: statistical-similarity data reduction (the paper's contribution).

Public API:
  IdealemCodec           -- end-to-end encode/decode with the paper's stream format
  encode_decisions       -- jit/vmap-able device-side encoder (lax.scan)
  ks_statistic, ks_pvalue, critical_distance
  residual/delta transforms, quality measures
"""
from .decode import BACKENDS as DECODE_BACKENDS
from .decode import DecodePlan, decode_stats, reconstruct
from .idealem import IdealemCodec
from .session import IdealemSession, PreparedChunk, SessionStats
from .stream import StreamFormatError
from .ks import critical_distance, ks_pvalue, ks_statistic, ks_statistic_many
from .encoder import (DictState, encode_decisions, encode_decisions_batched,
                      encode_decisions_sharded, init_state)
from .metrics import quality_measures, amplitude_spectrum, spectral_band_error

__all__ = [
    "IdealemCodec",
    "DecodePlan",
    "DECODE_BACKENDS",
    "reconstruct",
    "decode_stats",
    "IdealemSession",
    "PreparedChunk",
    "SessionStats",
    "StreamFormatError",
    "DictState",
    "init_state",
    "critical_distance",
    "ks_pvalue",
    "ks_statistic",
    "ks_statistic_many",
    "encode_decisions",
    "encode_decisions_batched",
    "encode_decisions_sharded",
    "quality_measures",
    "amplitude_spectrum",
    "spectral_band_error",
]
