"""Adaptive per-channel mode selection (DESIGN.md Sec. 11).

IDEALEM's three payload transforms trade off differently with the signal
shape: ``std`` wants locally exchangeable samples, ``residual``/``delta``
want smooth autocorrelated ones (the paper fixes the choice per run).  For
long mixed streams the right transform changes over time, so a session can
instead carry one ``ChannelSelector`` per channel: cheap streaming
statistics over a rolling warmup-sized window drive an online mode choice
plus a quantized KS-threshold adjustment.

Predictors (the arXiv:2111.13789 family):

  * ``rho1``        lag-1 autocorrelation of the window -- high values mean
                    the diff/residual payloads are small and stable, so
                    ``delta``/``residual`` beat ``std``;
  * ``var_ratio``   window variance over the reference (first-window)
                    variance -- a non-stationarity signal;
  * ``range_drift`` fraction of the reference range by which the window's
                    extremes escape it -- the min/max gate's failure mode.

Decisions are deliberately sticky so channels do not flap: a mode/scale
change must clear the threshold by a ``hysteresis`` margin, repeat for
``patience`` consecutive evaluations, and respect a ``min_dwell_blocks``
spacing from the previous switch.  The session applies accepted switches
only at feed boundaries (segment restarts), never mid-segment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["SelectorConfig", "SelectionEvent", "ChannelSelector"]

_MODE_ORDER = ("std", "residual", "delta")  # by increasing rho1 affinity


@dataclass(frozen=True)
class SelectorConfig:
    """Tuning knobs for :class:`ChannelSelector` (defaults are deliberately
    conservative: a stationary channel never switches)."""

    warmup_blocks: int = 8        # rolling-window length, in blocks
    hysteresis: float = 0.1       # rho1 margin to leave the current mode
    patience: int = 2             # consecutive evaluations before switching
    min_dwell_blocks: int = 64    # min blocks between switches (per channel)
    delta_rho: float = 0.7        # rho1 above which delta beats residual
    residual_rho: float = 0.35    # rho1 above which residual beats std
    drift_hi: float = 0.5         # non-stationarity level that tightens d_crit
    drift_lo: float = 0.2         # level below which the tightening relaxes
    # quantized d_crit multipliers (smallest = tightened); discrete levels
    # keep the per-channel threshold a small static set for jit caching
    d_crit_scales: Tuple[float, ...] = (0.75, 1.0)


@dataclass
class SelectionEvent:
    """One accepted switch, recorded in the session stats."""

    block_index: int
    old_mode: str
    new_mode: str
    old_scale: float
    new_scale: float
    rho1: float
    var_ratio: float
    range_drift: float

    def as_dict(self) -> dict:
        return {
            "block_index": self.block_index,
            "old_mode": self.old_mode, "new_mode": self.new_mode,
            "old_scale": self.old_scale, "new_scale": self.new_scale,
            "rho1": round(self.rho1, 4),
            "var_ratio": round(self.var_ratio, 4),
            "range_drift": round(self.range_drift, 4),
        }


class ChannelSelector:
    """Streaming per-channel statistics and the sticky mode/scale policy.

    ``observe(samples)`` after every feed keeps the rolling window current;
    ``decide(block_index)`` at a feed boundary returns a
    :class:`SelectionEvent` when a switch is accepted (and commits it), or
    ``None``.  The caller owns applying the switch (dictionary reset +
    restart segment).
    """

    def __init__(self, block_size: int, mode: str = "std",
                 config: Optional[SelectorConfig] = None):
        self.cfg = config or SelectorConfig()
        if self.cfg.warmup_blocks < 2:
            raise ValueError("warmup_blocks must be >= 2")
        if not self.cfg.d_crit_scales:
            raise ValueError("d_crit_scales must be non-empty")
        if mode not in _MODE_ORDER:
            raise ValueError(f"mode must be one of {_MODE_ORDER}")
        self.mode = mode
        self.scale = 1.0 if 1.0 in self.cfg.d_crit_scales \
            else self.cfg.d_crit_scales[-1]
        self._winlen = self.cfg.warmup_blocks * int(block_size)
        self._win = np.zeros(0, dtype=np.float64)
        self._ref = None  # (var, min, max) captured from the first full window
        self._pending = None
        self._streak = 0
        self._last_switch: Optional[int] = None
        self.events: List[SelectionEvent] = []

    # --------------------------------------------------------------- observe
    def observe(self, samples) -> None:
        """Fold raw (untransformed) samples into the rolling window."""
        x = np.asarray(samples, dtype=np.float64).ravel()
        if x.size:
            self._win = np.concatenate([self._win, x])[-self._winlen:]
        if self._ref is None and len(self._win) >= self._winlen:
            w = self._win
            self._ref = (float(np.var(w)), float(np.min(w)),
                         float(np.max(w)))

    def predictors(self) -> Optional[Tuple[float, float, float]]:
        """(rho1, var_ratio, range_drift) over the current window, or None
        while still warming up."""
        w = self._win
        if self._ref is None or len(w) < self._winlen:
            return None
        a, b = w[:-1], w[1:]
        va, vb = np.var(a), np.var(b)
        rho1 = 0.0 if va * vb == 0 else float(
            np.mean((a - a.mean()) * (b - b.mean())) / np.sqrt(va * vb))
        ref_var, ref_min, ref_max = self._ref
        var_ratio = float(np.var(w) / max(ref_var, 1e-30))
        width = max(ref_max - ref_min, 1e-30)
        drift = float(max(0.0, ref_min - np.min(w), np.max(w) - ref_max)
                      / width)
        return rho1, var_ratio, drift

    # ---------------------------------------------------------------- policy
    def _target_mode(self, rho1: float) -> str:
        """Rank by rho1 with sticky boundaries: a boundary the current mode
        already cleared moves *away* by the hysteresis margin."""
        cfg = self.cfg
        cur = _MODE_ORDER.index(self.mode)
        b1 = cfg.residual_rho + (cfg.hysteresis if cur < 1
                                 else -cfg.hysteresis)
        b2 = cfg.delta_rho + (cfg.hysteresis if cur < 2 else -cfg.hysteresis)
        return _MODE_ORDER[int(rho1 >= b1) + int(rho1 >= b2)]

    def _target_scale(self, var_ratio: float, drift: float) -> float:
        """Tighten d_crit (smallest quantized scale) while the channel is
        non-stationary; relax only once it settles (drift_lo < drift_hi is
        the hysteresis band)."""
        cfg = self.cfg
        sig = max(abs(float(np.log(max(var_ratio, 1e-30)))), drift)
        tight, normal = cfg.d_crit_scales[0], self.__class__._normal(cfg)
        if self.scale == normal:
            return tight if sig >= cfg.drift_hi else normal
        return normal if sig <= cfg.drift_lo else tight

    @staticmethod
    def _normal(cfg: SelectorConfig) -> float:
        return 1.0 if 1.0 in cfg.d_crit_scales else cfg.d_crit_scales[-1]

    def decide(self, block_index: int) -> Optional[SelectionEvent]:
        """Evaluate at a feed boundary; returns the accepted switch (already
        committed to ``self.mode``/``self.scale``) or None."""
        p = self.predictors()
        if p is None:
            return None
        cfg = self.cfg
        if (self._last_switch is not None
                and block_index - self._last_switch < cfg.min_dwell_blocks):
            return None
        rho1, var_ratio, drift = p
        target = (self._target_mode(rho1),
                  self._target_scale(var_ratio, drift))
        if target == (self.mode, self.scale):
            self._pending, self._streak = None, 0
            return None
        if target == self._pending:
            self._streak += 1
        else:
            self._pending, self._streak = target, 1
        if self._streak < cfg.patience:
            return None
        ev = SelectionEvent(block_index, self.mode, target[0], self.scale,
                            target[1], rho1, var_ratio, drift)
        self.mode, self.scale = target
        self._last_switch = block_index
        self._pending, self._streak = None, 0
        # re-arm the reference on the new regime: the next observe() call
        # recaptures it from the (already full) window
        self._ref = None
        self.events.append(ev)
        return ev
