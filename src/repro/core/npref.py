"""Pure-numpy reference encoder (decision oracle for the JAX/Pallas paths).

Mirrors the early-exit C encoder semantics exactly: for each block, walk the
dictionary in slot order, apply the min/max gate (eq. 3) then the KS test,
take the first passing entry; FIFO insert on miss.

Like the device encoder, the dictionary carry is resumable: pass
``state=np_init_state(num_dict)`` and thread the returned state through
chunked calls to get decisions identical to one pass over the whole array.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .. import obs

__all__ = [
    "ks_statistic_np",
    "ks_pvalue_np",
    "NpDictState",
    "np_init_state",
    "encode_decisions_np",
    "encode_decisions_mixed_np",
]


# Miss attribution (ISSUE 8): why a block failed to hit, classified by
# the deepest gate its dictionary walk got past -- cold dictionary, the
# min/max gate (eq. 3), the KS test, or the error-bound demotion check.
# Only this host reference walk can attribute reasons: the device scans
# return hit/slot/overwrite without per-gate outcomes (DESIGN.md
# Sec. 12), so these counters populate on numpy-matched sessions and the
# differential oracle, not on fused-kernel encodes.
_MISS_COUNTERS = {
    reason: obs.registry().counter(
        "repro_encode_miss_total",
        "dictionary misses by deepest gate passed (host reference walk)",
        labels={"reason": reason})
    for reason in ("cold", "minmax", "ks", "error_bound")
}


def ks_statistic_np(x: np.ndarray, y: np.ndarray) -> float:
    xs, ys = np.sort(x), np.sort(y)
    n1, n2 = len(xs), len(ys)
    both = np.concatenate([xs, ys])
    f1 = np.searchsorted(xs, both, side="right") / n1
    f2 = np.searchsorted(ys, both, side="right") / n2
    return float(np.max(np.abs(f1 - f2)))


def ks_pvalue_np(d: float, n1: int, n2: int, terms: int = 40) -> float:
    en = n1 * n2 / (n1 + n2)
    lam = max(np.sqrt(en) * d, 1e-12)
    if lam < 0.1:  # keep byte-consistent with ks._SMALL_LAM
        return 1.0
    j = np.arange(1, terms + 1)
    q = 2.0 * np.sum((-1.0) ** (j - 1) * np.exp(-2.0 * j * j * lam * lam))
    return float(np.clip(q, 0.0, 1.0))


@dataclass
class NpDictState:
    """Host twin of ``encoder.DictState`` (mutated in place by the scan)."""

    blocks: List[Optional[np.ndarray]]
    dmin: np.ndarray
    dmax: np.ndarray
    count: int = 0


def np_init_state(num_dict: int) -> NpDictState:
    return NpDictState(
        blocks=[None] * num_dict,
        dmin=np.zeros(num_dict),
        dmax=np.zeros(num_dict),
    )


def encode_decisions_np(
    blocks: np.ndarray,
    *,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative: bool = False,
    state: Optional[NpDictState] = None,
) -> Tuple[np.ndarray, ...]:
    """Sequential early-exit reference; same outputs as encoder.encode_decisions.

    With ``state``, continues from (and mutates) the given carry and returns
    ``((is_hit, slot, overwrite), state)``; without, runs one-shot and
    returns the plain decision triple.
    """
    return_state = state is not None
    if state is None:
        state = np_init_state(num_dict)
    nb, _ = blocks.shape
    dict_blocks, dmin, dmax = state.blocks, state.dmin, state.dmax
    is_hit = np.zeros(nb, dtype=bool)
    slot = np.zeros(nb, dtype=np.int32)
    overwrite = np.zeros(nb, dtype=bool)
    misses = {"cold": 0, "minmax": 0, "ks": 0, "error_bound": 0}
    for i in range(nb):
        x = blocks[i]
        xmin, xmax = float(np.min(x)), float(np.max(x))
        hit = -1
        # deepest gate any entry got past, for miss attribution (0 = no
        # valid entry, 1 = min/max, 2 = KS, 3 = error bound)
        depth = 0
        for s in range(num_dict):
            if dict_blocks[s] is None:
                continue
            depth = max(depth, 1)
            if use_minmax:
                w = dmax[s] - dmin[s]
                t = w * rel_tol
                if not (
                    dmin[s] - t <= xmin <= dmin[s] + t
                    and dmax[s] - t <= xmax <= dmax[s] + t
                ):
                    continue
            depth = max(depth, 2)
            if use_ks and ks_statistic_np(x, dict_blocks[s]) > d_crit:
                continue
            depth = max(depth, 3)
            if error_bound is not None:
                # pointwise demotion: the stored entry's raw row is what the
                # no-permutation decode reproduces, so max|err| over it (or
                # over its running cumsum in delta mode) IS the decode error
                diff = x - dict_blocks[s]
                if error_cumulative:
                    diff = np.cumsum(diff)
                if float(np.max(np.abs(diff))) > error_bound:
                    continue
            hit = s
            break
        if hit >= 0:
            is_hit[i], slot[i] = True, hit
        else:
            reason = ("cold", "minmax", "ks", "error_bound")[depth]
            misses[reason] += 1
            s = state.count % num_dict
            overwrite[i] = state.count >= num_dict
            slot[i] = s
            dict_blocks[s] = x.copy()
            dmin[s], dmax[s] = xmin, xmax
            state.count += 1
    for reason, n in misses.items():
        if n:
            _MISS_COUNTERS[reason].inc(n)
    out = (is_hit, slot, overwrite)
    return (out, state) if return_state else out


def encode_decisions_mixed_np(
    blocks_cn: np.ndarray,
    *,
    num_dict: int,
    n_valid,
    d_crit,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative=None,
    eb_on=None,
    states: Optional[List[Optional[NpDictState]]] = None,
    valid: Optional[np.ndarray] = None,
):
    """Host oracle for ``encoder.encode_decisions_mixed``: slices each
    channel's real rows (``valid`` (C, nb) mask) and columns (logical
    width ``n_valid[ci]``, the rest are +inf pads) out of the padded
    cohort and runs the early-exit walk per channel with that channel's
    ``d_crit``/``error_cumulative``/``eb_on``.

    One-shot returns the (C, nb) decision triple with padded rows zeroed;
    with ``states`` (a list of per-channel ``NpDictState`` or ``None``
    entries, filled and mutated in place) it returns the resumable
    ``((is_hit, slot, overwrite), states)`` form.
    """
    blocks_cn = np.asarray(blocks_cn)
    C, nb = blocks_cn.shape[:2]
    return_state = states is not None
    if states is None:
        states = [None] * C
    n_valid = np.asarray(n_valid)
    d_crit = np.asarray(d_crit)
    is_hit = np.zeros((C, nb), dtype=bool)
    slot = np.zeros((C, nb), dtype=np.int32)
    overwrite = np.zeros((C, nb), dtype=bool)
    for ci in range(C):
        rows = (np.ones(nb, dtype=bool) if valid is None
                else np.asarray(valid)[ci])
        pj = blocks_cn[ci][rows, : int(n_valid[ci])]
        if states[ci] is None:
            states[ci] = np_init_state(num_dict)
        ec = (False if error_cumulative is None
              else bool(np.asarray(error_cumulative)[ci]))
        ebo = True if eb_on is None else bool(np.asarray(eb_on)[ci])
        (h, s, o), _ = encode_decisions_np(
            pj, num_dict=num_dict, d_crit=float(d_crit[ci]),
            rel_tol=rel_tol, use_minmax=use_minmax, use_ks=use_ks,
            error_bound=error_bound if ebo else None,
            error_cumulative=ec, state=states[ci])
        is_hit[ci][rows], slot[ci][rows], overwrite[ci][rows] = h, s, o
    out = (is_hit, slot, overwrite)
    return (out, states) if return_state else out
