"""Unified decode engine: one reconstruction path for every consumer.

Reconstruction in IDEALEM (paper Sec. V-A2/V-B2) is per-block math: a hit
is either a random permutation of its source block (std mode) or the
stored transformed values re-anchored on the hit's own base (res/delta,
delta adding an in-block cumsum).  Before this module, that math lived in
three near-duplicate host walks -- ``core.stream.decode_stream``,
``store.reader.decode_range(s)`` and the ``DecompressionService`` flush
loop.  Now every consumer builds a :class:`DecodePlan` -- the explicit
struct-of-arrays form of "what feeds each output block" -- and calls
:func:`reconstruct` on it (DESIGN.md Sec. 8).

Plans are backend-agnostic.  Three backends produce byte-identical output:

  ``numpy``   -- the host reference (fancy-index gather + vectorized math);
  ``jax``     -- jnp gather / permutation-apply / re-anchor, with the delta
                 cumsum as a sequential ``fori_loop`` (XLA's associative
                 ``cumsum`` rounds f64 differently -- measured, see
                 tests/test_decode_backends.py);
  ``pallas``  -- the jax path with the cumsum in the
                 ``repro.kernels.seq_cumsum`` kernel.

Byte-exactness on an accelerator is *checked, never assumed*: the first
time a (backend, mode, dtype, value_range, block_size) combination runs,
a small probe plan is reconstructed on both paths and compared
``tobytes()``-for-``tobytes()``.  If the device result differs (e.g. f64
emulation on TPU) -- or the device path raises -- the engine logs the
fallback once and routes that combination to the host path; the decision
is observable via :func:`decode_stats` and pinned by tests.

Device dispatch shapes are padded to powers of two (pad rows are zero-
payload misses the per-block math ignores), so serving traffic reuses a
handful of compiled shapes instead of recompiling per request length.
"""
from __future__ import annotations

import json
import logging
import os
import time
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from .transforms import np_wrap_range

__all__ = [
    "MODE_STD", "MODE_RESIDUAL", "MODE_DELTA", "BACKENDS",
    "DecodePlan", "PlanPart", "plan_from_parsed", "pad_parts",
    "reconstruct", "resolve_backend", "decode_sources", "hit_perms",
    "gather_rows", "decode_stats", "reset_decode_stats",
    "AUTOTUNE_VERSION", "AutotuneCacheError", "load_autotune",
    "save_autotune", "reset_autotune", "autotune_choices", "autotune_cached",
]

MODE_STD, MODE_RESIDUAL, MODE_DELTA = 0, 1, 2

#: Recognised ``backend=`` values (plus ``"auto"``: the measured-best
#: backend for the plan's (mode, dtype, size bucket) -- see the autotuner
#: below).
BACKENDS = ("numpy", "jax", "pallas")

logger = logging.getLogger("repro.core.decode")

# Per-process accounting of backend routing, held as counters on the
# repro.obs registry (ISSUE 8) -- :func:`decode_stats` is a dict-shaped
# compat view over them, byte-compatible with the pre-registry API that
# tests pin.  ``fallbacks`` counts calls that *asked* for a device
# backend but ran on the host because the probe failed (or the device
# path raised); tests pin this so a silent fallback cannot masquerade as
# device coverage.  ``autotune_probes``/``autotune_hits`` count measured
# first-use probes vs cached ``"auto"`` resolutions.  Counters are
# individually locked, so the pipelined service's worker-thread bumps
# stay exact against the caller's reads.
_STAT_HELP = {
    "host_calls": "reconstruct calls served on the numpy host path",
    "device_calls": "reconstruct calls served on a device backend",
    "fallbacks": "device requests that fell back to the host",
    "autotune_probes": "backend=auto measured first-use probes",
    "autotune_hits": "backend=auto cached resolutions",
}
_stat_counters = {
    key: obs.registry().counter(f"repro_decode_{key}_total", help_text)
    for key, help_text in _STAT_HELP.items()
}
# resolved-backend routing, labelled per backend (the "backend choice
# counts" metric; decode_stats keeps only the host/device aggregate)
_backend_counters = {
    b: obs.registry().counter("repro_decode_backend_calls_total",
                              "reconstruct calls per resolved backend",
                              labels={"backend": b})
    for b in ("numpy", "jax", "pallas")
}
_exact_cache: dict = {}


def _bump(key: str, n: int = 1) -> None:
    _stat_counters[key].inc(n)


def decode_stats() -> dict:
    snap = {key: int(c.value) for key, c in _stat_counters.items()}
    return {**snap, "autotune_choices": autotune_choices()}


def reset_decode_stats() -> None:
    for c in _stat_counters.values():
        c.reset()
    for c in _backend_counters.values():
        c.reset()


# ------------------------------------------------------------------ the plan

@dataclass(frozen=True)
class DecodePlan:
    """Everything :func:`reconstruct` needs, as flat arrays.

    ``payloads`` holds each *source* block's stored values once (misses in
    stream order, plus any snapshot-materialized virtual misses and -- for
    padded batch plans -- one trailing all-zero row).  ``src[i]`` is the
    payload row feeding output block ``i``; hits share their source miss's
    row.  ``block_idx[i]`` is the block's global position in its stream:
    std-mode hit permutations are keyed on ``(seed, block_idx)``
    (:func:`hit_perms`), which is what makes any sub-range reconstruct
    byte-identically to the same rows of a full decode.  ``overwrite`` is
    carried for completeness/debugging; FIFO overwrites are a framing
    concern and do not affect reconstruction.
    """

    mode: int
    block_size: int
    dtype: np.dtype
    value_range: Optional[Tuple[float, float]]
    payloads: np.ndarray            # (n_rows, P) source payload rows
    src: np.ndarray                 # (nb,) payload row per output block
    bases: Optional[np.ndarray]     # (nb,) res/delta modes, else None
    is_hit: np.ndarray              # (nb,) bool
    block_idx: np.ndarray           # (nb,) global block positions
    seed: int = 0
    overwrite: Optional[np.ndarray] = None  # (nb,) bool, informational
    # Error-bounded streams (FLAG_EB) pin hits to the stored row order:
    # the std-mode hit permutation is skipped so max|x - x_hat| over a hit
    # is exactly the bound the encoder enforced.  Res/delta modes never
    # permute, so the flag only changes std-mode reconstruction.
    no_perm: bool = False

    @property
    def nb(self) -> int:
        return len(self.src)

    @property
    def payload_width(self) -> int:
        return int(self.payloads.shape[1])


class PlanPart(NamedTuple):
    """One request's worth of plan inputs, sources already resolved
    (``rows[i]`` is the payload feeding the part's block ``i``).  Parts
    from many requests -- across containers -- are padded into one
    :class:`DecodePlan` by :func:`pad_parts`."""

    rows: np.ndarray                # (n, P) per-block source payloads
    bases: Optional[np.ndarray]     # (n,) or None (std mode)
    is_hit: np.ndarray              # (n,) bool
    block_idx: np.ndarray           # (n,) global block positions


# ------------------------------------------------------- plan construction

def decode_sources(is_hit: np.ndarray, slot: np.ndarray) -> np.ndarray:
    """Payload row (miss ordinal) feeding each block: misses feed
    themselves, hits feed the most recent miss written to their slot.
    A hit with no preceding miss on its slot is malformed input."""
    from .stream import StreamFormatError  # typed error lives with the parser
    nb = len(is_hit)
    miss_pos = np.flatnonzero(~is_hit)
    hit_pos = np.flatnonzero(is_hit)
    src = np.zeros(nb, dtype=np.int64)
    src[miss_pos] = np.arange(len(miss_pos))
    if len(hit_pos):
        hit_slots = slot[hit_pos]
        miss_slots = slot[miss_pos]
        for s in np.unique(hit_slots):
            hp = hit_pos[hit_slots == s]
            mp = miss_pos[miss_slots == s]
            j = np.searchsorted(mp, hp) - 1
            if len(mp) == 0 or np.any(j < 0):
                raise StreamFormatError(f"hit on slot {s} before any miss")
            src[hp] = src[mp[j]]
    return src


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer on uint64 arrays (wrapping arithmetic is the
    point; numpy only flags the wrap for 0-d inputs)."""
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def hit_perms(seed: int, block_idx: np.ndarray, B: int) -> np.ndarray:
    """Per-hit reconstruction permutations, stateless in the block position.

    Each permutation is the argsort of SplitMix64 keys of (seed, global
    sample index), so the permutation a block receives depends only on
    ``(seed, its index in the stream)`` -- never on how many other blocks
    share the reconstruct call."""
    with np.errstate(over="ignore"):  # seed 2**64-1 wraps on the +1
        s = _splitmix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF) + np.uint64(1))
        samp = (np.asarray(block_idx, dtype=np.uint64)[:, None] * np.uint64(B)
                + np.arange(B, dtype=np.uint64)[None, :])
    return np.argsort(_splitmix64(samp ^ s), axis=1, kind="stable")


def gather_rows(u8: np.ndarray, dt: np.dtype, offs: np.ndarray,
                width: int) -> np.ndarray:
    """One fancy-indexing pass over raw stream/container bytes:
    ``width``-value rows at byte offsets ``offs``."""
    if width == 0 or len(offs) == 0:
        return np.zeros((len(offs), width), dtype=dt)
    return u8[offs[:, None] + np.arange(width * dt.itemsize)].view(dt)


def plan_from_parsed(header, parsed, seed: int = 0, i0: int = 0) -> DecodePlan:
    """Plan for a full sequential decode of one parsed stream.

    ``header``/``parsed`` are duck-typed (``repro.core.stream`` supplies
    ``StreamHeader`` and its struct-of-arrays ``_Parsed``); block positions
    are ``i0..i0+nb`` (``i0`` offsets a restart section within a larger
    stream so permutations stay keyed on global position)."""
    nb = len(parsed.is_hit)
    return DecodePlan(
        mode=header.mode, block_size=header.block_size,
        dtype=np.dtype(header.dtype), value_range=header.value_range,
        payloads=parsed.payloads,
        src=decode_sources(parsed.is_hit, parsed.slot),
        bases=parsed.bases, is_hit=parsed.is_hit,
        block_idx=i0 + np.arange(nb, dtype=np.int64), seed=seed,
        overwrite=parsed.overwrite,
        no_perm=bool(getattr(header, "error_bounded", False)))


def pad_parts(mode: int, block_size: int, dtype, value_range,
              parts: Sequence[PlanPart], seed: int = 0,
              no_perm: bool = False) -> Tuple[DecodePlan, int]:
    """Pad R ragged request parts into ONE plan of shape ``(R * nbm,)``.

    The read-side mirror of the encoder's masked ragged batches: requests
    are stacked on a leading axis and padded to the longest; pad blocks
    are all-miss with a shared all-zero payload row, dead weight the
    per-block math ignores.  Returns ``(plan, nbm)``; callers reshape
    ``reconstruct(plan)`` to ``(R, nbm, B)`` and slice each request back
    out.
    """
    dt = np.dtype(dtype)
    R = len(parts)
    lens = [len(p.is_hit) for p in parts]
    nbm = max(lens)
    P = block_size if mode == MODE_STD else block_size - 1
    n_rows = sum(lens)
    payloads = np.zeros((n_rows + 1, P), dtype=dt)   # last row: shared pad
    src = np.full((R, nbm), n_rows, dtype=np.int64)
    is_hit = np.zeros((R, nbm), dtype=bool)
    block_idx = np.zeros((R, nbm), dtype=np.int64)
    bases = None if mode == MODE_STD else np.zeros((R, nbm), dtype=dt)
    pos = 0
    for r, (p, n) in enumerate(zip(parts, lens)):
        payloads[pos:pos + n] = p.rows
        src[r, :n] = np.arange(pos, pos + n)
        is_hit[r, :n] = p.is_hit
        block_idx[r, :n] = p.block_idx
        if bases is not None:
            bases[r, :n] = p.bases
        pos += n
    plan = DecodePlan(
        mode=mode, block_size=block_size, dtype=dt, value_range=value_range,
        payloads=payloads, src=src.ravel(),
        bases=None if bases is None else bases.ravel(),
        is_hit=is_hit.ravel(), block_idx=block_idx.ravel(), seed=seed,
        no_perm=no_perm)
    return plan, nbm


# ------------------------------------------------------------ numpy backend

def _reconstruct_numpy(plan: DecodePlan) -> np.ndarray:
    rows = plan.payloads[plan.src]          # fancy index: always a fresh copy
    if plan.mode == MODE_STD:
        out = rows
        hit_pos = (np.zeros(0, dtype=np.int64) if plan.no_perm
                   else np.flatnonzero(plan.is_hit))
        if len(hit_pos):
            perm = hit_perms(plan.seed, plan.block_idx[hit_pos],
                             plan.block_size)
            out[hit_pos] = np.take_along_axis(rows[hit_pos], perm, axis=1)
        return out
    base = plan.bases[:, None]
    t = rows if plan.mode == MODE_RESIDUAL else np.cumsum(rows, axis=1)
    out = np.concatenate([base, base + t], axis=1)
    if plan.value_range is not None:
        out = np_wrap_range(out, *plan.value_range)
    return out


# ----------------------------------------------------------- device backend

def _pow2(n: int) -> int:
    return max(1, 1 << (int(n) - 1).bit_length())


_dev_fns: dict = {}


def _device_fn(backend: str, mode: int, value_range):
    """Jitted device reconstruct for one (backend, mode, range) combo.
    Gather, permutation apply, re-anchor and (delta) sequential cumsum all
    run on device; inputs arrive pre-padded to power-of-two shapes."""
    key = (backend, mode, value_range)
    fn = _dev_fns.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp

    def _seq_cumsum_jnp(x):
        P = x.shape[1]

        def body(j, carry):
            acc, out = carry
            v = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0]
            acc = acc + v
            out = jax.lax.dynamic_update_slice_in_dim(
                out, acc[:, None], j, axis=1)
            return acc, out

        out0 = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros_like(x), x[:, :1], 0, axis=1)
        _, out = jax.lax.fori_loop(1, P, body, (x[:, 0], out0))
        return out

    if mode == MODE_STD:
        def fn(payloads, src, perm):
            rows = jnp.take(payloads, src, axis=0)
            return jnp.take_along_axis(rows, perm, axis=1)
    else:
        def fn(payloads, src, bases):
            rows = jnp.take(payloads, src, axis=0)
            if mode == MODE_RESIDUAL:
                t = rows
            elif backend == "pallas":
                from repro.kernels.seq_cumsum import seq_cumsum
                t = seq_cumsum(rows)
            else:
                t = _seq_cumsum_jnp(rows)
            out = jnp.concatenate([bases[:, None], bases[:, None] + t],
                                  axis=1)
            if value_range is not None:
                rmin, rmax = value_range
                out = jnp.mod(out - rmin, rmax - rmin) + rmin
            return out

    fn = _dev_fns[key] = jax.jit(fn)
    return fn


def _run_device(plan: DecodePlan, backend: str) -> np.ndarray:
    """Dispatch one plan on a device backend, padding shapes to powers of
    two so serving traffic reuses compiled shapes.  f64 plans run under an
    ``enable_x64`` scope (the encoder's f32 paths are unaffected)."""
    from jax.experimental import enable_x64
    dt = np.dtype(plan.dtype)
    nb, P = plan.nb, plan.payload_width
    nbp, nrp = _pow2(nb), _pow2(len(plan.payloads) + 1)
    payloads = np.zeros((nrp, P), dtype=dt)
    payloads[:len(plan.payloads)] = plan.payloads
    src = np.full(nbp, nrp - 1, dtype=np.int64)  # pads read the zero row
    src[:nb] = plan.src
    fn = _device_fn(backend, plan.mode, plan.value_range)
    with enable_x64():
        if plan.mode == MODE_STD:
            perm = np.broadcast_to(
                np.arange(plan.block_size, dtype=np.int64),
                (nbp, plan.block_size)).copy()
            hit_pos = (np.zeros(0, dtype=np.int64) if plan.no_perm
                       else np.flatnonzero(plan.is_hit))
            if len(hit_pos):
                perm[hit_pos] = hit_perms(plan.seed, plan.block_idx[hit_pos],
                                          plan.block_size)
            out = fn(payloads, src, perm)
        else:
            bases = np.zeros(nbp, dtype=dt)
            bases[:nb] = plan.bases
            out = fn(payloads, src, bases)
        res = np.asarray(out)
    return res[:nb]


# --------------------------------------------- exactness probe + dispatch

def _probe_plan(mode: int, dtype, value_range, block_size: int,
                nb: int = 16, n_rows: int = 5) -> DecodePlan:
    """Small deterministic plan with mantissa-rich values: hits, misses,
    shared sources and (delta) long accumulation chains all present.
    The defaults are the exactness probe's; the autotuner reuses this with
    ``nb`` at the size-bucket it is timing."""
    dt = np.dtype(dtype)
    B = block_size
    P = B if mode == MODE_STD else B - 1
    bits = _splitmix64(np.arange(n_rows * P, dtype=np.uint64) + np.uint64(7))
    vals = (bits.astype(np.float64) / 2.0 ** 64 - 0.5) * 8.0
    payloads = vals.reshape(n_rows, P).astype(dt)
    src = (np.arange(nb, dtype=np.int64) * 3) % n_rows
    is_hit = np.ones(nb, dtype=bool)
    is_hit[:n_rows] = False
    bases = None
    if mode != MODE_STD:
        bbits = _splitmix64(np.arange(nb, dtype=np.uint64) + np.uint64(99))
        bases = ((bbits.astype(np.float64) / 2.0 ** 64 - 0.5) * 700.0
                 ).astype(dt)
    return DecodePlan(mode=mode, block_size=B, dtype=dt,
                      value_range=value_range, payloads=payloads, src=src,
                      bases=bases, is_hit=is_hit,
                      block_idx=np.arange(nb, dtype=np.int64), seed=3)


def _device_exact(backend: str, plan: DecodePlan) -> bool:
    """Probe (once per combination) whether ``backend`` reproduces the host
    path byte-for-byte on this device.  A failed or crashing probe routes
    the combination to the host path, with a single logged warning."""
    key = (backend, plan.mode, np.dtype(plan.dtype).str, plan.value_range,
           plan.block_size)
    ok = _exact_cache.get(key)
    if ok is None:
        probe = _probe_plan(plan.mode, plan.dtype, plan.value_range,
                            plan.block_size)
        want = _reconstruct_numpy(probe)
        try:
            got = _run_device(probe, backend)
            ok = got.tobytes() == want.tobytes()
            if not ok:
                logger.warning(
                    "decode backend %r is not byte-exact on this device for "
                    "%s; falling back to host reconstruction", backend, key)
        except Exception as e:
            ok = False
            logger.warning(
                "decode backend %r failed on this device for %s (%s); "
                "falling back to host reconstruction", backend, key, e)
        _exact_cache[key] = ok
    return ok


# ------------------------------------------------------ measured autotuner
#
# ``backend="auto"`` used to be a synonym for "jax"; it is now *measured*:
# the first time a (mode, dtype, size-bucket) combination is resolved, the
# engine times the host path against every device backend that passes the
# exactness probe on a bucket-sized probe plan, routes the combination to
# the fastest, and remembers the choice.  Choices persist in a versioned
# JSON cache (``decode_autotune.json`` by convention) when the
# ``REPRO_DECODE_AUTOTUNE`` env var names a path: the file is loaded lazily
# at first "auto" resolution and rewritten after each new probe.  A stale
# ``version`` field or a corrupt file is discarded (logged) and re-probed
# -- never trusted (DESIGN.md Sec. 9).
#
# The cache table itself (locking, lazy env load, validation, atomic
# persist) is the shared ``repro.core.tuning.MeasuredTuner`` -- the encode
# side's ``matcher="auto"`` runs on the same machinery (DESIGN.md Sec. 10);
# this module keeps only the decode-shaped parts: the probe plan, the
# exactness gating and the key format.

from .tuning import AutotuneCacheError, MeasuredTuner, best_of, pow2_bucket

AUTOTUNE_VERSION = 1
_BUCKET_MIN, _BUCKET_MAX = 64, 16384

_TUNER = MeasuredTuner(
    version=AUTOTUNE_VERSION, env_var="REPRO_DECODE_AUTOTUNE",
    validate_entry=lambda ent: ent.get("backend") in BACKENDS,
    log=logger, name="decode")


def _size_bucket(nb: int) -> int:
    """Pow-2 size bucket of a dispatch, clamped so the probe table stays
    small: everything below 64 blocks shares one bucket (dispatch overhead
    dominates), everything above 16384 another (bandwidth dominates)."""
    return pow2_bucket(nb, _BUCKET_MIN, _BUCKET_MAX)


def _autotune_key(mode: int, dtype, nb: int) -> str:
    return f"mode={mode}|dtype={np.dtype(dtype).str}|bucket={_size_bucket(nb)}"


def load_autotune(path: str, strict: bool = True) -> int:
    """Load persisted ``"auto"`` choices; returns the entry count.

    ``strict=True`` (the selfcheck contract) raises
    :class:`AutotuneCacheError` on a corrupt or version-stale file;
    ``strict=False`` (the serving path) logs, discards, and leaves the
    cache cold so the combination is re-probed."""
    return _TUNER.load(path, strict=strict)


def save_autotune(path: str) -> None:
    """Persist the in-memory choices as the versioned JSON cache (atomic
    replace, so a racing reader never sees a half-written file)."""
    _TUNER.save(path)


def reset_autotune() -> None:
    """Forget every choice (and the lazy disk load): next ``"auto"``
    resolution re-probes.  Test hook."""
    _TUNER.reset()


def autotune_choices() -> dict:
    """Current ``"auto"`` routing table: autotune key -> backend name."""
    return _TUNER.choices("backend")


def autotune_cached(mode: int, dtype, nb: int) -> bool:
    """Whether ``"auto"`` for this (mode, dtype, size-bucket) would resolve
    from cache (True) or have to run a timing probe (False).  The serving
    layer uses this to quiesce its pipeline before a cold probe -- timing
    backends while a reconstruct is in flight would poison the choice."""
    return _TUNER.cached(_autotune_key(mode, dtype, nb))


def _probe_autotune(mode: int, dtype, value_range, block_size: int,
                    bucket: int) -> dict:
    """Time host vs candidate device backends on a bucket-sized probe plan
    (pow-2 shapes, so the compiled shapes are the ones real traffic
    reuses).  Only backends that pass the exactness probe are candidates;
    ties and errors resolve toward the host path."""
    plan = _probe_plan(mode, dtype, value_range, block_size,
                       nb=bucket, n_rows=min(bucket, 64))

    times = {"numpy": best_of(lambda: _reconstruct_numpy(plan))}
    for b in BACKENDS[1:]:
        if not _device_exact(b, plan):
            continue
        try:
            times[b] = best_of(lambda: _run_device(plan, b))
        except Exception as e:
            logger.warning("autotune probe for backend %r failed (%s); "
                           "excluding it", b, e)
    # the host path wins ties: a device must be >5% faster on the probe to
    # take the route (noise margin; a near-tie is not worth the dispatch)
    backend = min(sorted(times), key=times.get)
    if times[backend] > times["numpy"] * 0.95:
        backend = "numpy"
    return {"backend": backend,
            "times_us": {k: round(v * 1e6, 3) for k, v in times.items()}}


def resolve_backend(backend: str, mode: int, dtype, nb: int,
                    value_range=None, block_size: int = 32) -> str:
    """Concrete backend for one dispatch.

    Explicit names pass through (validated); ``"auto"`` returns the
    measured-best backend for ``(mode, dtype, size bucket)`` -- probing,
    caching and (when ``REPRO_DECODE_AUTOTUNE`` is set) persisting on
    first use.  ``nb`` must be the size of the DISPATCH being routed (the
    serving layer passes its merged group's total blocks, not any single
    request's) -- routing measured at the wrong operating point would
    send large batches down a backend that only wins small ones."""
    if backend != "auto":
        if backend not in BACKENDS:
            raise ValueError(f"unknown decode backend {backend!r}; "
                             f"expected one of {BACKENDS + ('auto',)}")
        return backend
    key = _autotune_key(mode, dtype, nb)
    with _TUNER.lock:
        ent = _TUNER.lookup(key)
        if ent is not None:
            _bump("autotune_hits")
            return ent["backend"]
        ent = _TUNER.record(key, _probe_autotune(
            mode, np.dtype(dtype), value_range, block_size,
            _size_bucket(nb)))
        _bump("autotune_probes")
        logger.info("autotune: %s -> %s %s", key, ent["backend"],
                    ent["times_us"])
        return ent["backend"]


def reconstruct(plan: DecodePlan, backend: str = "numpy") -> np.ndarray:
    """Rebuild ``(nb, B)`` block values from a plan (paper Sec. V-A2/V-B2).

    ``backend`` is ``"numpy"`` (host reference), ``"jax"``/``"pallas"``
    (device; byte-identical, auto-falling back to host -- logged and
    counted in :func:`decode_stats` -- when the exactness probe fails on
    the current device), or ``"auto"`` (the measured-best backend for the
    plan's (mode, dtype, size bucket) -- :func:`resolve_backend`).
    Purely per-block math: callers may stack many ranges into one padded
    plan (:func:`pad_parts`) and slice the result apart.
    """
    if plan.nb == 0:
        # validate the name, but never autotune-probe for an empty plan
        if backend != "auto" and backend not in BACKENDS:
            raise ValueError(f"unknown decode backend {backend!r}; "
                             f"expected one of {BACKENDS + ('auto',)}")
        return np.zeros((0, plan.block_size), dtype=np.dtype(plan.dtype))
    backend = resolve_backend(backend, plan.mode, plan.dtype, plan.nb,
                              plan.value_range, plan.block_size)
    _backend_counters[backend].inc()
    if backend != "numpy":
        if _device_exact(backend, plan):
            try:
                out = _run_device(plan, backend)
            except Exception as e:
                # the probe passed but THIS shape failed (device OOM,
                # shape-specific compile error): serve the call from the
                # host instead of failing it
                logger.warning(
                    "decode backend %r failed at dispatch (nb=%d): %s; "
                    "serving this call from the host path",
                    backend, plan.nb, e)
            else:
                _bump("device_calls")
                return out
        _bump("fallbacks")
    _bump("host_calls")
    return _reconstruct_numpy(plan)
