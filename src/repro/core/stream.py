"""Byte-exact IDEALEM stream format (paper Sec. V, Figs. 8-11).

The device-side encoder (``repro.core.encoder``) emits fixed-shape per-block
decisions; this module assembles/parses the variable-length byte stream on the
host, preserving the paper's layout:

  std mode, D>=2 (Fig. 8):   miss: [idx u8][raw block 8B]   hit: [idx u8]
                             FIFO overwrite prefixes 0xFF (so D <= 255).
  std mode, D==1 (Fig. 9):   [raw block][hit-count bytes ...] repeated; a
                             count byte equal to max_count c means another
                             count byte follows (footnotes 7-8).
  res/delta, D>=2 (Fig.10):  miss: [idx][base f64][transformed (B-1)*8]
                             hit:  [idx][base f64]
  res/delta, D==1 (Fig.11):  [base][transformed]([count e][e bases])...

Misses are written verbatim (decoder reproduces them exactly); hits are
reconstructed by random permutation of the stored block (std mode) or by
re-anchoring the stored transformed values on the hit's base value
(res/delta mode; no permutation -- paper Sec. V-B2).

A fixed header (``_HDR``) + raw tail (samples not filling a block) precedes
the body.

Serialization is vectorized (DESIGN.md Sec. 4): block byte sizes, offsets
and scatter indices are computed with numpy cumsum/fancy-indexing instead of
a per-block Python loop; parsing walks only the 1-3 decision bytes per block
in Python and gathers all value payloads in one vectorized pass.  The seed
per-block loop implementations are kept as ``_assemble_stream_py`` /
``_parse_stream_py`` oracles for tests and the host-I/O microbenchmark.

Append-mode framing (DESIGN.md Sec. 3-4): a stream may be a concatenation of
*segments*, each with its own header.  Non-final segments set FLAG_MORE;
segments continuing a previous segment's dictionary state set FLAG_CONT (the
decoder carries the FIFO fill counter across, and D==1 continuation segments
open with a hit-count run for the carried dictionary entry).  One-shot
streams are a single segment with neither flag -- byte-identical to the seed
format.  ``IdealemSession`` (repro.core.session) emits these segments.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, replace
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from . import decode as decode_mod
from .decode import MODE_DELTA, MODE_RESIDUAL, MODE_STD  # noqa: F401 (re-export)

__all__ = ["StreamHeader", "StreamFormatError", "assemble_stream",
           "parse_stream", "decode_stream"]

# Historical import path: the class now lives in the unified hierarchy
# (repro.errors) under the ReproError root; same object either way.
from ..errors import StreamFormatError  # noqa: E402,F401


# Number of per-segment decision walks performed since import.  Tests use
# deltas of this counter to prove the store's range decoder parses only the
# segments covering the requested range (ISSUE 3 acceptance).
_stats = {"segment_walks": 0}


def segment_walk_count() -> int:
    return _stats["segment_walks"]

MAGIC = b"IDLM"
VERSION = 2
# Version 3 is emitted only when a v3-only feature (f16 payloads or the
# error-bounded no-permutation contract) is actually used, so v2 readers
# reject such streams with a typed StreamFormatError instead of decoding
# garbage, while every stream a v2 reader could decode stays byte-identical.
VERSION_EB = 3
FLAG_RANGE, FLAG_F32, FLAG_MORE, FLAG_CONT = 1, 2, 4, 8
FLAG_F16, FLAG_EB = 16, 32
_HDR = struct.Struct("<4sBBHBBBBddIH")  # 34 bytes (packed little-endian)


@dataclass
class StreamHeader:
    mode: int
    block_size: int
    num_dict: int
    max_count: int
    dtype: np.dtype
    value_range: Optional[Tuple[float, float]]
    n_blocks: int
    tail: np.ndarray
    more: bool = False  # another segment follows this one
    cont: bool = False  # continues the previous segment's dictionary state
    error_bounded: bool = False  # hits honored a pointwise bound; decode
    #                              skips the std-mode hit permutation

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)


def _pack_header(h: StreamHeader) -> bytes:
    flags = 0
    rmin = rmax = 0.0
    if h.value_range is not None:
        flags |= FLAG_RANGE
        rmin, rmax = float(h.value_range[0]), float(h.value_range[1])
    if np.dtype(h.dtype) == np.float32:
        flags |= FLAG_F32
    elif np.dtype(h.dtype) == np.float16:
        flags |= FLAG_F16
    elif np.dtype(h.dtype) != np.float64:
        raise ValueError(f"unsupported dtype {h.dtype}")
    if h.more:
        flags |= FLAG_MORE
    if h.cont:
        flags |= FLAG_CONT
    if h.error_bounded:
        flags |= FLAG_EB
    ver = VERSION_EB if flags & (FLAG_F16 | FLAG_EB) else VERSION
    buf = _HDR.pack(
        MAGIC, ver, h.mode, h.block_size, h.num_dict, h.max_count,
        flags, 0, rmin, rmax, h.n_blocks, len(h.tail),
    )
    return buf + np.asarray(h.tail, dtype=h.dtype).tobytes()


def _unpack_header(buf: memoryview, off: int = 0) -> Tuple[StreamHeader, int]:
    hdr_off = off
    try:
        (magic, ver, mode, bsz, ndict, maxc, flags, _rsv, rmin, rmax,
         n_blocks, tail_len) = _HDR.unpack_from(buf, off)
    except struct.error:
        raise StreamFormatError("truncated segment header", hdr_off) from None
    if magic != MAGIC:
        raise StreamFormatError("bad IDEALEM stream magic", hdr_off)
    if ver not in (VERSION, VERSION_EB):
        raise StreamFormatError(f"unsupported stream version {ver}", hdr_off)
    if mode not in (MODE_STD, MODE_RESIDUAL, MODE_DELTA):
        raise StreamFormatError(f"unknown mode byte {mode}", hdr_off)
    if bsz < 2 or ndict < 1 or maxc < 1:
        raise StreamFormatError(
            f"degenerate header fields (B={bsz}, D={ndict}, c={maxc})",
            hdr_off)
    if ver == VERSION and flags & (FLAG_F16 | FLAG_EB):
        raise StreamFormatError("v3 feature flags on a version-2 segment",
                                hdr_off)
    if (flags & FLAG_F32) and (flags & FLAG_F16):
        raise StreamFormatError("both f32 and f16 dtype flags set", hdr_off)
    if flags & FLAG_F32:
        dtype = np.float32
    elif flags & FLAG_F16:
        dtype = np.float16
    else:
        dtype = np.float64
    off += _HDR.size
    if off + tail_len * np.dtype(dtype).itemsize > len(buf):
        raise StreamFormatError(
            f"tail of {tail_len} samples overruns the buffer", off)
    tail = np.frombuffer(buf, dtype=dtype, count=tail_len, offset=off).copy()
    off += tail_len * np.dtype(dtype).itemsize
    rng = (rmin, rmax) if (flags & FLAG_RANGE) else None
    hdr = StreamHeader(mode, bsz, ndict, maxc, np.dtype(dtype), rng,
                       n_blocks, tail,
                       more=bool(flags & FLAG_MORE),
                       cont=bool(flags & FLAG_CONT),
                       error_bounded=bool(flags & FLAG_EB))
    return hdr, off


def _excl_cumsum(sizes: np.ndarray) -> np.ndarray:
    offs = np.empty_like(sizes)
    offs[0] = 0
    np.cumsum(sizes[:-1], out=offs[1:])
    return offs


def _byte_rows(a: np.ndarray, dt: np.dtype) -> np.ndarray:
    """(n, k) values -> (n, k*itemsize) little-endian byte rows."""
    a = np.ascontiguousarray(a, dtype=dt)
    return a.view(np.uint8).reshape(len(a), a.shape[1] * dt.itemsize)


def _assemble_multi(mode, dt, raw_blocks, payload_blocks, bases,
                    is_hit, slot, ovw) -> bytes:
    """Vectorized D>=2 body: per-block sizes -> offsets -> scattered writes."""
    isz = dt.itemsize
    nb, B = raw_blocks.shape
    hit_sz = 1 + (0 if mode == MODE_STD else isz)
    # miss payload is B values in every mode (std: block; res/delta: base +
    # B-1 transformed), so a miss costs [0xFF?][idx][B*isz].
    sizes = np.where(is_hit, hit_sz, 1 + B * isz + ovw).astype(np.int64)
    offs = _excl_cumsum(sizes)
    out = np.zeros(int(sizes.sum()), dtype=np.uint8)

    out[offs[ovw]] = 0xFF
    idx_pos = offs + ovw  # overwrite prefix shifts the slot byte by one
    out[idx_pos] = slot.astype(np.uint8)
    val_pos = idx_pos + 1
    miss = ~is_hit
    if mode == MODE_STD:
        rows = _byte_rows(raw_blocks[miss], dt)
        out[val_pos[miss][:, None] + np.arange(B * isz)] = rows
    else:
        out[val_pos[:, None] + np.arange(isz)] = _byte_rows(
            np.asarray(bases)[:, None], dt)
        rows = _byte_rows(payload_blocks[miss], dt)
        out[(val_pos[miss] + isz)[:, None] + np.arange((B - 1) * isz)] = rows
    return out.tobytes()


class _RunLayout(NamedTuple):
    """Byte layout of a D==1 body (relative to body start): shared between
    the vectorized assembler and parser so the math cannot diverge."""

    miss_pos: np.ndarray   # (n_miss,) block index of each miss
    k: np.ndarray          # (n_runs,) hits per run
    has_miss: np.ndarray   # (n_runs,) False only for a cont leading run
    ncb: np.ndarray        # (n_runs,) count bytes per run
    offs: np.ndarray       # (n_runs,) run start offset
    hit_off: np.ndarray    # (n_runs,) start of the count/hit-base area
    total: int             # body size in bytes


def _single_layout(is_hit: np.ndarray, c: int, cont: bool, B: int, isz: int,
                   std: bool) -> _RunLayout:
    """Run-length layout for D==1 bodies (Figs. 9/11): k hits cost
    floor(k/c)+1 count bytes; res/delta interleaves c hit bases per count."""
    nb = len(is_hit)
    miss_pos = np.flatnonzero(~is_hit)
    n_miss = len(miss_pos)
    if not cont:
        assert n_miss and miss_pos[0] == 0, "first block of a run must be a miss"
    bounds = np.concatenate([miss_pos, [nb]]).astype(np.int64)
    k_miss = np.diff(bounds) - 1  # hits trailing each miss
    if cont:
        k0 = int(miss_pos[0]) if n_miss else nb
        k = np.concatenate([[k0], k_miss]).astype(np.int64)
        has_miss = np.concatenate([[False], np.ones(n_miss, bool)])
    else:
        k = k_miss
        has_miss = np.ones(n_miss, bool)
    ncb = k // c + 1
    hit_area = ncb if std else ncb + k * isz
    sizes = has_miss * (B * isz) + hit_area
    offs = _excl_cumsum(sizes)
    return _RunLayout(miss_pos, k, has_miss, ncb, offs,
                      offs + has_miss * (B * isz), int(sizes.sum()))


def _single_hit_base_offs(lay: _RunLayout, is_hit: np.ndarray, c: int,
                          isz: int, cont: bool) -> np.ndarray:
    """res/delta D==1: byte offset of every hit's base value, in hit order."""
    hit_pos = np.flatnonzero(is_hit)
    if not len(hit_pos):
        return np.zeros(0, dtype=np.int64)
    r = np.searchsorted(lay.miss_pos, hit_pos, side="right") - 1
    run_idx = r + 1 if cont else r
    first = (np.where(r >= 0, lay.miss_pos[np.clip(r, 0, None)] + 1, 0)
             if len(lay.miss_pos) else np.zeros(len(hit_pos), dtype=np.int64))
    h = hit_pos - first  # hit ordinal within its run
    return (lay.hit_off[run_idx] + (h // c) * (1 + c * isz) + 1
            + (h % c) * isz)


def _assemble_single(mode, dt, raw_blocks, payload_blocks, bases,
                     is_hit, c, cont) -> bytes:
    """Vectorized D==1 body: hit-count runs (Figs. 9/11) via run-length math.

    With ``cont`` the segment opens with a *headless* count-run for hits on
    the dictionary entry carried from the previous segment (possibly 0).
    """
    isz = dt.itemsize
    nb, B = raw_blocks.shape
    lay = _single_layout(is_hit, c, cont, B, isz, mode == MODE_STD)
    miss_pos, k, has_miss, ncb, offs, hit_off = (
        lay.miss_pos, lay.k, lay.has_miss, lay.ncb, lay.offs, lay.hit_off)
    n_miss, n_runs = len(miss_pos), len(k)
    out = np.zeros(lay.total, dtype=np.uint8)

    if n_miss:
        moffs = offs[has_miss]
        if mode == MODE_STD:
            out[moffs[:, None] + np.arange(B * isz)] = _byte_rows(
                raw_blocks[miss_pos], dt)
        else:
            out[moffs[:, None] + np.arange(isz)] = _byte_rows(
                np.asarray(bases)[miss_pos][:, None], dt)
            out[(moffs + isz)[:, None] + np.arange((B - 1) * isz)] = (
                _byte_rows(payload_blocks[miss_pos], dt))

    stride = 1 if mode == MODE_STD else 1 + c * isz
    total_cb = int(ncb.sum())
    cnt_val = np.full(total_cb, c, dtype=np.uint8)
    cnt_val[np.cumsum(ncb) - 1] = (k % c).astype(np.uint8)
    run_id = np.repeat(np.arange(n_runs), ncb)
    g = np.arange(total_cb) - np.repeat(np.cumsum(ncb) - ncb, ncb)
    out[hit_off[run_id] + g * stride] = cnt_val

    if mode != MODE_STD:
        tgt = _single_hit_base_offs(lay, is_hit, c, isz, cont)
        if len(tgt):
            out[tgt[:, None] + np.arange(isz)] = _byte_rows(
                np.asarray(bases)[is_hit][:, None], dt)
    return out.tobytes()


def assemble_stream(
    header: StreamHeader,
    raw_blocks: np.ndarray,      # (nb, B) original values
    payload_blocks: np.ndarray,  # (nb, B) std mode / (nb, B-1) res-delta
    bases: Optional[np.ndarray],  # (nb,) res/delta mode only
    is_hit: np.ndarray,
    slot: np.ndarray,
    overwrite: np.ndarray,
) -> bytes:
    """Serialize encoder decisions into the paper's byte format (one segment).

    Byte-identical to the seed per-block loop (``_assemble_stream_py``) for
    non-continuation segments; all offset/scatter math is vectorized numpy.
    """
    dt = np.dtype(header.dtype)
    head = _pack_header(header)
    nb = len(raw_blocks)
    assert header.n_blocks == nb
    if nb == 0:
        return head
    is_hit = np.asarray(is_hit, dtype=bool)
    slot = np.asarray(slot, dtype=np.int64)
    overwrite = np.asarray(overwrite, dtype=bool)
    raw_blocks = np.asarray(raw_blocks)
    if header.num_dict >= 2:
        body = _assemble_multi(header.mode, dt, raw_blocks, payload_blocks,
                               bases, is_hit, slot, overwrite)
    else:
        body = _assemble_single(header.mode, dt, raw_blocks, payload_blocks,
                                bases, is_hit, header.max_count, header.cont)
    return head + body


# ------------------------------------------------------------------ parsing

class _Parsed(NamedTuple):
    is_hit: np.ndarray            # (nb,) bool
    slot: np.ndarray              # (nb,) int32
    overwrite: np.ndarray         # (nb,) bool
    bases: Optional[np.ndarray]   # (nb,) dt, res/delta modes only
    payloads: np.ndarray          # (n_miss, P) dt, in miss order


def _walk_segment(buf, off, header, fill, hits_b, slots_b, ovws_b):
    """Scalar walk over one segment's decision/count bytes.

    Appends one byte per block to the decision bytearrays (C-speed) and
    skips over value bytes; value offsets are NOT recorded here -- they are
    reconstructed vectorized from the decision arrays with the same layout
    math the assembler uses.  Returns (new_off, new_fill)."""
    _stats["segment_walks"] += 1
    try:
        return _walk_segment_inner(buf, off, header, fill, hits_b, slots_b,
                                   ovws_b)
    except IndexError:
        raise StreamFormatError("truncated segment body", off) from None


def _walk_segment_inner(buf, off, header, fill, hits_b, slots_b, ovws_b):
    isz = np.dtype(header.dtype).itemsize
    bsz = header.block_size
    std = header.mode == MODE_STD
    hit_val = 0 if std else isz                      # value bytes on a hit
    miss_val = (0 if std else isz) + (bsz if std else bsz - 1) * isz
    c = header.max_count

    if header.num_dict >= 2:
        nd = header.num_dict
        for _ in range(header.n_blocks):
            b = buf[off]
            off += 1
            if b == 0xFF:
                slots_b.append(buf[off])
                off += 1 + miss_val
                hits_b.append(0)
                ovws_b.append(1)
            elif b == fill and fill < nd:
                slots_b.append(b)
                off += miss_val
                hits_b.append(0)
                ovws_b.append(0)
                fill += 1
            else:
                slots_b.append(b)
                off += hit_val
                hits_b.append(1)
                ovws_b.append(0)
    else:
        n_left = header.n_blocks
        leading = header.cont  # run carried over the segment boundary
        while n_left > 0:
            if not leading:
                hits_b.append(0)
                slots_b.append(0)
                ovws_b.append(0)
                off += miss_val
                n_left -= 1
                fill = 1
            leading = False
            while True:  # one hit-count run
                e = buf[off]
                off += 1
                if e:
                    hits_b.extend(b"\x01" * e)
                    slots_b.extend(bytes(e))
                    ovws_b.extend(bytes(e))
                    off += e * hit_val
                    n_left -= e
                if e < c:
                    break
        if n_left < 0:
            raise StreamFormatError(
                "hit-count run overruns the segment block count", off)
    if off > len(buf):
        raise StreamFormatError(
            f"segment value bytes overrun the buffer by {off - len(buf)}",
            len(buf))
    return off, fill


class SegmentRef(NamedTuple):
    """One walked segment of a (possibly multi-segment) stream: where its
    body lives in the buffer, which blocks it covers, and the FIFO fill
    counter entering it.  The store's container index (repro.store) persists
    exactly this information so a segment can later be re-walked in
    isolation."""

    header: StreamHeader
    start: int       # byte offset of the segment header
    body_start: int  # byte offset of the first decision byte
    end: int         # byte offset one past the segment body
    i0: int          # index of the segment's first block within the walk
    n_blocks: int
    fill_in: int     # FIFO fill counter entering the segment


def _walk_all(buf: memoryview, off: int = 0, fill: int = 0,
              till_end: bool = False):
    """Walk a chained (FLAG_MORE) sequence of segments starting at ``off``.

    Stops after the first non-MORE segment; with ``till_end`` it instead
    walks until the buffer is exhausted (a *partial* chain -- e.g. the
    segments a live session has emitted so far, every one FLAG_MORE --
    which the store's container writer appends incrementally).

    Returns ``(segs, is_hit, slot, ovw)``: per-segment ``SegmentRef``s plus
    the concatenated per-block decision arrays."""
    hits_b = bytearray()
    slots_b = bytearray()
    ovws_b = bytearray()
    segs: List[SegmentRef] = []
    while True:
        start = off
        header, off = _unpack_header(buf, off)
        if segs and not header.cont:
            fill = 0  # restart segment: fresh dictionary state
        i0, body_start, fill_in = len(hits_b), off, fill
        off, fill = _walk_segment(buf, off, header, fill, hits_b, slots_b,
                                  ovws_b)
        segs.append(SegmentRef(header, start, body_start, off, i0,
                               len(hits_b) - i0, fill_in))
        if till_end:
            if off >= len(buf):
                break
        elif not header.more:
            break
    is_hit = np.frombuffer(hits_b, dtype=np.uint8).astype(bool)
    slot = np.frombuffer(slots_b, dtype=np.uint8).astype(np.int32)
    ovw = np.frombuffer(ovws_b, dtype=np.uint8).astype(bool)
    return segs, is_hit, slot, ovw


def _segment_offsets(header: StreamHeader, body_start: int, h: np.ndarray,
                     o: np.ndarray, cont: bool):
    """Absolute value-byte offsets for one walked segment, recomputed with
    the assembler's layout math from its decision arrays.

    Returns ``(base_offs, pay_offs)``: per-block base offsets (res/delta
    modes, else ``None``) and per-miss payload offsets in miss order."""
    dt = np.dtype(header.dtype)
    isz = dt.itemsize
    B = header.block_size
    std = header.mode == MODE_STD
    if header.num_dict >= 2:
        hit_sz = 1 + (0 if std else isz)
        sizes = np.where(h, hit_sz, 1 + B * isz + o).astype(np.int64)
        val = body_start + _excl_cumsum(sizes) + o + 1
        if std:
            return None, val[~h]
        return val, val[~h] + isz
    lay = _single_layout(h, header.max_count, cont, B, isz, std)
    moffs = body_start + lay.offs[lay.has_miss]
    if std:
        return None, moffs
    bo = np.empty(len(h), dtype=np.int64)
    bo[lay.miss_pos] = moffs
    bo[h] = body_start + _single_hit_base_offs(
        lay, h, header.max_count, isz, cont)
    return bo, moffs + isz


def _gather_values(u8: np.ndarray, dt: np.dtype, P: int, base_parts,
                   pay_parts):
    """One fancy-indexing pass over the raw bytes: per-block bases (or
    ``None`` for std mode) and the (n_miss, P) payload matrix."""
    if base_parts is None:
        bases = None
    else:
        bo = (np.concatenate(base_parts) if base_parts
              else np.zeros(0, dtype=np.int64))
        bases = decode_mod.gather_rows(u8, dt, bo, 1).ravel()
    po = (np.concatenate(pay_parts) if pay_parts
          else np.zeros(0, dtype=np.int64))
    return bases, decode_mod.gather_rows(u8, dt, po, P)


def _hdr_params(h: StreamHeader):
    """Decode-relevant header parameters (framing flags and counts excluded);
    segments whose params differ cannot share one merged plan."""
    return (h.mode, h.block_size, h.num_dict, h.max_count,
            np.dtype(h.dtype).str, h.value_range, h.error_bounded)


def _split_sections(segs: List[SegmentRef]) -> List[List[SegmentRef]]:
    """Group a walked segment chain into *restart sections*: maximal runs of
    segments whose dictionary state chains (every segment after the first
    has FLAG_CONT).  An adaptive session emits a new section per mode
    switch; plain sessions are a single section."""
    out: List[List[SegmentRef]] = []
    cur: List[SegmentRef] = []
    for seg in segs:
        if cur and not seg.header.cont:
            out.append(cur)
            cur = []
        cur.append(seg)
    out.append(cur)
    return out


def _section_arrays(u8, segs, is_hit, slot, ovw) -> Tuple[StreamHeader,
                                                          _Parsed]:
    """Merge a run of parameter-homogeneous segments (already walked) into
    struct-of-arrays form; value offsets are recomputed per segment with
    the assembler's layout math and gathered in one fancy-indexing pass."""
    for seg in segs[1:]:
        if _hdr_params(seg.header) != _hdr_params(segs[0].header):
            raise StreamFormatError(
                "segment parameters changed mid-stream; heterogeneous "
                "(adaptive) streams must be decoded with decode_stream",
                seg.start)
    i0 = segs[0].i0
    i1 = segs[-1].i0 + segs[-1].n_blocks
    merged = replace(segs[0].header, n_blocks=i1 - i0,
                     tail=segs[-1].header.tail, more=False, cont=False)
    std = merged.mode == MODE_STD
    P = merged.block_size if std else merged.block_size - 1

    base_parts = None if std else []  # per-block base offsets, block order
    pay_parts = []                    # per-miss payload offsets, miss order
    for seg in segs:
        if seg.n_blocks == 0:
            continue
        h = is_hit[seg.i0:seg.i0 + seg.n_blocks]
        o = ovw[seg.i0:seg.i0 + seg.n_blocks]
        bo, po = _segment_offsets(seg.header, seg.body_start, h, o,
                                  seg.header.cont)
        if bo is not None:
            base_parts.append(bo)
        pay_parts.append(po)

    bases, payloads = _gather_values(u8, np.dtype(merged.dtype), P,
                                     base_parts, pay_parts)
    return merged, _Parsed(is_hit[i0:i1], slot[i0:i1], ovw[i0:i1], bases,
                           payloads)


def _parse_arrays(data) -> Tuple[StreamHeader, _Parsed]:
    """Parse a (possibly multi-segment) stream into struct-of-arrays form.

    Per-block Python work is the decision-byte walk only.  Requires every
    segment to share decode parameters (raises :class:`StreamFormatError`
    for heterogeneous adaptive streams -- those decode section-by-section
    via :func:`decode_stream`); parameter-homogeneous restarts merge fine
    because a restarted dictionary's hits still source the most recent
    miss written to their slot."""
    buf = memoryview(data)
    u8 = np.frombuffer(buf, dtype=np.uint8)
    segs, is_hit, slot, ovw = _walk_all(buf)
    return _section_arrays(u8, segs, is_hit, slot, ovw)


def parse_stream(data):
    """Parse a stream into (header, events); each event is a dict with
    kind in {'miss','hit'} plus per-kind payload.  Multi-segment (session)
    streams are merged: the returned header carries the total block count
    and the final segment's tail."""
    header, pr = _parse_arrays(data)
    std = header.mode == MODE_STD
    hits_l = pr.is_hit.tolist()
    slots_l = pr.slot.tolist()
    ovw_l = pr.overwrite.tolist()
    bases_l = None if std else pr.bases.tolist()
    pay_rows = list(pr.payloads)  # row views into the gathered matrix
    events = []
    mi = 0
    for i, ih in enumerate(hits_l):
        if ih:
            ev = {"kind": "hit", "slot": slots_l[i]}
            if not std:
                ev["base"] = bases_l[i]
        else:
            ev = {"kind": "miss", "slot": slots_l[i], "overwrite": ovw_l[i]}
            if not std:
                ev["base"] = bases_l[i]
            ev["payload"] = pay_rows[mi]
            mi += 1
        events.append(ev)
    return header, events


# Reconstruction itself lives in the unified decode engine (repro.core.
# decode, DESIGN.md Sec. 8); these aliases keep the historical access
# points of the parsing layer working.
_splitmix64 = decode_mod._splitmix64
_hit_perms = decode_mod.hit_perms
_decode_sources = decode_mod.decode_sources


def decode_stream(data: bytes, seed: int = 0,
                  backend: str = "numpy") -> np.ndarray:
    """Full decoder: parse -> ``DecodePlan`` -> ``decode.reconstruct``
    (paper Sec. V-A2/V-B2).

    Hits source the most recent miss written to their slot; std-mode hits
    are random permutations of that block, res/delta hits re-anchor the
    stored transformed values on the hit's own base.  ``backend`` selects
    the reconstruction backend (``repro.core.decode.BACKENDS``); every
    backend is byte-identical (device backends fall back to the host when
    the exactness probe fails -- logged).

    Note: each hit's permutation is drawn statelessly from ``(seed, block
    position)`` (``decode.hit_perms``), so the sampled permutations differ
    from the seed decoder's sequential per-hit draws.  Any permutation is a
    valid reconstruction (the format pins bytes, not the decoder's RNG
    sequence); decode is deterministic for a fixed stream + seed, and
    positional keying makes ``repro.store`` range decodes exact slices of
    this output.

    Heterogeneous (adaptive-session) streams -- segment parameters changing
    at a dictionary restart -- are decoded section by section with each
    section's own header parameters; the outputs (and each section's tail)
    concatenate in stream order.
    """
    buf = memoryview(data)
    u8 = np.frombuffer(buf, dtype=np.uint8)
    segs, is_hit, slot, ovw = _walk_all(buf)
    dt0 = np.dtype(segs[0].header.dtype)
    outs = []
    for section in _split_sections(segs):
        header, pr = _section_arrays(u8, section, is_hit, slot, ovw)
        if np.dtype(header.dtype) != dt0:
            raise StreamFormatError("dtype changed across restart sections",
                                    section[0].start)
        if len(pr.is_hit):
            plan = decode_mod.plan_from_parsed(header, pr, seed=seed,
                                               i0=section[0].i0)
            outs.append(decode_mod.reconstruct(plan,
                                               backend=backend).ravel())
        if len(header.tail):
            outs.append(np.asarray(header.tail, dtype=dt0))
    if not outs:
        return np.zeros((0,), dtype=dt0)
    return np.concatenate(outs)


# ----------------------------------------------- seed per-block loop oracles
# Kept verbatim for byte-identity tests and the bench_stream_io before/after
# comparison; single-segment only (no MORE/CONT framing).

def _emit_counts(out: bytearray, k: int, c: int) -> None:
    """Hit-count run-length bytes: byte==c signals continuation."""
    while True:
        e = min(k, c)
        out.append(e)
        k -= e
        if e < c:
            break


def _assemble_stream_py(header, raw_blocks, payload_blocks, bases,
                        is_hit, slot, overwrite) -> bytes:
    """Seed O(n_blocks) Python-loop serializer (reference)."""
    mode, ndict, c = header.mode, header.num_dict, header.max_count
    dt = np.dtype(header.dtype)
    out = bytearray(_pack_header(header))
    nb = len(raw_blocks)
    assert header.n_blocks == nb

    if ndict >= 2:
        for i in range(nb):
            if is_hit[i]:
                out.append(int(slot[i]))
                if mode != MODE_STD:
                    out += np.asarray(bases[i], dtype=dt).tobytes()
            else:
                if overwrite[i]:
                    out.append(0xFF)
                out.append(int(slot[i]))
                if mode == MODE_STD:
                    out += np.ascontiguousarray(raw_blocks[i], dtype=dt).tobytes()
                else:
                    out += np.asarray(bases[i], dtype=dt).tobytes()
                    out += np.ascontiguousarray(payload_blocks[i], dtype=dt).tobytes()
    else:  # single dictionary block: hit-count structure
        i = 0
        while i < nb:
            assert not is_hit[i], "first block of a run must be a miss"
            if mode == MODE_STD:
                out += np.ascontiguousarray(raw_blocks[i], dtype=dt).tobytes()
            else:
                out += np.asarray(bases[i], dtype=dt).tobytes()
                out += np.ascontiguousarray(payload_blocks[i], dtype=dt).tobytes()
            j = i + 1
            hit_bases = []
            while j < nb and is_hit[j]:
                if mode != MODE_STD:
                    hit_bases.append(bases[j])
                j += 1
            k = j - i - 1
            if mode == MODE_STD:
                _emit_counts(out, k, c)
            else:
                # interleave counts with their base values (Fig. 11)
                done = 0
                while True:
                    e = min(k - done, c)
                    out.append(e)
                    for b in hit_bases[done:done + e]:
                        out += np.asarray(b, dtype=dt).tobytes()
                    done += e
                    if e < c:
                        break
            i = j
    return bytes(out)


def _parse_stream_py(data):
    """Seed per-block-loop parser (reference; single segment)."""
    buf = memoryview(data)
    header, off = _unpack_header(buf)
    dt = np.dtype(header.dtype)
    isz = dt.itemsize
    bsz = header.block_size
    n_payload = bsz if header.mode == MODE_STD else bsz - 1
    events = []

    def read_vals(n):
        nonlocal off
        v = np.frombuffer(buf, dtype=dt, count=n, offset=off).copy()
        off += n * isz
        return v

    if header.num_dict >= 2:
        fill = 0
        while len(events) < header.n_blocks:
            b = buf[off]; off += 1
            ovw = False
            if b == 0xFF:
                ovw = True
                b = buf[off]; off += 1
            s = int(b)
            if ovw or (s == fill and fill < header.num_dict):
                ev = {"kind": "miss", "slot": s, "overwrite": ovw}
                if header.mode != MODE_STD:
                    ev["base"] = float(read_vals(1)[0])
                ev["payload"] = read_vals(n_payload)
                if not ovw:
                    fill += 1
                events.append(ev)
            else:
                ev = {"kind": "hit", "slot": s}
                if header.mode != MODE_STD:
                    ev["base"] = float(read_vals(1)[0])
                events.append(ev)
    else:
        c = header.max_count
        while len(events) < header.n_blocks:
            ev = {"kind": "miss", "slot": 0, "overwrite": False}
            if header.mode != MODE_STD:
                ev["base"] = float(read_vals(1)[0])
            ev["payload"] = read_vals(n_payload)
            events.append(ev)
            while True:
                e = buf[off]; off += 1
                for _ in range(e):
                    hev = {"kind": "hit", "slot": 0}
                    if header.mode != MODE_STD:
                        hev["base"] = float(read_vals(1)[0])
                    events.append(hev)
                if e < c:
                    break
    return header, events
