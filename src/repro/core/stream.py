"""Byte-exact IDEALEM stream format (paper Sec. V, Figs. 8-11).

The device-side encoder (``repro.core.encoder``) emits fixed-shape per-block
decisions; this module assembles/parses the variable-length byte stream on the
host, preserving the paper's layout:

  std mode, D>=2 (Fig. 8):   miss: [idx u8][raw block 8B]   hit: [idx u8]
                             FIFO overwrite prefixes 0xFF (so D <= 255).
  std mode, D==1 (Fig. 9):   [raw block][hit-count bytes ...] repeated; a
                             count byte equal to max_count c means another
                             count byte follows (footnotes 7-8).
  res/delta, D>=2 (Fig.10):  miss: [idx][base f64][transformed (B-1)*8]
                             hit:  [idx][base f64]
  res/delta, D==1 (Fig.11):  [base][transformed]([count e][e bases])...

Misses are written verbatim (decoder reproduces them exactly); hits are
reconstructed by random permutation of the stored block (std mode) or by
re-anchoring the stored transformed values on the hit's base value
(res/delta mode; no permutation -- paper Sec. V-B2).

A 40-byte header + raw tail (samples not filling a block) precedes the body.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from .transforms import np_wrap_range

__all__ = ["StreamHeader", "assemble_stream", "parse_stream", "decode_stream"]

MAGIC = b"IDLM"
VERSION = 2
MODE_STD, MODE_RESIDUAL, MODE_DELTA = 0, 1, 2
_HDR = struct.Struct("<4sBBHBBBBddIH")  # 40 bytes


@dataclass
class StreamHeader:
    mode: int
    block_size: int
    num_dict: int
    max_count: int
    dtype: np.dtype
    value_range: Optional[Tuple[float, float]]
    n_blocks: int
    tail: np.ndarray

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)


def _pack_header(h: StreamHeader) -> bytes:
    flags = 0
    rmin = rmax = 0.0
    if h.value_range is not None:
        flags |= 1
        rmin, rmax = float(h.value_range[0]), float(h.value_range[1])
    if np.dtype(h.dtype) == np.float32:
        flags |= 2
    elif np.dtype(h.dtype) != np.float64:
        raise ValueError(f"unsupported dtype {h.dtype}")
    buf = _HDR.pack(
        MAGIC, VERSION, h.mode, h.block_size, h.num_dict, h.max_count,
        flags, 0, rmin, rmax, h.n_blocks, len(h.tail),
    )
    return buf + np.asarray(h.tail, dtype=h.dtype).tobytes()


def _unpack_header(buf: memoryview) -> Tuple[StreamHeader, int]:
    (magic, ver, mode, bsz, ndict, maxc, flags, _rsv, rmin, rmax,
     n_blocks, tail_len) = _HDR.unpack_from(buf, 0)
    if magic != MAGIC or ver != VERSION:
        raise ValueError("bad IDEALEM stream header")
    dtype = np.float32 if (flags & 2) else np.float64
    off = _HDR.size
    tail = np.frombuffer(buf, dtype=dtype, count=tail_len, offset=off).copy()
    off += tail_len * np.dtype(dtype).itemsize
    rng = (rmin, rmax) if (flags & 1) else None
    return (
        StreamHeader(mode, bsz, ndict, maxc, np.dtype(dtype), rng, n_blocks, tail),
        off,
    )


def _emit_counts(out: bytearray, k: int, c: int) -> None:
    """Hit-count run-length bytes: byte==c signals continuation."""
    while True:
        e = min(k, c)
        out.append(e)
        k -= e
        if e < c:
            break


def assemble_stream(
    header: StreamHeader,
    raw_blocks: np.ndarray,      # (nb, B) original values
    payload_blocks: np.ndarray,  # (nb, B) std mode / (nb, B-1) res-delta
    bases: Optional[np.ndarray],  # (nb,) res/delta mode only
    is_hit: np.ndarray,
    slot: np.ndarray,
    overwrite: np.ndarray,
) -> bytes:
    """Serialize encoder decisions into the paper's byte format."""
    mode, ndict, c = header.mode, header.num_dict, header.max_count
    dt = np.dtype(header.dtype)
    out = bytearray(_pack_header(header))
    nb = len(raw_blocks)
    assert header.n_blocks == nb

    if ndict >= 2:
        for i in range(nb):
            if is_hit[i]:
                out.append(int(slot[i]))
                if mode != MODE_STD:
                    out += np.asarray(bases[i], dtype=dt).tobytes()
            else:
                if overwrite[i]:
                    out.append(0xFF)
                out.append(int(slot[i]))
                if mode == MODE_STD:
                    out += np.ascontiguousarray(raw_blocks[i], dtype=dt).tobytes()
                else:
                    out += np.asarray(bases[i], dtype=dt).tobytes()
                    out += np.ascontiguousarray(payload_blocks[i], dtype=dt).tobytes()
    else:  # single dictionary block: hit-count structure
        i = 0
        while i < nb:
            assert not is_hit[i], "first block of a run must be a miss"
            if mode == MODE_STD:
                out += np.ascontiguousarray(raw_blocks[i], dtype=dt).tobytes()
            else:
                out += np.asarray(bases[i], dtype=dt).tobytes()
                out += np.ascontiguousarray(payload_blocks[i], dtype=dt).tobytes()
            j = i + 1
            hit_bases = []
            while j < nb and is_hit[j]:
                if mode != MODE_STD:
                    hit_bases.append(bases[j])
                j += 1
            k = j - i - 1
            if mode == MODE_STD:
                _emit_counts(out, k, c)
            else:
                # interleave counts with their base values (Fig. 11)
                done = 0
                while True:
                    e = min(k - done, c)
                    out.append(e)
                    for b in hit_bases[done:done + e]:
                        out += np.asarray(b, dtype=dt).tobytes()
                    done += e
                    if e < c:
                        break
            i = j
    return bytes(out)


def parse_stream(data: bytes):
    """Parse a stream into (header, events); each event is a dict with
    kind in {'miss','hit'} plus per-kind payload."""
    buf = memoryview(data)
    header, off = _unpack_header(buf)
    dt = np.dtype(header.dtype)
    isz = dt.itemsize
    bsz = header.block_size
    n_payload = bsz if header.mode == MODE_STD else bsz - 1
    events = []

    def read_vals(n):
        nonlocal off
        v = np.frombuffer(buf, dtype=dt, count=n, offset=off).copy()
        off += n * isz
        return v

    if header.num_dict >= 2:
        fill = 0
        while len(events) < header.n_blocks:
            b = buf[off]; off += 1
            ovw = False
            if b == 0xFF:
                ovw = True
                b = buf[off]; off += 1
            s = int(b)
            if ovw or (s == fill and fill < header.num_dict):
                ev = {"kind": "miss", "slot": s, "overwrite": ovw}
                if header.mode != MODE_STD:
                    ev["base"] = float(read_vals(1)[0])
                ev["payload"] = read_vals(n_payload)
                if not ovw:
                    fill += 1
                events.append(ev)
            else:
                ev = {"kind": "hit", "slot": s}
                if header.mode != MODE_STD:
                    ev["base"] = float(read_vals(1)[0])
                events.append(ev)
    else:
        c = header.max_count
        while len(events) < header.n_blocks:
            ev = {"kind": "miss", "slot": 0, "overwrite": False}
            if header.mode != MODE_STD:
                ev["base"] = float(read_vals(1)[0])
            ev["payload"] = read_vals(n_payload)
            events.append(ev)
            while True:
                e = buf[off]; off += 1
                for _ in range(e):
                    hev = {"kind": "hit", "slot": 0}
                    if header.mode != MODE_STD:
                        hev["base"] = float(read_vals(1)[0])
                    events.append(hev)
                if e < c:
                    break
    return header, events


def decode_stream(data: bytes, seed: int = 0) -> np.ndarray:
    """Full decoder: parse + reconstruct (paper Sec. V-A2 / V-B2)."""
    header, events = parse_stream(data)
    rng = np.random.default_rng(seed)
    dictionary = {}
    out = []
    for ev in events:
        if ev["kind"] == "miss":
            dictionary[ev["slot"]] = ev["payload"]
            payload = ev["payload"]
        else:
            payload = dictionary[ev["slot"]]
        if header.mode == MODE_STD:
            if ev["kind"] == "miss":
                out.append(payload)  # initiating sequence kept verbatim
            else:
                out.append(rng.permutation(payload))  # without replacement
        else:
            base = ev["base"]
            if header.mode == MODE_RESIDUAL:
                vals = np.concatenate([[base], base + payload])
            else:  # delta
                vals = np.concatenate([[base], base + np.cumsum(payload)])
            if header.value_range is not None:
                vals = np_wrap_range(vals, *header.value_range)
            out.append(vals)
    out.append(header.tail)
    return np.concatenate(out) if out else np.zeros((0,), dtype=header.dtype)
