"""The IDEALEM encoder as a jit-compiled ``lax.scan`` (DESIGN.md Sec. 2).

The reference C encoder walks the dictionary and early-exits at the first
KS pass.  On TPU we compute the min/max gate (eq. 3) and the KS distance
against *all* D entries as dense masked work and select the lowest-index
passing entry -- decision-identical to the early-exit scan, but fully
vectorized (VPU) and batchable over channels with ``vmap``.

Per-block outputs are fixed-shape decisions (is_hit, slot, overwrite); the
variable-length byte stream is assembled host-side by ``repro.core.stream``
from these decisions plus the raw blocks.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .ks import ks_statistic_many

__all__ = ["DictState", "EncoderParams", "init_state", "encode_decisions"]


class DictState(NamedTuple):
    """Carry state of the encoder scan: the FIFO dictionary buffer."""

    sorted_blocks: jax.Array  # (D, n) sorted source-distribution samples
    dmin: jax.Array  # (D,)
    dmax: jax.Array  # (D,)
    valid: jax.Array  # (D,) bool
    count: jax.Array  # () int32, number of inserts so far (FIFO position)


class EncoderParams(NamedTuple):
    d_crit: float  # critical KS distance (from alpha via ks.critical_distance)
    rel_tol: float  # relative tolerance r for the min/max check (eq. 3)
    use_minmax: bool  # paper's new gate; False = "KS test only" mode
    use_ks: bool = True  # False = min/max check alone (ablation)


def init_state(num_dict: int, n: int, dtype=jnp.float32) -> DictState:
    return DictState(
        sorted_blocks=jnp.zeros((num_dict, n), dtype=dtype),
        dmin=jnp.zeros((num_dict,), dtype=dtype),
        dmax=jnp.zeros((num_dict,), dtype=dtype),
        valid=jnp.zeros((num_dict,), dtype=bool),
        count=jnp.zeros((), dtype=jnp.int32),
    )


def _minmax_gate(xmin, xmax, dmin, dmax, r):
    """Eq. (3): both block extremes inside +-w*r of the stored extremes."""
    w = dmax - dmin
    t = w * r
    return (
        (xmin >= dmin - t)
        & (xmin <= dmin + t)
        & (xmax >= dmax - t)
        & (xmax <= dmax + t)
    )


def _step(matcher, params: EncoderParams, state: DictState, block: jax.Array):
    num_dict = state.sorted_blocks.shape[0]
    xs = jnp.sort(block)
    xmin, xmax = xs[0], xs[-1]

    if params.use_minmax:
        mm = _minmax_gate(xmin, xmax, state.dmin, state.dmax, params.rel_tol)
    else:
        mm = jnp.ones((num_dict,), dtype=bool)

    if params.use_ks:
        ks = matcher(xs, state.sorted_blocks)  # (D,)
        ks_ok = ks <= params.d_crit
    else:
        ks_ok = jnp.ones((num_dict,), dtype=bool)

    ok = state.valid & mm & ks_ok
    is_hit = jnp.any(ok)
    first_hit = jnp.argmax(ok)  # lowest passing slot == early-exit result

    # FIFO insert slot on miss: fill 0..D-1, then overwrite oldest.
    ins_slot = jnp.mod(state.count, num_dict)
    overwrite = (~is_hit) & (state.count >= num_dict)
    slot = jnp.where(is_hit, first_hit, ins_slot).astype(jnp.int32)

    do_ins = ~is_hit
    new_sorted = jax.lax.dynamic_update_slice(
        state.sorted_blocks, xs[None, :], (ins_slot, 0)
    )
    upd = jnp.arange(num_dict) == ins_slot
    new_state = DictState(
        sorted_blocks=jnp.where(do_ins, new_sorted, state.sorted_blocks),
        dmin=jnp.where(do_ins & upd, xmin, state.dmin),
        dmax=jnp.where(do_ins & upd, xmax, state.dmax),
        valid=jnp.where(do_ins & upd, True, state.valid),
        count=state.count + do_ins.astype(jnp.int32),
    )
    return new_state, (is_hit, slot, overwrite)


@functools.partial(
    jax.jit, static_argnames=("num_dict", "d_crit", "rel_tol", "use_minmax", "use_ks", "matcher")
)
def encode_decisions(
    blocks: jax.Array,
    *,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    matcher: Optional[Callable] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Encode a (nb, n) stack of (already transformed) blocks.

    Returns (is_hit (nb,), slot (nb,), overwrite (nb,)).
    ``matcher(xs_sorted, dict_sorted) -> (D,)`` defaults to the pure-jnp KS
    oracle; pass ``repro.kernels.ops.dict_match_ks`` for the Pallas kernel.
    Batch over channels with ``jax.vmap`` on the leading axis.
    """
    if matcher is None:
        matcher = ks_statistic_many
    params = EncoderParams(
        d_crit=d_crit, rel_tol=rel_tol, use_minmax=use_minmax, use_ks=use_ks
    )
    state0 = init_state(num_dict, blocks.shape[-1], dtype=blocks.dtype)
    step = functools.partial(_step, matcher, params)
    _, (is_hit, slot, overwrite) = jax.lax.scan(step, state0, blocks)
    return is_hit, slot, overwrite


def encode_decisions_batched(blocks_cn, **kw):
    """vmap over a leading channel axis: blocks (C, nb, n)."""
    fn = functools.partial(encode_decisions, **kw)
    return jax.vmap(fn)(blocks_cn)
