"""The IDEALEM encoder as a jit-compiled ``lax.scan`` (DESIGN.md Sec. 2).

The reference C encoder walks the dictionary and early-exits at the first
KS pass.  On TPU we compute the min/max gate (eq. 3) and the KS distance
against *all* D entries as dense masked work and select the lowest-index
passing entry -- decision-identical to the early-exit scan, but fully
vectorized (VPU) and batchable over channels with ``vmap``.

Streaming (DESIGN.md Sec. 3): ``DictState`` is a first-class resumable
carry.  ``encode_decisions(..., state=s)`` continues a scan where the last
chunk stopped and returns the updated state, so a live stream encoded in
chunks makes exactly the same hit/miss decisions as one monolithic scan.
On accelerators the incoming state buffers are donated to the jitted scan,
so resuming does not hold two copies of the dictionary in device memory.

Per-block outputs are fixed-shape decisions (is_hit, slot, overwrite); the
variable-length byte stream is assembled host-side by ``repro.core.stream``
from these decisions plus the raw blocks.

Matchers fuse the two similarity checks: ``matcher(xs_sorted, dict_sorted,
dmin, dmax, rel_tol) -> (ks (D,), mm (D,))``.  The default is the pure-jnp
oracle below; ``repro.kernels.ops.dict_match`` is the Pallas kernel with
the same signature, whose fused min/max gate is consumed directly instead
of being recomputed outside the kernel.

Beyond callables, ``matcher=`` accepts names (DESIGN.md Sec. 10):
``"reference"`` (jnp oracle), ``"ops"`` (pallas matcher + jnp step),
``"fused"`` (the single-dispatch ``kernels.encode_step`` kernel that also
applies the threshold, arg-min and FIFO overwrite), and ``"auto"`` (the
measured pick per (D, n, dtype) via the shared ``core.tuning`` machinery,
persisted under ``REPRO_ENCODE_AUTOTUNE``).
"""
from __future__ import annotations

import functools
import logging
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .ks import ks_statistic_many, ks_statistic_many_masked
from .tuning import MeasuredTuner, best_of

__all__ = [
    "DictState",
    "EncoderParams",
    "ChanParams",
    "init_state",
    "repad_state_n",
    "matcher_reference",
    "resolve_matcher",
    "encode_decisions",
    "encode_decisions_batched",
    "encode_decisions_mixed",
    "encode_decisions_mixed_sharded",
    "encode_decisions_sharded",
    "encode_decisions_dsharded",
    "MATCHERS",
    "load_encode_autotune",
    "save_encode_autotune",
    "reset_encode_autotune",
    "encode_autotune_choices",
    "encode_autotune_cached",
]

logger = logging.getLogger("repro.core.encoder")

# "no entry passed" marker for cross-shard/cross-tile arg-min reductions;
# any real dictionary index (< 2^8) is far below it.
_SENTINEL = 2 ** 30



class DictState(NamedTuple):
    """Resumable carry of the encoder scan: the FIFO dictionary buffer.

    Thread it through chunked calls of ``encode_decisions`` to continue a
    stream.  Batched (multi-channel) states carry one leading ``(C,)`` axis
    on every field (see ``init_state(channels=...)``).
    """

    sorted_blocks: jax.Array  # (D, n) sorted source-distribution samples
    dmin: jax.Array  # (D,)
    dmax: jax.Array  # (D,)
    valid: jax.Array  # (D,) bool
    count: jax.Array  # () int32, number of inserts so far (FIFO position)
    # (D, n) raw (stream-order) payload rows, kept only for the error-bounded
    # mode's pointwise |x - x_hat| check; (0, n) when the mode is off so the
    # pytree structure (and partition specs) stay constant at zero cost.
    raw_blocks: jax.Array


class EncoderParams(NamedTuple):
    d_crit: float  # critical KS distance (from alpha via ks.critical_distance)
    rel_tol: float  # relative tolerance r for the min/max check (eq. 3)
    use_minmax: bool  # paper's new gate; False = "KS test only" mode
    use_ks: bool = True  # False = min/max check alone (ablation)
    # error-bounded mode (2404.02840 taxonomy): a would-be hit whose
    # pointwise reconstruction error exceeds the bound is demoted to a miss.
    # None disables the check; error_cumulative bounds the running cumsum of
    # the payload difference instead (delta mode, where decoded samples are
    # base + cumsum of stored diffs).
    error_bound: Optional[float] = None
    error_cumulative: bool = False


class ChanParams(NamedTuple):
    """Per-channel *traced* parameters of the masked mixed-mode scan
    (adaptive sessions, DESIGN.md Sec. 13).  Callers pass ``(C,)`` arrays;
    under the channel vmap every field is a scalar.  Built host-side by
    ``_chan_params_host`` so the float rounding matches the static paths
    exactly (``inv_n`` is the f32 rounding of the python-float ``1/n`` the
    fused kernel closes over)."""

    n: jax.Array  # () int32 logical payload width (<= padded cohort max)
    nf: jax.Array  # () f32 float(n): the reference matcher's ECDF divisor
    inv_n: jax.Array  # () f32 f32(1/n): the fused kernel's ECDF multiplier
    d_crit: jax.Array  # () f32 per-channel threshold (selector-scaled)
    err_cum: jax.Array  # () bool cumulative error metric (delta mode)
    eb_on: jax.Array  # () bool error-bound gate armed for this channel


def init_state(num_dict: int, n: int, dtype=jnp.float32,
               channels: Optional[int] = None,
               raw: bool = False) -> DictState:
    """Fresh (empty-dictionary) carry; ``channels=C`` stacks C independent
    per-channel states on a leading axis for the batched encoder.  ``raw``
    allocates the raw-payload rows the error-bounded check matches against
    (required whenever ``error_bound`` is set)."""
    lead = () if channels is None else (channels,)
    return DictState(
        sorted_blocks=jnp.zeros(lead + (num_dict, n), dtype=dtype),
        dmin=jnp.zeros(lead + (num_dict,), dtype=dtype),
        dmax=jnp.zeros(lead + (num_dict,), dtype=dtype),
        valid=jnp.zeros(lead + (num_dict,), dtype=bool),
        count=jnp.zeros(lead, dtype=jnp.int32),
        raw_blocks=jnp.zeros(lead + (num_dict if raw else 0, n),
                             dtype=dtype),
    )


def repad_state_n(state: DictState, n_new: int) -> DictState:
    """Re-pad the trailing payload-width axis of a (batched) mixed carry
    when the cohort's max live width changes.  Grown columns are ``+inf``
    (the pad value of inserted rows -- sorted rows stay sorted).  Shrinking
    slices pad columns off, which is only sound when every remaining valid
    row's logical width is <= ``n_new``; the session resets a lane before
    its width changes, so that invariant always holds."""
    n_old = state.sorted_blocks.shape[-1]
    if n_new == n_old:
        return state

    def fit(a):
        if n_new > n_old:
            pad = [(0, 0)] * (a.ndim - 1) + [(0, n_new - n_old)]
            return jnp.pad(a, pad, constant_values=jnp.inf)
        return a[..., :n_new]

    raw = state.raw_blocks
    if raw.shape[-2]:
        raw = fit(raw)
    return state._replace(sorted_blocks=fit(state.sorted_blocks),
                          raw_blocks=raw)


def _error_gate(block, raw_blocks, params: EncoderParams):
    """Per-entry pointwise error check: ``max|err| <= bound`` where err is
    the payload difference (std/residual: decoded samples differ from the
    original by exactly this) or its running cumsum (delta: decoded samples
    are base + cumsum of stored diffs).  With a value_range the bound holds
    in the circular metric (payloads are wrap-centered)."""
    diff = block[None, :] - raw_blocks
    if params.error_cumulative:
        diff = jnp.cumsum(diff, axis=-1)
    return jnp.max(jnp.abs(diff), axis=-1) <= params.error_bound


def _minmax_gate(xmin, xmax, dmin, dmax, r):
    """Eq. (3): both block extremes inside +-w*r of the stored extremes."""
    w = dmax - dmin
    t = w * r
    return (
        (xmin >= dmin - t)
        & (xmin <= dmin + t)
        & (xmax >= dmax - t)
        & (xmax <= dmax + t)
    )


def matcher_reference(xs_sorted, dict_sorted, dmin, dmax, rel_tol):
    """Default pure-jnp matcher: (ks (D,), mm (D,)) against all entries."""
    ks = ks_statistic_many(xs_sorted, dict_sorted)
    mm = _minmax_gate(xs_sorted[0], xs_sorted[-1], dmin, dmax, rel_tol)
    return ks, mm


def _step(matcher, params: EncoderParams, state: DictState, blk):
    """One scan step over ``(block, xs_sorted, block_valid)``.

    The per-block sort is hoisted out of the step: every scan entry point
    sorts the whole ``(nb, n)`` batch once (``jnp.sort(..., axis=-1)`` is
    bitwise identical to a per-step ``jnp.sort``) and threads the sorted
    rows alongside the raw ones, so the step itself is pure matching.

    ``block_valid`` is the ragged-batch padding mask: a False step is a
    no-op -- the carry passes through untouched and the decision triple is
    all-zero -- so channels with fewer real blocks than the padded batch
    (coalesced serving batches, sharded channel padding) stay
    decision-identical to an unpadded scan.
    """
    block, xs, valid = blk
    num_dict = state.sorted_blocks.shape[0]
    xmin, xmax = xs[0], xs[-1]

    ks, mm = matcher(xs, state.sorted_blocks, state.dmin, state.dmax,
                     params.rel_tol)
    ones = jnp.ones((num_dict,), dtype=bool)
    mm_ok = mm if params.use_minmax else ones
    ks_ok = (ks <= params.d_crit) if params.use_ks else ones

    ok = state.valid & mm_ok & ks_ok
    if params.error_bound is not None:
        ok = ok & _error_gate(block, state.raw_blocks, params)
    is_hit = jnp.any(ok) & valid
    first_hit = jnp.argmax(ok)  # lowest passing slot == early-exit result

    # FIFO insert slot on miss: fill 0..D-1, then overwrite oldest.
    ins_slot = jnp.mod(state.count, num_dict)
    do_ins = (~is_hit) & valid
    overwrite = do_ins & (state.count >= num_dict)
    slot = jnp.where(is_hit, first_hit, ins_slot).astype(jnp.int32)
    slot = jnp.where(valid, slot, 0)

    new_sorted = jax.lax.dynamic_update_slice(
        state.sorted_blocks, xs[None, :], (ins_slot, 0)
    )
    upd = jnp.arange(num_dict) == ins_slot
    raw_blocks = state.raw_blocks
    if params.error_bound is not None:
        new_raw = jax.lax.dynamic_update_slice(
            raw_blocks, block[None, :], (ins_slot, 0))
        raw_blocks = jnp.where(do_ins, new_raw, raw_blocks)
    new_state = DictState(
        sorted_blocks=jnp.where(do_ins, new_sorted, state.sorted_blocks),
        dmin=jnp.where(do_ins & upd, xmin, state.dmin),
        dmax=jnp.where(do_ins & upd, xmax, state.dmax),
        valid=jnp.where(do_ins & upd, True, state.valid),
        count=state.count + do_ins.astype(jnp.int32),
        raw_blocks=raw_blocks,
    )
    return new_state, (is_hit, slot, overwrite)


# ------------------------------------------------------- fused kernel step
def _is_fused(matcher) -> bool:
    """The fused matcher travels through the jit machinery as the hashable
    static value ``("fused", tile_d)`` rather than a callable."""
    return isinstance(matcher, tuple) and len(matcher) == 2 \
        and matcher[0] == "fused"


def _pad_state_d(state: DictState, pad: int) -> DictState:
    """Pad the dictionary axis with ``valid=False`` rows (tile alignment for
    the fused kernel, shard alignment for D-sharding).  Pad rows never pass
    the gate and are never inserted (FIFO slot uses the logical D)."""
    if pad == 0:
        return state
    raw = state.raw_blocks
    if raw.shape[0]:  # empty (0, n) raw stays empty: the mode is off
        raw = jnp.pad(raw, ((0, pad), (0, 0)))
    return DictState(
        sorted_blocks=jnp.pad(state.sorted_blocks, ((0, pad), (0, 0))),
        dmin=jnp.pad(state.dmin, (0, pad)),
        dmax=jnp.pad(state.dmax, (0, pad)),
        valid=jnp.pad(state.valid, (0, pad)),
        count=state.count,
        raw_blocks=raw,
    )


def _slice_state_d(state: DictState, num_dict: int) -> DictState:
    """Inverse of ``_pad_state_d``: back to the logical-D resumable carry."""
    if state.sorted_blocks.shape[0] == num_dict:
        return state
    raw = state.raw_blocks
    if raw.shape[0]:
        raw = raw[:num_dict]
    return DictState(
        sorted_blocks=state.sorted_blocks[:num_dict],
        dmin=state.dmin[:num_dict],
        dmax=state.dmax[:num_dict],
        valid=state.valid[:num_dict],
        count=state.count,
        raw_blocks=raw,
    )


def _step_fused(tile_d: int, params: EncoderParams, num_dict: int,
                state: DictState, blk):
    """Fused-kernel scan step: one pallas dispatch computes gate + masked KS
    + arg-min + FIFO overwrite and returns the updated (padded) carry.
    Decision-identical to ``_step`` with the ``ops`` matcher (bitwise: same
    kernel arithmetic) and to ``matcher_reference`` (same decisions).  Like
    ``_step`` it consumes pre-sorted rows from the batched sort stage."""
    from repro.kernels.encode_step import (DEC_COUNT, DEC_HIT, DEC_OVER,
                                           DEC_SLOT, encode_step_pallas)
    from repro.kernels.ops import _INTERPRET

    block, xs, valid = blk
    if params.error_bound is None:
        new_sorted, ndmin, ndmax, nvalid, dec = encode_step_pallas(
            xs, state.sorted_blocks, state.dmin, state.dmax, state.valid,
            state.count, valid, d_crit=params.d_crit, rel_tol=params.rel_tol,
            use_minmax=params.use_minmax, use_ks=params.use_ks,
            num_dict=num_dict, tile_d=tile_d, interpret=_INTERPRET)
        new_raw = state.raw_blocks
    else:
        new_sorted, ndmin, ndmax, nvalid, new_raw, dec = encode_step_pallas(
            xs, state.sorted_blocks, state.dmin, state.dmax, state.valid,
            state.count, valid, d_crit=params.d_crit, rel_tol=params.rel_tol,
            use_minmax=params.use_minmax, use_ks=params.use_ks,
            num_dict=num_dict, tile_d=tile_d, interpret=_INTERPRET,
            raw=block, raw_blocks=state.raw_blocks,
            error_bound=params.error_bound,
            error_cumulative=params.error_cumulative)
    new_state = DictState(new_sorted, ndmin, ndmax, nvalid, dec[DEC_COUNT],
                          new_raw)
    return new_state, (dec[DEC_HIT].astype(bool), dec[DEC_SLOT],
                       dec[DEC_OVER].astype(bool))


@functools.lru_cache(maxsize=None)
def _encode_scan():
    """Build the jitted scan lazily so importing this module never touches
    the accelerator runtime (decode-only / numpy-backend processes).

    Buffer donation of the resumable carry is a device-memory optimization;
    the CPU backend does not implement it and warns, so gate on backend.
    """
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

    @functools.partial(
        jax.jit,
        static_argnames=("d_crit", "rel_tol", "use_minmax", "use_ks",
                         "matcher", "error_bound", "error_cumulative"),
        donate_argnums=donate,
    )
    def scan(state: DictState, blocks, valid, *, d_crit, rel_tol, use_minmax,
             use_ks, matcher, error_bound=None, error_cumulative=False):
        params = EncoderParams(
            d_crit=d_crit, rel_tol=rel_tol, use_minmax=use_minmax,
            use_ks=use_ks, error_bound=error_bound,
            error_cumulative=error_cumulative,
        )
        xs_all = jnp.sort(blocks, axis=-1)  # hoisted out of the scan step
        if _is_fused(matcher):
            tile_d = matcher[1]
            num_dict = state.sorted_blocks.shape[0]
            pstate = _pad_state_d(state, (-num_dict) % tile_d)
            step = functools.partial(_step_fused, tile_d, params, num_dict)
            new_state, (is_hit, slot, overwrite) = jax.lax.scan(
                step, pstate, (blocks, xs_all, valid))
            new_state = _slice_state_d(new_state, num_dict)
        else:
            step = functools.partial(_step, matcher, params)
            new_state, (is_hit, slot, overwrite) = jax.lax.scan(
                step, state, (blocks, xs_all, valid))
        return (is_hit, slot, overwrite), new_state

    return scan


# ------------------------------------------- measured matcher autotuning
#
# ``matcher="auto"`` mirrors decode's ``backend="auto"`` (DESIGN.md Sec. 9):
# first use of a (D, n, dtype) combination times the reference, ops and
# fused paths (sweeping the fused kernel's tile_d) on a probe scan, routes
# the combination to the fastest, and persists the choice in the same
# versioned cache scheme under ``REPRO_ENCODE_AUTOTUNE``.

MATCHERS = ("reference", "ops", "fused")
ENCODE_AUTOTUNE_VERSION = 1
_FUSED_TILE_SWEEP = (8, 32, 128)
_PROBE_BLOCKS = 8

_TUNER = MeasuredTuner(
    version=ENCODE_AUTOTUNE_VERSION, env_var="REPRO_ENCODE_AUTOTUNE",
    validate_entry=lambda ent: ent.get("matcher") in MATCHERS,
    log=logger, name="encode")


def _matcher_key(num_dict: int, n: int, dtype) -> str:
    import numpy as np

    return f"D={int(num_dict)}|n={int(n)}|dtype={np.dtype(dtype).str}"


def load_encode_autotune(path: str, strict: bool = True) -> int:
    """Load persisted matcher choices (see ``core.tuning``); entry count."""
    return _TUNER.load(path, strict=strict)


def save_encode_autotune(path: str) -> None:
    """Persist the in-memory matcher choices (atomic replace)."""
    _TUNER.save(path)


def reset_encode_autotune() -> None:
    """Forget every matcher choice; next ``"auto"`` re-probes.  Test hook."""
    _TUNER.reset()


def encode_autotune_choices() -> dict:
    """Current ``matcher="auto"`` routing table: key -> matcher name."""
    return _TUNER.choices("matcher")


def encode_autotune_cached(num_dict: int, n: int, dtype) -> bool:
    """Whether ``matcher="auto"`` for (D, n, dtype) resolves from cache."""
    return _TUNER.cached(_matcher_key(num_dict, n, dtype))


def _named_matcher(name: str, tile_d: Optional[int] = None):
    if name == "reference":
        return matcher_reference
    if name == "ops":
        from repro.kernels.ops import dict_match

        return dict_match
    if name == "fused":
        if tile_d is None:
            from repro.kernels.dict_match import TILE_D

            tile_d = TILE_D
        return ("fused", int(tile_d))
    raise ValueError(f"unknown matcher name {name!r}; "
                     f"expected one of {MATCHERS + ('auto',)}")


def _probe_matcher(num_dict: int, n: int, dtype) -> dict:
    """Time each matcher on a short probe scan at the real (D, n, dtype)
    operating point.  A candidate that fails to run (e.g. a tile size too
    large for device memory) is excluded, not fatal."""
    import numpy as np

    rng = np.random.default_rng(0)
    # mixture source: the dictionary fills, then hits and misses both occur,
    # so the fused kernel's gate-skip sees representative traffic
    blocks = jnp.asarray(np.concatenate([
        rng.normal(m, s, size=(_PROBE_BLOCKS // 2, n))
        for m, s in [(0.0, 1.0), (5.0, 0.5)]]), dtype)
    kw = dict(num_dict=num_dict, d_crit=0.35, rel_tol=0.5)

    def run(m):
        jax.block_until_ready(encode_decisions(blocks, matcher=m, **kw))

    times = {"reference": best_of(lambda: run(matcher_reference))}
    candidates = [("ops", _named_matcher("ops"))]
    candidates += [(f"fused/{td}", ("fused", td)) for td in _FUSED_TILE_SWEEP]
    for label, m in candidates:
        try:
            times[label] = best_of(lambda m=m: run(m))
        except Exception as e:
            logger.warning("matcher probe %r failed (%s); excluding it",
                           label, e)
    winner = min(sorted(times), key=times.get)
    if winner.startswith("fused/"):
        name, tile_d = "fused", int(winner.split("/")[1])
    else:
        name, tile_d = winner, None
    return {"matcher": name, "tile_d": tile_d,
            "times_us": {k: round(v * 1e6, 3) for k, v in times.items()}}


def resolve_matcher(matcher, *, num_dict: int, n: int, dtype):
    """Concrete matcher for an encode call.

    ``None`` -> the jnp oracle; callables and already-resolved fused tuples
    pass through (so vmapped/sharded inner calls re-resolve as no-ops);
    names pick the implementation; ``"auto"`` serves the measured choice
    for (D, n, dtype), probing (and persisting) on first use.  Resolve
    *before* entering jit/vmap tracing -- a timing probe under a tracer
    would measure tracing, not execution.
    """
    if matcher is None:
        return matcher_reference
    if callable(matcher) or _is_fused(matcher):
        return matcher
    if matcher in MATCHERS:
        return _named_matcher(matcher)
    if matcher == "auto":
        key = _matcher_key(num_dict, n, dtype)
        with _TUNER.lock:
            hit = _TUNER.cached(key)
            ent = _TUNER.resolve(
                key, lambda: _probe_matcher(int(num_dict), int(n), dtype))
            if not hit:
                logger.info("encode autotune: %s -> %s %s", key,
                            ent["matcher"], ent["times_us"])
        return _named_matcher(ent["matcher"], ent.get("tile_d"))
    raise ValueError(f"unknown matcher {matcher!r}; expected a callable "
                     f"or one of {MATCHERS + ('auto',)}")


def encode_decisions(
    blocks: jax.Array,
    *,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative: bool = False,
    matcher: Optional[Union[Callable, str, Tuple]] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Encode a (nb, n) stack of (already transformed) blocks.

    One-shot (``state=None``): returns ``(is_hit (nb,), slot (nb,),
    overwrite (nb,))`` from a fresh dictionary, as before.

    Resumable (``state=...``): continues the scan from the given carry and
    returns ``((is_hit, slot, overwrite), new_state)``.  Chunked calls that
    thread the state are decision-identical to one scan over the
    concatenated blocks.  The passed-in state is donated on accelerators --
    treat it as consumed.

    ``valid`` is an optional (nb,) padding mask: False steps leave the
    carry untouched and emit an all-zero decision, so ragged batches padded
    to a common block count stay decision-identical to unpadded scans.

    ``matcher(xs_sorted, dict_sorted, dmin, dmax, rel_tol) -> (ks, mm)``
    defaults to the pure-jnp oracle; pass ``repro.kernels.ops.dict_match``
    for the Pallas kernel (its fused min/max gate is used directly), or a
    name -- ``"reference"``/``"ops"``/``"fused"``/``"auto"`` -- resolved by
    :func:`resolve_matcher`.
    """
    matcher = resolve_matcher(matcher, num_dict=num_dict,
                              n=blocks.shape[-1], dtype=blocks.dtype)
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks.shape[-1], dtype=blocks.dtype,
                           raw=error_bound is not None)
    if error_bound is not None and state.raw_blocks.shape[-2] == 0:
        raise ValueError("error_bound requires a state created with "
                         "init_state(..., raw=True)")
    out, new_state = _encode_scan()(
        state, blocks,
        jnp.ones(blocks.shape[0], dtype=bool) if valid is None else valid,
        d_crit=float(d_crit), rel_tol=float(rel_tol),
        use_minmax=use_minmax, use_ks=use_ks, matcher=matcher,
        error_bound=None if error_bound is None else float(error_bound),
        error_cumulative=bool(error_cumulative),
    )
    return (out, new_state) if return_state else out


def encode_decisions_batched(
    blocks_cn: jax.Array,
    *,
    num_dict: int,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
    **kw,
):
    """Multi-channel encoder: blocks (C, nb, n) with per-channel DictState.

    One vmapped scan encodes all channels in lockstep.  One-shot
    (``state=None``) returns the (C, nb) decision triple; resumable
    (``state=init_state(..., channels=C)`` or a previous return) returns
    ``((is_hit, slot, overwrite), new_state)`` with the carry stacked on
    the leading channel axis.  ``valid`` (C, nb) masks padded blocks of
    ragged channels (coalesced serving batches).
    """
    # resolve names here, outside the vmap trace (a cold "auto" probe must
    # run eagerly); the inner per-channel resolution is then a no-op
    kw["matcher"] = resolve_matcher(
        kw.get("matcher"), num_dict=num_dict, n=blocks_cn.shape[-1],
        dtype=blocks_cn.dtype)
    return_state = state is not None
    if state is None:
        state = init_state(
            num_dict, blocks_cn.shape[-1], dtype=blocks_cn.dtype,
            channels=blocks_cn.shape[0],
            raw=kw.get("error_bound") is not None,
        )
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)

    def one(s, b, v):
        return encode_decisions(b, num_dict=num_dict, state=s, valid=v, **kw)

    out, new_state = jax.vmap(one)(state, blocks_cn, valid)
    return (out, new_state) if return_state else out


# ------------------------------------------- masked mixed-mode (adaptive)
#
# Adaptive sessions diverge per channel: payload width (std vs
# residual/delta transforms), KS threshold (selector-scaled d_crit) and
# error metric (plain vs cumulative) all become channel-local.  Instead of
# one dispatch per channel, the mixed scan pads payloads to the cohort max
# width with +inf, masks tail columns per channel, and turns the formerly
# static kwargs into ChanParams carried through the vmap -- one dispatch
# and one host sync per feed, bitwise identical to the per-channel loop
# (DESIGN.md Sec. 13).

def _step_mixed(params: EncoderParams, chan: ChanParams, state: DictState,
                blk):
    """Masked variant of ``_step``: every width-dependent quantity uses the
    channel's logical width ``chan.n`` with the +inf tail columns masked
    out, and the KS threshold / error metric come from ``chan`` instead of
    the static params.  Bitwise-identical decisions and carry to ``_step``
    on the unpadded width."""
    block, xs, valid = blk
    num_dict = state.sorted_blocks.shape[0]
    n_max = xs.shape[0]
    col_ok = jnp.arange(n_max) < chan.n
    xmin = xs[0]
    # == xs[chan.n - 1] on sorted data; avoids a traced-index gather
    xmax = jnp.max(jnp.where(col_ok, xs, -jnp.inf))

    ks = ks_statistic_many_masked(xs, state.sorted_blocks, chan.nf, col_ok)
    mm = _minmax_gate(xmin, xmax, state.dmin, state.dmax, params.rel_tol)
    ones = jnp.ones((num_dict,), dtype=bool)
    mm_ok = mm if params.use_minmax else ones
    ks_ok = (ks <= chan.d_crit) if params.use_ks else ones

    ok = state.valid & mm_ok & ks_ok
    if params.error_bound is not None:
        diff = block[None, :] - state.raw_blocks
        diff = jnp.where(chan.err_cum, jnp.cumsum(diff, axis=-1), diff)
        # pad columns hold inf - inf = NaN: mask them before the max
        err = jnp.max(jnp.where(col_ok[None, :], jnp.abs(diff), 0.0),
                      axis=-1)
        ok = ok & ((~chan.eb_on) | (err <= params.error_bound))
    is_hit = jnp.any(ok) & valid
    first_hit = jnp.argmax(ok)

    ins_slot = jnp.mod(state.count, num_dict)
    do_ins = (~is_hit) & valid
    overwrite = do_ins & (state.count >= num_dict)
    slot = jnp.where(is_hit, first_hit, ins_slot).astype(jnp.int32)
    slot = jnp.where(valid, slot, 0)

    new_sorted = jax.lax.dynamic_update_slice(
        state.sorted_blocks, xs[None, :], (ins_slot, 0)
    )
    upd = jnp.arange(num_dict) == ins_slot
    raw_blocks = state.raw_blocks
    if params.error_bound is not None:
        new_raw = jax.lax.dynamic_update_slice(
            raw_blocks, block[None, :], (ins_slot, 0))
        raw_blocks = jnp.where(do_ins, new_raw, raw_blocks)
    new_state = DictState(
        sorted_blocks=jnp.where(do_ins, new_sorted, state.sorted_blocks),
        dmin=jnp.where(do_ins & upd, xmin, state.dmin),
        dmax=jnp.where(do_ins & upd, xmax, state.dmax),
        valid=jnp.where(do_ins & upd, True, state.valid),
        count=state.count + do_ins.astype(jnp.int32),
        raw_blocks=raw_blocks,
    )
    return new_state, (is_hit, slot, overwrite)


def _chan_block(chan: ChanParams) -> jax.Array:
    """The fused kernel's (8,) f32 channel-parameter operand (layout
    mirrored by ``kernels.encode_step.CHAN_*``; rows 5..7 are padding)."""
    z = jnp.zeros((), jnp.float32)
    return jnp.stack([chan.nf, chan.inv_n, chan.d_crit,
                      chan.err_cum.astype(jnp.float32),
                      chan.eb_on.astype(jnp.float32), z, z, z])


def _step_mixed_fused(tile_d: int, params: EncoderParams, num_dict: int,
                      chan_arr: jax.Array, state: DictState, blk):
    """Fused-kernel mixed scan step: the per-channel parameters travel as
    the kernel's ``chan`` operand, so one pallas dispatch per block still
    covers the whole heterogeneous step."""
    from repro.kernels.encode_step import (DEC_COUNT, DEC_HIT, DEC_OVER,
                                           DEC_SLOT, encode_step_pallas)
    from repro.kernels.ops import _INTERPRET

    block, xs, valid = blk
    kw = dict(d_crit=0.0, rel_tol=params.rel_tol,  # d_crit from chan
              use_minmax=params.use_minmax, use_ks=params.use_ks,
              num_dict=num_dict, tile_d=tile_d, interpret=_INTERPRET,
              chan=chan_arr)
    if params.error_bound is None:
        new_sorted, ndmin, ndmax, nvalid, dec = encode_step_pallas(
            xs, state.sorted_blocks, state.dmin, state.dmax, state.valid,
            state.count, valid, **kw)
        new_raw = state.raw_blocks
    else:
        new_sorted, ndmin, ndmax, nvalid, new_raw, dec = encode_step_pallas(
            xs, state.sorted_blocks, state.dmin, state.dmax, state.valid,
            state.count, valid, raw=block, raw_blocks=state.raw_blocks,
            error_bound=params.error_bound, **kw)
    new_state = DictState(new_sorted, ndmin, ndmax, nvalid, dec[DEC_COUNT],
                          new_raw)
    return new_state, (dec[DEC_HIT].astype(bool), dec[DEC_SLOT],
                       dec[DEC_OVER].astype(bool))


def _mixed_one(matcher, params: EncoderParams, num_dict: int):
    """Per-channel scan body shared by the vmapped and shard_map'd mixed
    encoders.  ``matcher`` is ``"reference"`` or a fused tuple (the only
    matchers with masked variants)."""

    def one(s, b, xsb, v, cp):
        if _is_fused(matcher):
            ps = _pad_state_d(s, (-num_dict) % matcher[1])
            step = functools.partial(_step_mixed_fused, matcher[1], params,
                                     num_dict, _chan_block(cp))
            new_s, out = jax.lax.scan(step, ps, (b, xsb, v))
            return out, _slice_state_d(new_s, num_dict)
        step = functools.partial(_step_mixed, params, cp)
        new_s, out = jax.lax.scan(step, s, (b, xsb, v))
        return out, new_s

    return one


@functools.lru_cache(maxsize=None)
def _mixed_scan():
    """Jitted mixed-mode scan, built lazily like ``_encode_scan``."""
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

    @functools.partial(
        jax.jit,
        static_argnames=("rel_tol", "use_minmax", "use_ks", "matcher",
                         "error_bound"),
        donate_argnums=donate,
    )
    def scan(state, blocks, valid, chan, *, rel_tol, use_minmax, use_ks,
             matcher, error_bound=None):
        params = EncoderParams(d_crit=0.0, rel_tol=rel_tol,
                               use_minmax=use_minmax, use_ks=use_ks,
                               error_bound=error_bound)
        num_dict = state.sorted_blocks.shape[-2]
        xs_all = jnp.sort(blocks, axis=-1)  # +inf pads sort to the tail
        one = _mixed_one(matcher, params, num_dict)
        out, new_state = jax.vmap(one)(state, blocks, xs_all, valid, chan)
        return out, new_state

    return scan


def _resolve_mixed_matcher(matcher):
    """Only the reference and fused matchers have masked (width-aware)
    variants; ``"ops"``/``"auto"``/callables must use the per-channel
    loop instead (the session falls back automatically)."""
    if matcher is None or matcher == "reference" \
            or matcher is matcher_reference:
        return "reference"
    if matcher == "fused":
        matcher = _named_matcher("fused")
    if _is_fused(matcher):
        return matcher
    raise ValueError(
        f"the mixed-mode scan has masked variants of the reference and "
        f"fused matchers only; got {matcher!r}")


def _chan_params_host(n_valid, d_crit, err_cum, eb_on) -> ChanParams:
    """Host-side ChanParams construction: ``inv_n`` is rounded f64 -> f32
    exactly like the static fused kernel's closed-over python float, so
    the chan-parameterized kernel is bitwise identical to the static one."""
    import numpy as np

    n = np.maximum(np.asarray(n_valid, np.int64), 1)  # inactive-lane guard
    return ChanParams(
        n=jnp.asarray(n, jnp.int32),
        nf=jnp.asarray(n, jnp.float32),
        inv_n=jnp.asarray(1.0 / n.astype(np.float64), jnp.float32),
        d_crit=jnp.asarray(np.asarray(d_crit), jnp.float32),
        err_cum=jnp.asarray(np.asarray(err_cum), bool),
        eb_on=jnp.asarray(np.asarray(eb_on), bool),
    )


def encode_decisions_mixed(
    blocks_cn: jax.Array,
    *,
    num_dict: int,
    n_valid,
    d_crit,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative=None,
    eb_on=None,
    matcher: Optional[Union[Callable, str, Tuple]] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Batched mixed-mode encoder for adaptive heterogeneous channels.

    ``blocks_cn`` (C, nb, n_max): per-channel payloads padded on the
    trailing width axis with ``+inf`` to the cohort max and on the block
    axis via ``valid`` (C, nb).  ``n_valid`` (C,) gives each channel's
    logical payload width, ``d_crit`` (C,) its (selector-scaled) KS
    threshold, ``error_cumulative`` (C,) bools pick the cumsum error
    metric per channel (delta mode) under the shared static
    ``error_bound``, and ``eb_on`` (C,) disarms the bound per channel.

    Decisions and the per-lane carry are bitwise identical to C separate
    ``encode_decisions`` calls on the unpadded payloads, in **one**
    dispatch (DESIGN.md Sec. 13).  Resumable exactly like
    ``encode_decisions_batched``; the carry's width axis follows the
    cohort max -- repad with :func:`repad_state_n` when it changes.
    """
    import numpy as np

    C = blocks_cn.shape[0]
    matcher = _resolve_mixed_matcher(matcher)
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks_cn.shape[-1],
                           dtype=blocks_cn.dtype, channels=C,
                           raw=error_bound is not None)
    if error_bound is not None and state.raw_blocks.shape[-2] == 0:
        raise ValueError("error_bound requires a state created with "
                         "init_state(..., raw=True)")
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)
    chan = _chan_params_host(
        n_valid, d_crit,
        np.zeros(C, bool) if error_cumulative is None else error_cumulative,
        np.ones(C, bool) if eb_on is None else eb_on)
    out, new_state = _mixed_scan()(
        state, blocks_cn, valid, chan, rel_tol=float(rel_tol),
        use_minmax=use_minmax, use_ks=use_ks, matcher=matcher,
        error_bound=None if error_bound is None else float(error_bound),
    )
    return (out, new_state) if return_state else out


@functools.lru_cache(maxsize=None)
def _mixed_sharded_scan(mesh, axis_name: str):
    """shard_map'd mixed scan: channel axis split over the mesh like
    ``_sharded_scan``, with the ChanParams arrays sharded alongside."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    st_spec = state_partition_spec(axis_name)
    blk_spec = P(axis_name, None, None)
    msk_spec = P(axis_name, None)
    chan_spec = ChanParams(*([P(axis_name)] * len(ChanParams._fields)))
    out_spec = (P(axis_name, None),) * 3

    @functools.partial(
        jax.jit,
        static_argnames=("rel_tol", "use_minmax", "use_ks", "matcher",
                         "error_bound"),
        donate_argnums=donate,
    )
    def scan(state, blocks, valid, chan, *, rel_tol, use_minmax, use_ks,
             matcher, error_bound=None):
        params = EncoderParams(d_crit=0.0, rel_tol=rel_tol,
                               use_minmax=use_minmax, use_ks=use_ks,
                               error_bound=error_bound)
        num_dict = state.sorted_blocks.shape[-2]
        one = _mixed_one(matcher, params, num_dict)

        def shard(s, b, v, cp):
            x = jnp.sort(b, axis=-1)
            return jax.vmap(one)(s, b, x, v, cp)

        return shard_map(
            shard, mesh=mesh,
            in_specs=(st_spec, blk_spec, msk_spec, chan_spec),
            out_specs=(out_spec, st_spec),
            check_rep=False,
        )(state, blocks, valid, chan)

    return scan


def encode_decisions_mixed_sharded(
    blocks_cn: jax.Array,
    *,
    mesh,
    axis_name: str,
    num_dict: int,
    n_valid,
    d_crit,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative=None,
    eb_on=None,
    matcher: Optional[Union[Callable, str, Tuple]] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Channel-sharded :func:`encode_decisions_mixed`: the cohort's channel
    axis (and its ChanParams arrays) split over the 1-D ``mesh`` exactly
    like ``encode_decisions_sharded``.  C must be a mesh-axis multiple (an
    ``EncodePlan`` computes the padding; inactive pad lanes carry
    ``valid=False`` rows and a clamped width)."""
    import numpy as np

    matcher = _resolve_mixed_matcher(matcher)
    C = blocks_cn.shape[0]
    if C % mesh.shape[axis_name] != 0:
        raise ValueError(
            f"channels={C} not divisible by mesh axis "
            f"{axis_name}={mesh.shape[axis_name]}; pad via EncodePlan")
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks_cn.shape[-1],
                           dtype=blocks_cn.dtype, channels=C,
                           raw=error_bound is not None)
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)
    chan = _chan_params_host(
        n_valid, d_crit,
        np.zeros(C, bool) if error_cumulative is None else error_cumulative,
        np.ones(C, bool) if eb_on is None else eb_on)
    out, new_state = _mixed_sharded_scan(mesh, axis_name)(
        state, blocks_cn, valid, chan, rel_tol=float(rel_tol),
        use_minmax=use_minmax, use_ks=use_ks, matcher=matcher,
        error_bound=None if error_bound is None else float(error_bound),
    )
    return (out, new_state) if return_state else out


# ------------------------------------------------------- sharded scale-out
def state_partition_spec(axis_name: str):
    """``DictState``-shaped PartitionSpec pytree: every carry field split
    on its leading channel axis.  The single place that knows the field
    layout -- ``shard_map`` in_specs and the launch-layer device placement
    (``EncodePlan.state_sharding``) both derive from it."""
    from jax.sharding import PartitionSpec as P

    return DictState(
        sorted_blocks=P(axis_name, None, None),
        dmin=P(axis_name, None),
        dmax=P(axis_name, None),
        valid=P(axis_name, None),
        count=P(axis_name),
        raw_blocks=P(axis_name, None, None),
    )


@functools.lru_cache(maxsize=None)
def _sharded_scan(mesh, axis_name: str):
    """shard_map'd version of the batched scan: the channel axis is split
    across ``mesh``'s devices; each shard runs the same vmapped scan (and
    therefore the same matcher -- the pallas kernel dispatches per shard),
    so outputs are bit-identical to the single-device batched encode.

    The per-channel carry lives sharded on its device between calls and is
    donated like the single-device path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    st_spec = state_partition_spec(axis_name)
    blk_spec = P(axis_name, None, None)
    msk_spec = P(axis_name, None)
    out_spec = (P(axis_name, None),) * 3

    @functools.partial(
        jax.jit,
        static_argnames=("d_crit", "rel_tol", "use_minmax", "use_ks",
                         "matcher", "error_bound", "error_cumulative"),
        donate_argnums=donate,
    )
    def scan(state, blocks, valid, *, d_crit, rel_tol, use_minmax, use_ks,
             matcher, error_bound=None, error_cumulative=False):
        params = EncoderParams(d_crit=d_crit, rel_tol=rel_tol,
                               use_minmax=use_minmax, use_ks=use_ks,
                               error_bound=error_bound,
                               error_cumulative=error_cumulative)
        num_dict = state.sorted_blocks.shape[-2]
        if _is_fused(matcher):
            tile_d = matcher[1]
            step = functools.partial(_step_fused, tile_d, params, num_dict)
        else:
            step = functools.partial(_step, matcher, params)

        def shard(s, b, v):
            x = jnp.sort(b, axis=-1)  # hoisted out of the scan step

            def one(s1, b1, x1, v1):
                if _is_fused(matcher):
                    s1 = _pad_state_d(s1, (-num_dict) % matcher[1])
                new_s, out = jax.lax.scan(step, s1, (b1, x1, v1))
                return out, _slice_state_d(new_s, num_dict)

            return jax.vmap(one)(s, b, x, v)

        # check_rep=False: the pallas matcher has no replication rule; all
        # operands map over the channel axis anyway (no replicated outputs).
        return shard_map(
            shard, mesh=mesh,
            in_specs=(st_spec, blk_spec, msk_spec),
            out_specs=(out_spec, st_spec),
            check_rep=False,
        )(state, blocks, valid)

    return scan


def encode_decisions_sharded(
    blocks_cn: jax.Array,
    *,
    mesh,
    axis_name: str,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative: bool = False,
    matcher: Optional[Union[Callable, str, Tuple]] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Scale-out variant of ``encode_decisions_batched``: the leading
    channel axis of ``blocks_cn`` (C, nb, n) is sharded over the 1-D
    ``mesh`` (see ``repro.launch.encode_plan``) and each device scans its
    channel shard with a device-resident, donated carry.

    C must be a multiple of the mesh axis size -- pad channels up and mask
    them out via ``valid`` (an ``EncodePlan`` computes the padding).
    Decisions (and therefore stream bytes) are bit-identical to the
    single-device batched encode of the same channels.
    """
    matcher = resolve_matcher(matcher, num_dict=num_dict,
                              n=blocks_cn.shape[-1], dtype=blocks_cn.dtype)
    C = blocks_cn.shape[0]
    if C % mesh.shape[axis_name] != 0:
        raise ValueError(
            f"channels={C} not divisible by mesh axis "
            f"{axis_name}={mesh.shape[axis_name]}; pad via EncodePlan")
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks_cn.shape[-1],
                           dtype=blocks_cn.dtype, channels=C,
                           raw=error_bound is not None)
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)
    out, new_state = _sharded_scan(mesh, axis_name)(
        state, blocks_cn, valid, d_crit=float(d_crit),
        rel_tol=float(rel_tol), use_minmax=use_minmax, use_ks=use_ks,
        matcher=matcher,
        error_bound=None if error_bound is None else float(error_bound),
        error_cumulative=bool(error_cumulative),
    )
    return (out, new_state) if return_state else out


# ------------------------------------------------- D-axis (dictionary) sharding
def _step_dshard(matcher, params: EncoderParams, num_dict: int,
                 dict_axis: str, state: DictState, block_valid):
    """One scan step over a *dictionary shard*: this device holds a
    contiguous slice of the (padded) dictionary rows, matches the candidate
    against them, and the lowest passing *global* index is all-reduced over
    the ``dict_axis`` mesh axis with ``pmin`` -- the reduction is exactly
    ``argmax(ok)`` of the unsharded scan, so decisions are identical.

    The FIFO insert slot ``count % num_dict`` is a global index; only the
    shard that owns it writes (the others pass their carry through).
    ``count`` is replicated across dictionary shards and advances in
    lockstep."""
    block, xs, valid = block_valid
    shard_d = state.sorted_blocks.shape[0]
    off = jax.lax.axis_index(dict_axis).astype(jnp.int32) * shard_d
    xmin, xmax = xs[0], xs[-1]

    ks, mm = matcher(xs, state.sorted_blocks, state.dmin, state.dmax,
                     params.rel_tol)
    ones = jnp.ones((shard_d,), dtype=bool)
    mm_ok = mm if params.use_minmax else ones
    ks_ok = (ks <= params.d_crit) if params.use_ks else ones
    ok = state.valid & mm_ok & ks_ok
    if params.error_bound is not None:
        ok = ok & _error_gate(block, state.raw_blocks, params)

    ids = off + jnp.arange(shard_d, dtype=jnp.int32)
    local_first = jnp.min(jnp.where(ok, ids, _SENTINEL))
    best = jax.lax.pmin(local_first, dict_axis)
    is_hit = (best < _SENTINEL) & valid

    ins = jnp.mod(state.count, num_dict)  # global FIFO slot (logical D)
    do_ins = (~is_hit) & valid
    overwrite = do_ins & (state.count >= num_dict)
    slot = jnp.where(is_hit, best, ins).astype(jnp.int32)
    slot = jnp.where(valid, slot, 0)

    lins = ins - off
    in_shard = (lins >= 0) & (lins < shard_d)
    lclip = jnp.clip(lins, 0, shard_d - 1)
    do_here = do_ins & in_shard
    new_sorted = jax.lax.dynamic_update_slice(
        state.sorted_blocks, xs[None, :], (lclip, 0))
    upd = jnp.arange(shard_d) == lclip
    raw_blocks = state.raw_blocks
    if params.error_bound is not None:
        new_raw = jax.lax.dynamic_update_slice(
            raw_blocks, block[None, :], (lclip, 0))
        raw_blocks = jnp.where(do_here, new_raw, raw_blocks)
    new_state = DictState(
        sorted_blocks=jnp.where(do_here, new_sorted, state.sorted_blocks),
        dmin=jnp.where(do_here & upd, xmin, state.dmin),
        dmax=jnp.where(do_here & upd, xmax, state.dmax),
        valid=jnp.where(do_here & upd, True, state.valid),
        count=state.count + do_ins.astype(jnp.int32),
        raw_blocks=raw_blocks,
    )
    return new_state, (is_hit, slot, overwrite)


def state_dshard_partition_spec(ch_axis: str, dict_axis: str):
    """``DictState``-shaped PartitionSpec pytree for a (channels, dict)
    2-D mesh: channels on the leading axis, dictionary rows on the second;
    ``count`` is replicated across dictionary shards."""
    from jax.sharding import PartitionSpec as P

    return DictState(
        sorted_blocks=P(ch_axis, dict_axis, None),
        dmin=P(ch_axis, dict_axis),
        dmax=P(ch_axis, dict_axis),
        valid=P(ch_axis, dict_axis),
        count=P(ch_axis),
        raw_blocks=P(ch_axis, dict_axis, None),
    )


@functools.lru_cache(maxsize=None)
def _dsharded_scan(mesh, ch_axis: str, dict_axis: str):
    """shard_map'd scan over a 2-D (channels, dict) mesh: channels split as
    in ``_sharded_scan``, and within each channel group the dictionary rows
    of every channel are split over the ``dict_axis`` devices, with the
    per-step best-match arg-min all-reduced across them.  A 1-sized channel
    axis gives pure D-sharding of fat channels."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    st_spec = state_dshard_partition_spec(ch_axis, dict_axis)
    blk_spec = P(ch_axis, None, None)
    msk_spec = P(ch_axis, None)
    # decisions come out identical on every dict shard (post-pmin); declare
    # them replicated over dict_axis (check_rep=False skips verification,
    # as for the channel-sharded scan's pallas matcher)
    out_spec = (P(ch_axis, None),) * 3

    @functools.partial(
        jax.jit,
        static_argnames=("d_crit", "rel_tol", "use_minmax", "use_ks",
                         "matcher", "error_bound", "error_cumulative"),
        donate_argnums=donate,
    )
    def scan(state, blocks, valid, *, d_crit, rel_tol, use_minmax, use_ks,
             matcher, error_bound=None, error_cumulative=False):
        params = EncoderParams(d_crit=d_crit, rel_tol=rel_tol,
                               use_minmax=use_minmax, use_ks=use_ks,
                               error_bound=error_bound,
                               error_cumulative=error_cumulative)
        num_dict = state.sorted_blocks.shape[1]
        shards = mesh.shape[dict_axis]
        pad = (-num_dict) % shards
        raw = state.raw_blocks
        if raw.shape[1]:
            raw = jnp.pad(raw, ((0, 0), (0, pad), (0, 0)))
        pstate = DictState(
            sorted_blocks=jnp.pad(state.sorted_blocks,
                                  ((0, 0), (0, pad), (0, 0))),
            dmin=jnp.pad(state.dmin, ((0, 0), (0, pad))),
            dmax=jnp.pad(state.dmax, ((0, 0), (0, pad))),
            valid=jnp.pad(state.valid, ((0, 0), (0, pad))),
            count=state.count,
            raw_blocks=raw,
        )
        step = functools.partial(_step_dshard, matcher, params, num_dict,
                                 dict_axis)

        def shard(s, b, v):
            x = jnp.sort(b, axis=-1)  # hoisted out of the scan step

            def one(s1, b1, x1, v1):
                new_s, out = jax.lax.scan(step, s1, (b1, x1, v1))
                return out, new_s

            return jax.vmap(one)(s, b, x, v)

        out, new_p = shard_map(
            shard, mesh=mesh,
            in_specs=(st_spec, blk_spec, msk_spec),
            out_specs=(out_spec, st_spec),
            check_rep=False,
        )(pstate, blocks, valid)
        new_state = DictState(
            sorted_blocks=new_p.sorted_blocks[:, :num_dict],
            dmin=new_p.dmin[:, :num_dict],
            dmax=new_p.dmax[:, :num_dict],
            valid=new_p.valid[:, :num_dict],
            count=new_p.count,
            raw_blocks=(new_p.raw_blocks[:, :num_dict]
                        if new_p.raw_blocks.shape[1] else new_p.raw_blocks),
        )
        return out, new_state

    return scan


def encode_decisions_dsharded(
    blocks_cn: jax.Array,
    *,
    mesh,
    ch_axis: str,
    dict_axis: str,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    error_bound: Optional[float] = None,
    error_cumulative: bool = False,
    matcher: Optional[Union[Callable, str, Tuple]] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Dictionary-sharded encoder: blocks (C, nb, n) over a 2-D
    ``mesh`` (ch_axis, dict_axis).  Channels split over ``ch_axis`` exactly
    like :func:`encode_decisions_sharded`; *within* each channel the
    dictionary rows are split over ``dict_axis`` and the per-step best
    match is all-reduced, so one fat channel can use several devices.
    Decisions are bit-identical to the single-device batched encode.

    The fused single-dispatch matcher cannot run here -- its in-kernel FIFO
    overwrite would have to precede the cross-shard arg-min reduction -- so
    ``"fused"``/``"auto"``-fused resolutions fall back to the ``ops``
    pallas matcher.
    """
    matcher = resolve_matcher(matcher, num_dict=num_dict,
                              n=blocks_cn.shape[-1], dtype=blocks_cn.dtype)
    if _is_fused(matcher):
        from repro.kernels.ops import dict_match

        matcher = dict_match
    C = blocks_cn.shape[0]
    if C % mesh.shape[ch_axis] != 0:
        raise ValueError(
            f"channels={C} not divisible by mesh axis "
            f"{ch_axis}={mesh.shape[ch_axis]}; pad via EncodePlan")
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks_cn.shape[-1],
                           dtype=blocks_cn.dtype, channels=C,
                           raw=error_bound is not None)
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)
    out, new_state = _dsharded_scan(mesh, ch_axis, dict_axis)(
        state, blocks_cn, valid, d_crit=float(d_crit),
        rel_tol=float(rel_tol), use_minmax=use_minmax, use_ks=use_ks,
        matcher=matcher,
        error_bound=None if error_bound is None else float(error_bound),
        error_cumulative=bool(error_cumulative),
    )
    return (out, new_state) if return_state else out
