"""The IDEALEM encoder as a jit-compiled ``lax.scan`` (DESIGN.md Sec. 2).

The reference C encoder walks the dictionary and early-exits at the first
KS pass.  On TPU we compute the min/max gate (eq. 3) and the KS distance
against *all* D entries as dense masked work and select the lowest-index
passing entry -- decision-identical to the early-exit scan, but fully
vectorized (VPU) and batchable over channels with ``vmap``.

Streaming (DESIGN.md Sec. 3): ``DictState`` is a first-class resumable
carry.  ``encode_decisions(..., state=s)`` continues a scan where the last
chunk stopped and returns the updated state, so a live stream encoded in
chunks makes exactly the same hit/miss decisions as one monolithic scan.
On accelerators the incoming state buffers are donated to the jitted scan,
so resuming does not hold two copies of the dictionary in device memory.

Per-block outputs are fixed-shape decisions (is_hit, slot, overwrite); the
variable-length byte stream is assembled host-side by ``repro.core.stream``
from these decisions plus the raw blocks.

Matchers fuse the two similarity checks: ``matcher(xs_sorted, dict_sorted,
dmin, dmax, rel_tol) -> (ks (D,), mm (D,))``.  The default is the pure-jnp
oracle below; ``repro.kernels.ops.dict_match`` is the Pallas kernel with
the same signature, whose fused min/max gate is consumed directly instead
of being recomputed outside the kernel.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .ks import ks_statistic_many

__all__ = [
    "DictState",
    "EncoderParams",
    "init_state",
    "matcher_reference",
    "encode_decisions",
    "encode_decisions_batched",
    "encode_decisions_sharded",
]



class DictState(NamedTuple):
    """Resumable carry of the encoder scan: the FIFO dictionary buffer.

    Thread it through chunked calls of ``encode_decisions`` to continue a
    stream.  Batched (multi-channel) states carry one leading ``(C,)`` axis
    on every field (see ``init_state(channels=...)``).
    """

    sorted_blocks: jax.Array  # (D, n) sorted source-distribution samples
    dmin: jax.Array  # (D,)
    dmax: jax.Array  # (D,)
    valid: jax.Array  # (D,) bool
    count: jax.Array  # () int32, number of inserts so far (FIFO position)


class EncoderParams(NamedTuple):
    d_crit: float  # critical KS distance (from alpha via ks.critical_distance)
    rel_tol: float  # relative tolerance r for the min/max check (eq. 3)
    use_minmax: bool  # paper's new gate; False = "KS test only" mode
    use_ks: bool = True  # False = min/max check alone (ablation)


def init_state(num_dict: int, n: int, dtype=jnp.float32,
               channels: Optional[int] = None) -> DictState:
    """Fresh (empty-dictionary) carry; ``channels=C`` stacks C independent
    per-channel states on a leading axis for the batched encoder."""
    lead = () if channels is None else (channels,)
    return DictState(
        sorted_blocks=jnp.zeros(lead + (num_dict, n), dtype=dtype),
        dmin=jnp.zeros(lead + (num_dict,), dtype=dtype),
        dmax=jnp.zeros(lead + (num_dict,), dtype=dtype),
        valid=jnp.zeros(lead + (num_dict,), dtype=bool),
        count=jnp.zeros(lead, dtype=jnp.int32),
    )


def _minmax_gate(xmin, xmax, dmin, dmax, r):
    """Eq. (3): both block extremes inside +-w*r of the stored extremes."""
    w = dmax - dmin
    t = w * r
    return (
        (xmin >= dmin - t)
        & (xmin <= dmin + t)
        & (xmax >= dmax - t)
        & (xmax <= dmax + t)
    )


def matcher_reference(xs_sorted, dict_sorted, dmin, dmax, rel_tol):
    """Default pure-jnp matcher: (ks (D,), mm (D,)) against all entries."""
    ks = ks_statistic_many(xs_sorted, dict_sorted)
    mm = _minmax_gate(xs_sorted[0], xs_sorted[-1], dmin, dmax, rel_tol)
    return ks, mm


def _step(matcher, params: EncoderParams, state: DictState, block_valid):
    """One scan step over ``(block, block_valid)``.

    ``block_valid`` is the ragged-batch padding mask: a False step is a
    no-op -- the carry passes through untouched and the decision triple is
    all-zero -- so channels with fewer real blocks than the padded batch
    (coalesced serving batches, sharded channel padding) stay
    decision-identical to an unpadded scan.
    """
    block, valid = block_valid
    num_dict = state.sorted_blocks.shape[0]
    xs = jnp.sort(block)
    xmin, xmax = xs[0], xs[-1]

    ks, mm = matcher(xs, state.sorted_blocks, state.dmin, state.dmax,
                     params.rel_tol)
    ones = jnp.ones((num_dict,), dtype=bool)
    mm_ok = mm if params.use_minmax else ones
    ks_ok = (ks <= params.d_crit) if params.use_ks else ones

    ok = state.valid & mm_ok & ks_ok
    is_hit = jnp.any(ok) & valid
    first_hit = jnp.argmax(ok)  # lowest passing slot == early-exit result

    # FIFO insert slot on miss: fill 0..D-1, then overwrite oldest.
    ins_slot = jnp.mod(state.count, num_dict)
    do_ins = (~is_hit) & valid
    overwrite = do_ins & (state.count >= num_dict)
    slot = jnp.where(is_hit, first_hit, ins_slot).astype(jnp.int32)
    slot = jnp.where(valid, slot, 0)

    new_sorted = jax.lax.dynamic_update_slice(
        state.sorted_blocks, xs[None, :], (ins_slot, 0)
    )
    upd = jnp.arange(num_dict) == ins_slot
    new_state = DictState(
        sorted_blocks=jnp.where(do_ins, new_sorted, state.sorted_blocks),
        dmin=jnp.where(do_ins & upd, xmin, state.dmin),
        dmax=jnp.where(do_ins & upd, xmax, state.dmax),
        valid=jnp.where(do_ins & upd, True, state.valid),
        count=state.count + do_ins.astype(jnp.int32),
    )
    return new_state, (is_hit, slot, overwrite)


@functools.lru_cache(maxsize=None)
def _encode_scan():
    """Build the jitted scan lazily so importing this module never touches
    the accelerator runtime (decode-only / numpy-backend processes).

    Buffer donation of the resumable carry is a device-memory optimization;
    the CPU backend does not implement it and warns, so gate on backend.
    """
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

    @functools.partial(
        jax.jit,
        static_argnames=("d_crit", "rel_tol", "use_minmax", "use_ks",
                         "matcher"),
        donate_argnums=donate,
    )
    def scan(state: DictState, blocks, valid, *, d_crit, rel_tol, use_minmax,
             use_ks, matcher):
        params = EncoderParams(
            d_crit=d_crit, rel_tol=rel_tol, use_minmax=use_minmax,
            use_ks=use_ks,
        )
        step = functools.partial(_step, matcher, params)
        new_state, (is_hit, slot, overwrite) = jax.lax.scan(step, state,
                                                            (blocks, valid))
        return (is_hit, slot, overwrite), new_state

    return scan


def encode_decisions(
    blocks: jax.Array,
    *,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    matcher: Optional[Callable] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Encode a (nb, n) stack of (already transformed) blocks.

    One-shot (``state=None``): returns ``(is_hit (nb,), slot (nb,),
    overwrite (nb,))`` from a fresh dictionary, as before.

    Resumable (``state=...``): continues the scan from the given carry and
    returns ``((is_hit, slot, overwrite), new_state)``.  Chunked calls that
    thread the state are decision-identical to one scan over the
    concatenated blocks.  The passed-in state is donated on accelerators --
    treat it as consumed.

    ``valid`` is an optional (nb,) padding mask: False steps leave the
    carry untouched and emit an all-zero decision, so ragged batches padded
    to a common block count stay decision-identical to unpadded scans.

    ``matcher(xs_sorted, dict_sorted, dmin, dmax, rel_tol) -> (ks, mm)``
    defaults to the pure-jnp oracle; pass ``repro.kernels.ops.dict_match``
    for the Pallas kernel (its fused min/max gate is used directly).
    """
    if matcher is None:
        matcher = matcher_reference
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks.shape[-1], dtype=blocks.dtype)
    if valid is None:
        valid = jnp.ones(blocks.shape[0], dtype=bool)
    out, new_state = _encode_scan()(
        state, blocks, valid, d_crit=float(d_crit), rel_tol=float(rel_tol),
        use_minmax=use_minmax, use_ks=use_ks, matcher=matcher,
    )
    return (out, new_state) if return_state else out


def encode_decisions_batched(
    blocks_cn: jax.Array,
    *,
    num_dict: int,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
    **kw,
):
    """Multi-channel encoder: blocks (C, nb, n) with per-channel DictState.

    One vmapped scan encodes all channels in lockstep.  One-shot
    (``state=None``) returns the (C, nb) decision triple; resumable
    (``state=init_state(..., channels=C)`` or a previous return) returns
    ``((is_hit, slot, overwrite), new_state)`` with the carry stacked on
    the leading channel axis.  ``valid`` (C, nb) masks padded blocks of
    ragged channels (coalesced serving batches).
    """
    return_state = state is not None
    if state is None:
        state = init_state(
            num_dict, blocks_cn.shape[-1], dtype=blocks_cn.dtype,
            channels=blocks_cn.shape[0],
        )
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)

    def one(s, b, v):
        return encode_decisions(b, num_dict=num_dict, state=s, valid=v, **kw)

    out, new_state = jax.vmap(one)(state, blocks_cn, valid)
    return (out, new_state) if return_state else out


# ------------------------------------------------------- sharded scale-out
def state_partition_spec(axis_name: str):
    """``DictState``-shaped PartitionSpec pytree: every carry field split
    on its leading channel axis.  The single place that knows the field
    layout -- ``shard_map`` in_specs and the launch-layer device placement
    (``EncodePlan.state_sharding``) both derive from it."""
    from jax.sharding import PartitionSpec as P

    return DictState(
        sorted_blocks=P(axis_name, None, None),
        dmin=P(axis_name, None),
        dmax=P(axis_name, None),
        valid=P(axis_name, None),
        count=P(axis_name),
    )


@functools.lru_cache(maxsize=None)
def _sharded_scan(mesh, axis_name: str):
    """shard_map'd version of the batched scan: the channel axis is split
    across ``mesh``'s devices; each shard runs the same vmapped scan (and
    therefore the same matcher -- the pallas kernel dispatches per shard),
    so outputs are bit-identical to the single-device batched encode.

    The per-channel carry lives sharded on its device between calls and is
    donated like the single-device path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    st_spec = state_partition_spec(axis_name)
    blk_spec = P(axis_name, None, None)
    msk_spec = P(axis_name, None)
    out_spec = (P(axis_name, None),) * 3

    @functools.partial(
        jax.jit,
        static_argnames=("d_crit", "rel_tol", "use_minmax", "use_ks",
                         "matcher"),
        donate_argnums=donate,
    )
    def scan(state, blocks, valid, *, d_crit, rel_tol, use_minmax, use_ks,
             matcher):
        params = EncoderParams(d_crit=d_crit, rel_tol=rel_tol,
                               use_minmax=use_minmax, use_ks=use_ks)
        step = functools.partial(_step, matcher, params)

        def shard(s, b, v):
            def one(s1, b1, v1):
                new_s, out = jax.lax.scan(step, s1, (b1, v1))
                return out, new_s

            return jax.vmap(one)(s, b, v)

        # check_rep=False: the pallas matcher has no replication rule; all
        # operands map over the channel axis anyway (no replicated outputs).
        return shard_map(
            shard, mesh=mesh,
            in_specs=(st_spec, blk_spec, msk_spec),
            out_specs=(out_spec, st_spec),
            check_rep=False,
        )(state, blocks, valid)

    return scan


def encode_decisions_sharded(
    blocks_cn: jax.Array,
    *,
    mesh,
    axis_name: str,
    num_dict: int,
    d_crit: float,
    rel_tol: float = 0.1,
    use_minmax: bool = True,
    use_ks: bool = True,
    matcher: Optional[Callable] = None,
    state: Optional[DictState] = None,
    valid: Optional[jax.Array] = None,
):
    """Scale-out variant of ``encode_decisions_batched``: the leading
    channel axis of ``blocks_cn`` (C, nb, n) is sharded over the 1-D
    ``mesh`` (see ``repro.launch.encode_plan``) and each device scans its
    channel shard with a device-resident, donated carry.

    C must be a multiple of the mesh axis size -- pad channels up and mask
    them out via ``valid`` (an ``EncodePlan`` computes the padding).
    Decisions (and therefore stream bytes) are bit-identical to the
    single-device batched encode of the same channels.
    """
    if matcher is None:
        matcher = matcher_reference
    C = blocks_cn.shape[0]
    if C % mesh.shape[axis_name] != 0:
        raise ValueError(
            f"channels={C} not divisible by mesh axis "
            f"{axis_name}={mesh.shape[axis_name]}; pad via EncodePlan")
    return_state = state is not None
    if state is None:
        state = init_state(num_dict, blocks_cn.shape[-1],
                           dtype=blocks_cn.dtype, channels=C)
    if valid is None:
        valid = jnp.ones(blocks_cn.shape[:2], dtype=bool)
    out, new_state = _sharded_scan(mesh, axis_name)(
        state, blocks_cn, valid, d_crit=float(d_crit),
        rel_tol=float(rel_tol), use_minmax=use_minmax, use_ks=use_ks,
        matcher=matcher,
    )
    return (out, new_state) if return_state else out
