"""User-facing IDEALEM codec: orchestrates transform -> decisions -> stream.

>>> codec = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01)
>>> blob = codec.encode(x)            # x: 1-D numpy float array
>>> y = codec.decode(blob)            # same length, statistically similar
>>> codec.compression_ratio(x, blob)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from . import stream as stream_mod
from .ks import critical_distance
from .stream import MODE_DELTA, MODE_RESIDUAL, MODE_STD, StreamHeader
from .transforms import np_wrap_centered

_MODES = {"std": MODE_STD, "residual": MODE_RESIDUAL, "delta": MODE_DELTA}


@dataclass
class IdealemCodec:
    mode: str = "std"
    block_size: int = 32
    num_dict: int = 255
    alpha: float = 0.01
    rel_tol: float = 0.1
    use_minmax: bool = True
    use_ks: bool = True
    max_count: int = 255
    value_range: Optional[Tuple[float, float]] = None
    backend: str = "jax"  # "jax" | "numpy" | "pallas"
    decode_seed: int = 0
    d_crit: float = field(init=False)

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {list(_MODES)}")
        if not (1 <= self.num_dict <= 255):
            raise ValueError("num_dict must be in [1, 255]")
        if not (1 <= self.max_count <= 255):
            raise ValueError("max_count must be in [1, 255]")
        if self.block_size < 2:
            raise ValueError("block_size must be >= 2")
        n = self._lem_n()
        self.d_crit = critical_distance(self.alpha, n, n)

    # ------------------------------------------------------------- internals
    def _lem_n(self) -> int:
        return self.block_size if self.mode == "std" else self.block_size - 1

    def _split(self, x: np.ndarray):
        nb = len(x) // self.block_size
        blocks = x[: nb * self.block_size].reshape(nb, self.block_size)
        tail = x[nb * self.block_size:]
        return blocks, tail

    def _transform(self, blocks: np.ndarray):
        """Returns (payload for LEM+stream, bases or None). Host-side f64."""
        if self.mode == "std":
            return blocks, None
        bases = blocks[:, 0].copy()
        if self.mode == "residual":
            t = blocks[:, 1:] - bases[:, None]
        else:
            t = np.diff(blocks, axis=1)
        if self.value_range is not None:
            t = np_wrap_centered(t, *self.value_range)
        return t, bases

    def _decide(self, payload: np.ndarray):
        kw = dict(
            num_dict=self.num_dict,
            d_crit=float(self.d_crit),
            rel_tol=float(self.rel_tol),
            use_minmax=self.use_minmax,
            use_ks=self.use_ks,
        )
        if self.backend == "numpy":
            from .npref import encode_decisions_np
            return encode_decisions_np(payload, **kw)
        from .encoder import encode_decisions
        import jax.numpy as jnp
        matcher = None
        if self.backend == "pallas":
            from repro.kernels.ops import dict_match_ks
            matcher = dict_match_ks
        out = encode_decisions(jnp.asarray(payload, dtype=jnp.float32),
                               matcher=matcher, **kw)
        return tuple(np.asarray(o) for o in out)

    # ------------------------------------------------------------ public API
    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x)
        if x.ndim != 1:
            raise ValueError("IDEALEM compresses 1-D arrays (vmap for batches)")
        blocks, tail = self._split(x)
        payload, bases = self._transform(blocks)
        if len(blocks):
            is_hit, slot, overwrite = self._decide(payload)
        else:
            is_hit = slot = overwrite = np.zeros((0,), dtype=np.int32)
        header = StreamHeader(
            mode=_MODES[self.mode],
            block_size=self.block_size,
            num_dict=self.num_dict,
            max_count=self.max_count,
            dtype=x.dtype,
            value_range=self.value_range,
            n_blocks=len(blocks),
            tail=tail,
        )
        return stream_mod.assemble_stream(
            header, blocks, payload, bases, is_hit, slot, overwrite
        )

    def decode(self, blob: bytes) -> np.ndarray:
        return stream_mod.decode_stream(blob, seed=self.decode_seed)

    @staticmethod
    def compression_ratio(x: np.ndarray, blob: bytes) -> float:
        return x.nbytes / len(blob)

    def encode_stats(self, x: np.ndarray) -> dict:
        blob = self.encode(x)
        _, events = stream_mod.parse_stream(blob)
        hits = sum(1 for e in events if e["kind"] == "hit")
        return {
            "ratio": self.compression_ratio(x, blob),
            "bytes": len(blob),
            "blocks": len(events),
            "hits": hits,
            "hit_rate": hits / max(len(events), 1),
        }
