"""User-facing IDEALEM codec: orchestrates transform -> decisions -> stream.

One-shot:

>>> codec = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01)
>>> blob = codec.encode(x)            # x: 1-D numpy float array
>>> y = codec.decode(blob)            # same length, statistically similar
>>> codec.compression_ratio(x, blob)

Streaming (chunked / multi-channel): ``encode`` is a thin wrapper over
``IdealemSession`` (repro.core.session), which keeps the FIFO dictionary
alive between chunks:

>>> s = codec.session()               # or codec.session(channels=C)
>>> parts = [s.feed(chunk) for chunk in chunks] + [s.finish()]
>>> y = codec.decode(b"".join(parts))

Backends: "jax" (vmap/scan device encoder), "pallas" (same scan consuming
the fused ``dict_match`` kernel gate+KS), "numpy" (sequential early-exit
reference).  All three are decision-identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import numpy as np

from ..api import CodecConfig
from . import stream as stream_mod
from .ks import critical_distance
from .select import SelectorConfig
from .session import IdealemSession
from .stream import MODE_DELTA, MODE_RESIDUAL, MODE_STD
from .transforms import np_wrap_centered

_MODES = {"std": MODE_STD, "residual": MODE_RESIDUAL, "delta": MODE_DELTA}


@dataclass
class IdealemCodec:
    mode: str = "std"
    block_size: int = 32
    num_dict: int = 255
    alpha: float = 0.01
    rel_tol: float = 0.1
    use_minmax: bool = True
    use_ks: bool = True
    max_count: int = 255
    value_range: Optional[Tuple[float, float]] = None
    backend: str = "jax"  # "jax" | "numpy" | "pallas" (encode scan)
    # encode matcher for device backends: None keeps the backend default
    # (jax -> reference oracle, pallas -> fused kernel); or one of
    # "reference" | "ops" | "fused" | "auto" (measured, see core.tuning)
    matcher: Optional[str] = None
    decode_seed: int = 0
    decode_backend: str = "numpy"  # reconstruction backend (core.decode)
    # error-bounded mode: a would-be hit whose pointwise reconstruction
    # error would exceed the bound is demoted to a miss, and hit decode
    # skips the exchangeability permutation so the bound literally holds on
    # every sample (max|x - x_hat| <= error_bound; circular metric when
    # value_range wraps).  error_bound_rel is the bound as a fraction of the
    # value_range width, resolved to an absolute error_bound here.
    error_bound: Optional[float] = None
    error_bound_rel: Optional[float] = None
    # adaptive per-channel mode selection (core.select): streaming-only --
    # sessions switch transform/threshold at segment restarts
    adaptive: bool = False
    selector: Optional[SelectorConfig] = None
    d_crit: float = field(init=False)

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {list(_MODES)}")
        if self.matcher is not None and self.matcher not in (
                "reference", "ops", "fused", "auto"):
            raise ValueError(
                "matcher must be None or one of "
                "('reference', 'ops', 'fused', 'auto')")
        if not (1 <= self.num_dict <= 255):
            raise ValueError("num_dict must be in [1, 255]")
        if not (1 <= self.max_count <= 255):
            raise ValueError("max_count must be in [1, 255]")
        if self.block_size < 2:
            raise ValueError("block_size must be >= 2")
        if self.error_bound_rel is not None:
            if self.value_range is None:
                raise ValueError("error_bound_rel requires value_range")
            self.error_bound = float(self.error_bound_rel) * (
                self.value_range[1] - self.value_range[0])
        if self.error_bound is not None and not self.error_bound > 0:
            raise ValueError("error_bound must be positive")
        n = self._lem_n()
        self.d_crit = critical_distance(self.alpha, n, n)

    # ------------------------------------------------------------- internals
    @property
    def mode_id(self) -> int:
        return _MODES[self.mode]

    def _lem_n(self) -> int:
        return self.block_size if self.mode == "std" else self.block_size - 1

    def _transform(self, blocks: np.ndarray):
        """Returns (payload for LEM+stream, bases or None). Host-side."""
        if self.mode == "std":
            return blocks, None
        bases = blocks[:, 0].copy()
        if self.mode == "residual":
            t = blocks[:, 1:] - bases[:, None]
        else:
            t = np.diff(blocks, axis=1)
        if self.value_range is not None:
            t = np_wrap_centered(t, *self.value_range)
        return t, bases

    # ------------------------------------------------------------ public API
    @classmethod
    def from_config(cls, config: Union[CodecConfig, dict]) -> "IdealemCodec":
        """Build a codec from one :class:`repro.api.CodecConfig` (or its
        JSON dict form) -- the wire-facing constructor.  Plain keyword
        construction keeps working; this is the same set of knobs behind
        one frozen, serializable type."""
        if isinstance(config, dict):
            config = CodecConfig.from_json(config)
        return cls(**config.kwargs())

    @property
    def config(self) -> CodecConfig:
        """The frozen :class:`repro.api.CodecConfig` describing this codec.

        Round-trip stable: ``IdealemCodec.from_config(codec.config)``
        makes identical decisions and bytes.  ``error_bound_rel`` is
        resolved once at construction, so the config carries the absolute
        ``error_bound``; a custom adaptive ``selector`` is an in-process
        knob and is not captured (``adaptive`` itself is)."""
        return CodecConfig(
            mode=self.mode, block_size=self.block_size,
            num_dict=self.num_dict, alpha=self.alpha, rel_tol=self.rel_tol,
            use_minmax=self.use_minmax, use_ks=self.use_ks,
            max_count=self.max_count, value_range=self.value_range,
            backend=self.backend, matcher=self.matcher,
            decode_seed=self.decode_seed, decode_backend=self.decode_backend,
            error_bound=self.error_bound, adaptive=self.adaptive)

    def session(self, channels: Optional[int] = None,
                emit_segments: bool = True,
                dtype=np.float64, plan=None,
                container: bool = False) -> IdealemSession:
        """Open a resumable streaming session with this configuration.

        ``plan`` (a ``repro.launch.encode_plan.EncodePlan``) shards the
        channel axis of the device scan across the plan's mesh; output
        bytes are identical to the unplanned session.  ``container=True``
        makes ``finish()`` return one indexed random-access container
        (``repro.store``) over all channels instead of the final segment.
        """
        return IdealemSession(self, channels=channels,
                              emit_segments=emit_segments, dtype=dtype,
                              plan=plan, container=container)

    def encode(self, x: np.ndarray) -> bytes:
        """One-shot encode: a single-feed session assembled as one segment."""
        x = np.ascontiguousarray(x)
        if self.adaptive:
            raise ValueError("adaptive codecs are streaming-only; use "
                             "codec.session() and feed chunks")
        if x.ndim != 1:
            raise ValueError(
                "IdealemCodec.encode compresses 1-D arrays; use "
                "codec.session(channels=C) for batched multi-channel streams")
        s = IdealemSession(self, emit_segments=False, dtype=x.dtype)
        s.feed(x)
        return s.finish()

    def decode(self, blob: bytes, backend: Optional[str] = None) -> np.ndarray:
        """Decode a stream; ``backend`` overrides the codec's
        ``decode_backend`` (all backends are byte-identical)."""
        return stream_mod.decode_stream(blob, seed=self.decode_seed,
                                        backend=backend or self.decode_backend)

    @staticmethod
    def compression_ratio(x: np.ndarray, blob: bytes) -> float:
        return x.nbytes / len(blob)

    def encode_stats(self, x: np.ndarray) -> dict:
        blob = self.encode(x)
        _, events = stream_mod.parse_stream(blob)
        hits = sum(1 for e in events if e["kind"] == "hit")
        return {
            "ratio": self.compression_ratio(x, blob),
            "bytes": len(blob),
            "blocks": len(events),
            "hits": hits,
            "hit_rate": hits / max(len(events), 1),
        }
