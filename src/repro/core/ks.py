"""Two-sample Kolmogorov-Smirnov machinery for the LEM similarity measure.

The paper (Sec. III-A) uses the two-sample KS test as the exchangeability
measure: statistic D = sup_x |F1(x) - F2(x)| (eq. 1), standardized by
sqrt(n1*n2/(n1+n2)) (eq. 2), mapped to a p-value with the asymptotic
Kolmogorov distribution.  A block is exchangeable with a stored source
distribution when p >= alpha.

TPU adaptation (DESIGN.md Sec. 2): the p-value is monotone in the statistic,
so the alpha threshold is converted ONCE (host-side) into a critical distance
``critical_distance(alpha, n1, n2)`` and the hot loop compares plain distances.
The p-value path is kept for analysis benchmarks (Fig. 3).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "kolmogorov_sf",
    "ks_pvalue",
    "ks_statistic",
    "ks_statistic_sorted",
    "ks_statistic_many",
    "ks_statistic_many_masked",
    "critical_distance",
]

_SERIES_TERMS = 40

# Below this the alternating series needs more terms than we carry: the
# partial sums of the even-truncated series cancel as lam -> 0 (Q(0) came
# out 0.0 instead of 1.0).  The true survival function satisfies
# 1 - Q(0.1) ~ 4e-53, far below f64 resolution, so returning exactly 1.0
# under the cutoff agrees with scipy.special.kolmogorov to machine
# precision while the series itself is accurate (truncation < 3e-15) above.
_SMALL_LAM = 0.1


def kolmogorov_sf(lam):
    """Survival function of the Kolmogorov distribution.

    Q_KS(lam) = 2 * sum_{j>=1} (-1)^{j-1} exp(-2 j^2 lam^2), clipped to [0,1].
    For lam < 0.1 the truncated series is replaced by its limit 1.0 (the
    scipy small-lam regime, where 1 - Q(lam) underflows f64); above the
    cutoff it matches scipy.special.kolmogorov to ~1e-8 in f32, ~1e-14 in
    f64.
    """
    lam = jnp.asarray(lam)
    j = jnp.arange(1, _SERIES_TERMS + 1, dtype=lam.dtype if jnp.issubdtype(lam.dtype, jnp.floating) else jnp.float32)
    lam_ = jnp.maximum(lam, 1e-12)
    terms = jnp.where(
        (j % 2) == 1, 1.0, -1.0
    ) * jnp.exp(-2.0 * (j ** 2)[..., :] * (lam_[..., None] ** 2))
    q = 2.0 * jnp.sum(terms, axis=-1)
    return jnp.where(lam_ < _SMALL_LAM, 1.0, jnp.clip(q, 0.0, 1.0))


def ks_pvalue(d, n1, n2):
    """Asymptotic two-sided two-sample KS p-value (scipy ``mode='asymp'``).

    Includes the small-lam special case: for sqrt(n1*n2/(n1+n2))*d < 0.1
    (in particular d == 0, identical samples) the p-value is exactly 1.0,
    not the cancelled partial sum the raw series produces.
    """
    d = jnp.asarray(d)
    en = (n1 * n2) / (n1 + n2)
    return kolmogorov_sf(jnp.sqrt(en) * d)


def _ecdf_distance_sorted(xs, ys):
    """sup_x |F_x - F_y| for sorted 1-D samples xs (n1,), ys (n2,).

    Evaluated at every sample point of both samples (ECDFs are right-
    continuous step functions, the sup is attained at a jump point).
    """
    n1 = xs.shape[0]
    n2 = ys.shape[0]
    # F at candidate points
    fx_at_x = (jnp.arange(1, n1 + 1, dtype=jnp.float32)) / n1
    fy_at_x = jnp.searchsorted(ys, xs, side="right").astype(jnp.float32) / n2
    d1 = jnp.max(jnp.abs(fx_at_x - fy_at_x))
    # F at dictionary points
    fy_at_y = (jnp.arange(1, n2 + 1, dtype=jnp.float32)) / n2
    fx_at_y = jnp.searchsorted(xs, ys, side="right").astype(jnp.float32) / n1
    d2 = jnp.max(jnp.abs(fx_at_y - fy_at_y))
    return jnp.maximum(d1, d2)


def ks_statistic_sorted(xs, ys):
    """KS statistic between two already-sorted samples."""
    return _ecdf_distance_sorted(jnp.asarray(xs), jnp.asarray(ys))


def ks_statistic(x, y):
    """KS statistic between two unsorted samples."""
    return _ecdf_distance_sorted(jnp.sort(jnp.asarray(x)), jnp.sort(jnp.asarray(y)))


def ks_statistic_many(xs_sorted, dict_sorted):
    """KS statistic of one sorted candidate vs a stack of sorted blocks.

    xs_sorted: (n,); dict_sorted: (D, n).  Returns (D,) float32.
    This is the pure-jnp oracle for the Pallas ``dict_match`` kernel.
    """
    return jax.vmap(lambda ys: _ecdf_distance_sorted(xs_sorted, ys))(dict_sorted)


def _ecdf_distance_sorted_masked(xs, ys, nf, col_ok):
    """``_ecdf_distance_sorted`` for width-padded sorted samples.

    Both samples share the logical length ``nf`` (float32 scalar, traced)
    and are padded on the tail with ``+inf`` to a common physical width;
    ``col_ok`` masks the real columns.  Because ``+inf`` pads sort last and
    never compare ``<=`` a finite sample, every ``searchsorted`` count at a
    real column equals its unpadded value, and the masked positions are
    zero-filled before the max (KS >= 0), so the result is bitwise
    identical to ``_ecdf_distance_sorted`` on the unpadded samples.
    """
    m = xs.shape[0]
    fx_at_x = (jnp.arange(1, m + 1, dtype=jnp.float32)) / nf
    fy_at_x = jnp.searchsorted(ys, xs, side="right").astype(jnp.float32) / nf
    d1 = jnp.max(jnp.where(col_ok, jnp.abs(fx_at_x - fy_at_x), 0.0))
    fy_at_y = (jnp.arange(1, m + 1, dtype=jnp.float32)) / nf
    fx_at_y = jnp.searchsorted(xs, ys, side="right").astype(jnp.float32) / nf
    d2 = jnp.max(jnp.where(col_ok, jnp.abs(fx_at_y - fy_at_y), 0.0))
    return jnp.maximum(d1, d2)


def ks_statistic_many_masked(xs_sorted, dict_sorted, nf, col_ok):
    """Masked ``ks_statistic_many`` for the mixed-mode (adaptive) encoder:
    candidate and dictionary rows are padded to a common width with +inf,
    ``nf``/``col_ok`` give the channel's logical sample count and real
    columns.  Bitwise identical to ``ks_statistic_many`` on the unpadded
    width (DESIGN.md Sec. 13)."""
    return jax.vmap(
        lambda ys: _ecdf_distance_sorted_masked(xs_sorted, ys, nf, col_ok)
    )(dict_sorted)


def critical_distance(alpha: float, n1: int, n2: int) -> float:
    """Invert the asymptotic p-value: largest D with p(D) >= alpha.

    Host-side scalar (numpy bisection); decision `p >= alpha` is exactly
    `D <= critical_distance(alpha, n1, n2)` up to float tolerance since the
    same series is used in both directions.
    """
    if not (0.0 < alpha < 1.0):
        raise ValueError(f"alpha must be in (0,1), got {alpha}")
    en = (n1 * n2) / (n1 + n2)

    def q(lam: float) -> float:
        if lam < _SMALL_LAM:
            return 1.0
        j = np.arange(1, _SERIES_TERMS + 1)
        val = 2.0 * np.sum((-1.0) ** (j - 1) * np.exp(-2.0 * j * j * lam * lam))
        return float(np.clip(val, 0.0, 1.0))

    lo, hi = 1e-9, 10.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if q(mid) >= alpha:
            lo = mid
        else:
            hi = mid
    return lo / np.sqrt(en)
