"""Shared measured-autotune machinery (DESIGN.md Secs. 9-10).

PR 5 taught the decode engine to *measure* ``backend="auto"``: first use of
a (mode, dtype, size-bucket) combination times every candidate, routes the
combination to the fastest, and persists the choice in a versioned JSON
cache.  The encode side now wants the same contract for ``matcher="auto"``
(reference / ops / fused, keyed on (D, n, dtype)) -- so the cache layer
lives here, shared by both:

  * :class:`MeasuredTuner` -- the thread-safe choice table: lazy load from
    an env-var-named path, versioned-document validation, atomic persist,
    probe/hit counters.  One instance per tuned subsystem (decode backends,
    encode matchers), each with its own env var and entry validator.
  * :func:`best_of` -- the timing primitive every probe uses: one warmup
    call (jit compile, caches) then best-of-N wall clock.
  * :class:`AutotuneCacheError` -- the shared typed failure for corrupt or
    version-stale persisted caches (``repro.core.decode`` re-exports it, so
    existing callers keep working).

The probe itself stays with its subsystem (decode builds probe *plans*,
encode builds probe *scans*); this module only owns remembering, guarding
and persisting what the probes measured.  File format is unchanged from
PR 5: ``{"version": N, "entries": {key: {..., "times_us": {...}}}}`` --
caches written by the pre-refactor decode engine load as-is.
"""
from __future__ import annotations

import json
import logging
import os
import threading
import time
from typing import Callable, Dict, Optional

from .. import obs

__all__ = ["AutotuneCacheError", "MeasuredTuner", "best_of", "pow2_bucket"]

logger = logging.getLogger("repro.core.tuning")


# Historical import path: the class now lives in the unified hierarchy
# (repro.errors) under the ReproError root; same object either way.
from ..errors import AutotuneCacheError  # noqa: E402,F401


def best_of(fn: Callable[[], object], reps: int = 3) -> float:
    """Best-of-``reps`` wall-clock seconds after one warmup call."""
    fn()  # warmup: jit compile, caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def pow2_bucket(n: int, lo: int, hi: int) -> int:
    """Pow-2 size bucket of a workload dimension, clamped to [lo, hi] so
    the probe table stays small (below ``lo`` overhead dominates, above
    ``hi`` bandwidth does)."""
    p = max(1, 1 << (int(max(1, n)) - 1).bit_length())
    return min(max(p, lo), hi)


class MeasuredTuner:
    """Versioned, persistable table of measured "auto" choices.

    ``env_var`` names the environment variable that (optionally) points at
    the JSON cache file; when set, the table is loaded lazily at first
    lookup and rewritten after each recorded probe.  ``validate_entry``
    rejects malformed entries on load (each subsystem knows its own entry
    shape); a stale ``version`` or corrupt file is discarded -- never
    trusted.

    Lookups and records race the pipelined service's worker thread (and
    each other across services), hence the RLock; ``stats`` counts probes
    (cold resolutions the caller measured) vs hits (served from the
    table).  Since ISSUE 8 the counts live on the ``repro.obs`` registry
    (``repro_tuning_{probes,hits}_total`` labelled per tuner ``name``);
    ``stats`` stays a dict-shaped view for existing subscript reads.
    """

    def __init__(self, *, version: int, env_var: str,
                 validate_entry: Callable[[dict], bool],
                 log: Optional[logging.Logger] = None,
                 name: Optional[str] = None):
        self.version = version
        self.env_var = env_var
        self.name = name if name is not None else env_var.lower()
        self._validate_entry = validate_entry
        self._log = log if log is not None else logger
        self._entries: Dict[str, dict] = {}
        self._loaded = False
        self.lock = threading.RLock()
        reg = obs.registry()
        self._probes = reg.counter(
            "repro_tuning_probes_total",
            "cold auto resolutions measured by a timing probe",
            labels={"tuner": self.name})
        self._hits = reg.counter(
            "repro_tuning_hits_total",
            "auto resolutions served from the recorded table",
            labels={"tuner": self.name})

    @property
    def stats(self) -> Dict[str, int]:
        """Compat view: ``{"probes": int, "hits": int}`` (a snapshot --
        mutating the returned dict does not write back)."""
        return {"probes": int(self._probes.value),
                "hits": int(self._hits.value)}

    # ------------------------------------------------------------ persistence
    def _path(self) -> Optional[str]:
        return os.environ.get(self.env_var) or None

    def _validate_doc(self, doc) -> dict:
        if not isinstance(doc, dict):
            raise AutotuneCacheError("autotune cache is not a JSON object")
        if doc.get("version") != self.version:
            raise AutotuneCacheError(
                f"autotune cache version {doc.get('version')!r} != "
                f"{self.version}: stale cache, re-probe")
        entries = doc.get("entries")
        if not isinstance(entries, dict):
            raise AutotuneCacheError("autotune cache has no 'entries' object")
        for key, ent in entries.items():
            if (not isinstance(ent, dict)
                    or not isinstance(ent.get("times_us"), dict)
                    or not self._validate_entry(ent)):
                raise AutotuneCacheError(f"malformed autotune entry {key!r}")
        return entries

    def load(self, path: str, strict: bool = True) -> int:
        """Load persisted choices; returns the entry count.

        ``strict=True`` (the selfcheck contract) raises
        :class:`AutotuneCacheError` on a corrupt or version-stale file;
        ``strict=False`` (the serving path) logs, discards, and leaves the
        table cold so combinations are re-probed."""
        with self.lock:
            self._loaded = True
            try:
                with open(path, "r", encoding="utf-8") as f:
                    doc = json.load(f)
                entries = self._validate_doc(doc)
            except AutotuneCacheError:
                if strict:
                    raise
                self._log.warning("discarding invalid autotune cache %s "
                                  "(re-probing)", path)
                return 0
            except (OSError, ValueError) as e:
                if strict:
                    raise AutotuneCacheError(
                        f"unreadable autotune cache: {e}")
                self._log.warning("discarding unreadable autotune cache %s "
                                  "(%s)", path, e)
                return 0
            self._entries.update(entries)
            return len(entries)

    def save(self, path: str) -> None:
        """Persist the in-memory choices as the versioned JSON cache
        (atomic replace, so a racing reader never sees a half-written
        file)."""
        with self.lock:
            doc = {"version": self.version, "entries": dict(self._entries)}
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def reset(self) -> None:
        """Forget every choice (and the lazy disk load): the next lookup
        misses and the caller re-probes.  Test hook."""
        with self.lock:
            self._entries.clear()
            self._loaded = False
            self._probes.reset()
            self._hits.reset()

    # ---------------------------------------------------------------- lookups
    def _ensure_loaded(self) -> None:
        if not self._loaded:
            self._loaded = True
            path = self._path()
            if path and os.path.exists(path):
                self.load(path, strict=False)

    def cached(self, key: str) -> bool:
        """Whether ``key`` would resolve from the table (True) or force a
        timing probe (False).  Serving layers use this to quiesce their
        pipelines before a cold probe."""
        with self.lock:
            self._ensure_loaded()
            return key in self._entries

    def lookup(self, key: str) -> Optional[dict]:
        """The recorded entry for ``key`` (counted as a hit), or None."""
        with self.lock:
            self._ensure_loaded()
            ent = self._entries.get(key)
            if ent is not None:
                self._hits.inc()
            return ent

    def record(self, key: str, entry: dict) -> dict:
        """Store a freshly probed entry (counted as a probe) and persist it
        when the env var names a path.  Persistence is an optimization: the
        in-memory choice stands and the caller's dispatch must not fail
        over an unwritable cache path."""
        with self.lock:
            self._entries[key] = entry
            self._probes.inc()
        path = self._path()
        if path:
            try:
                self.save(path)
            except OSError as e:
                self._log.warning("could not persist autotune cache to %s "
                                  "(%s); continuing in-memory", path, e)
        return entry

    def resolve(self, key: str, probe: Callable[[], dict]) -> dict:
        """Serve ``key`` from the table or run ``probe`` once under the
        lock and record its entry.  The lock is held across the probe on
        purpose: two threads racing a cold key must not both measure (the
        loser would time against the winner's dispatches)."""
        with self.lock:
            self._ensure_loaded()
            ent = self._entries.get(key)
            if ent is not None:
                self._hits.inc()
                return ent
            return self.record(key, probe())

    def choices(self, field: str) -> dict:
        """Current routing table: key -> the named entry field."""
        with self.lock:
            return {k: v[field] for k, v in sorted(self._entries.items())}
