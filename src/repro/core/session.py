"""Streaming codec sessions (DESIGN.md Sec. 3).

``IdealemCodec.encode`` is one-shot: dictionary built from scratch per call.
For the paper's deployment scenario -- online compression of continuous
sensor/PMU streams (Sec. I, Fig. 15) -- that destroys the hit rate the FIFO
dictionary exists to provide whenever data arrives in chunks.

``IdealemSession`` owns the persistent encoder state between chunks:

  * per-channel device ``DictState`` (or numpy ``NpDictState``), threaded
    through the resumable ``encode_decisions`` scan so chunked encoding makes
    exactly the same hit/miss decisions as one monolithic pass;
  * per-channel host tail buffers holding samples that do not yet fill a
    block;
  * segment emission: ``feed(chunk) -> bytes`` returns an append-mode stream
    segment (FLAG_MORE/FLAG_CONT framing, see repro.core.stream) and
    ``finish() -> bytes`` the final segment carrying the tail.  The
    concatenation of all returned segments decodes identically to what
    one-shot ``IdealemCodec.encode`` over the concatenated samples decodes
    to.

With ``emit_segments=False`` the session buffers host-side and ``finish``
assembles one classic single-segment stream -- byte-identical to the seed
one-shot format; ``IdealemCodec.encode`` is a thin wrapper over this mode.

Multi-channel: ``channels=C`` batches C independent streams through one
vmapped device scan (blocks stacked ``(C, nb, n)``, per-channel carry);
``feed`` then takes ``(C, m)`` chunks and returns one segment per channel.

Performance note (jax/pallas backends): the device scan compiles per
distinct per-feed block count, so live producers should feed fixed chunk
quanta (ideally a multiple of ``block_size``) to hit steady-state
throughput after the first chunk.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, List, NamedTuple, Optional, Union

import numpy as np

from . import stream as stream_mod
from .stream import StreamHeader

if TYPE_CHECKING:  # pragma: no cover
    from .idealem import IdealemCodec

__all__ = ["IdealemSession", "PreparedChunk", "SessionStats"]


class PreparedChunk(NamedTuple):
    """Host-side staging of one feed: complete blocks cut from the chunk
    (tails already re-buffered) with their transforms applied.

    ``feed`` prepares and decides in one call; the serve-layer coalescer
    prepares many sessions, batches their payloads into one padded device
    call, then ``commit``s each session's decisions back.
    """

    blocks: np.ndarray            # (C, nb, B) raw values
    payloads: np.ndarray          # (C, nb, n_lem) transformed
    bases: List[Optional[np.ndarray]]  # per channel, (nb,) or None (std)
    nb: int


@dataclass
class SessionStats:
    """Per-channel accounting of a streaming session."""

    blocks: int = 0
    hits: int = 0
    segments: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.blocks, 1)

    def as_dict(self) -> dict:
        return {
            "blocks": self.blocks, "hits": self.hits,
            "hit_rate": self.hit_rate, "segments": self.segments,
            "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
            "ratio": self.bytes_in / max(self.bytes_out, 1),
        }


class IdealemSession:
    """Resumable encode session over one codec configuration.

    >>> codec = IdealemCodec(mode="std", block_size=32, num_dict=255)
    >>> s = codec.session()
    >>> parts = [s.feed(chunk) for chunk in chunks] + [s.finish()]
    >>> y = codec.decode(b"".join(parts))   # == decode of one-shot encode
    """

    def __init__(self, codec: "IdealemCodec", channels: Optional[int] = None,
                 emit_segments: bool = True, dtype=np.float64, plan=None,
                 container: bool = False):
        self.codec = codec
        self.channels = channels
        self.emit_segments = emit_segments
        self._writer = None
        if container:
            # every emitted segment is also appended to an in-memory
            # indexed container (repro.store); finish() then returns the
            # random-access packed form instead of the final segment.
            from repro.store.container import ContainerWriter
            self._writer = ContainerWriter()
        self.dtype = np.dtype(dtype)
        C = self._C = channels if channels is not None else 1
        if channels is not None and channels < 1:
            raise ValueError("channels must be >= 1")
        if plan is not None:
            if codec.backend == "numpy":
                raise ValueError("encode plans need a device backend")
            if plan.channels != C:
                raise ValueError(
                    f"plan is for {plan.channels} channels, session has {C}")
        self.plan = plan  # launch.encode_plan.EncodePlan (duck-typed)
        self._tails = [np.zeros(0, dtype=self.dtype) for _ in range(C)]
        self._started = [False] * C  # any segment emitted yet (per channel)
        self._finished = False
        self._stats = [SessionStats() for _ in range(C)]
        self._dev_state = None   # batched DictState (jax / pallas backends)
        self._np_states = None   # list[NpDictState] (numpy backend)
        # host-side accumulation for emit_segments=False (one-shot assembly)
        self._buf = [
            {"raw": [], "payload": [], "bases": [], "hit": [], "slot": [],
             "ovw": []}
            for _ in range(C)
        ]

    # ------------------------------------------------------------- internals
    def _decide(self, payload_cn: np.ndarray):
        """(C, nb, n_lem) transformed blocks -> per-channel decision triples,
        threading the persistent dictionary carry."""
        cdc = self.codec
        kw = dict(
            num_dict=cdc.num_dict,
            d_crit=float(cdc.d_crit),
            rel_tol=float(cdc.rel_tol),
            use_minmax=cdc.use_minmax,
            use_ks=cdc.use_ks,
        )
        if cdc.backend == "numpy":
            from .npref import encode_decisions_np, np_init_state
            if self._np_states is None:
                self._np_states = [np_init_state(cdc.num_dict)
                                   for _ in range(self._C)]
            return [
                encode_decisions_np(payload_cn[ci],
                                    state=self._np_states[ci], **kw)[0]
                for ci in range(self._C)
            ]
        import jax
        import jax.numpy as jnp
        from .encoder import (encode_decisions_batched,
                              encode_decisions_dsharded,
                              encode_decisions_sharded, init_state)
        matcher = getattr(cdc, "matcher", None)
        if cdc.backend == "pallas":
            # default to the fused single-dispatch kernel (bitwise-identical
            # decisions to the composed ops matcher); an explicit codec
            # matcher ("ops", "auto", ...) overrides
            kw["matcher"] = matcher or "fused"
        elif matcher:
            kw["matcher"] = matcher
        if self.plan is not None:
            # scale-out path: channel axis sharded over the plan's mesh;
            # pad rows are masked out of the scan and sliced off below.
            plan = self.plan
            Cp = plan.padded_channels
            pad = Cp - self._C
            if pad:
                payload_cn = np.pad(
                    payload_cn, [(0, pad), (0, 0), (0, 0)])
            pj = jnp.asarray(payload_cn, dtype=jnp.float32)
            valid = np.ones(pj.shape[:2], dtype=bool)
            valid[self._C:] = False
            if self._dev_state is None:
                st = init_state(cdc.num_dict, pj.shape[-1],
                                dtype=jnp.float32, channels=Cp)
                self._dev_state = jax.device_put(st, plan.state_sharding())
            if getattr(plan, "dict_shards", 1) > 1:
                (h, s, o), self._dev_state = encode_decisions_dsharded(
                    pj, mesh=plan.mesh, ch_axis=plan.axis_name,
                    dict_axis=plan.dict_axis, state=self._dev_state,
                    valid=jnp.asarray(valid), **kw)
            else:
                (h, s, o), self._dev_state = encode_decisions_sharded(
                    pj, mesh=plan.mesh, axis_name=plan.axis_name,
                    state=self._dev_state, valid=jnp.asarray(valid), **kw)
        else:
            pj = jnp.asarray(payload_cn, dtype=jnp.float32)
            if self._dev_state is None:
                self._dev_state = init_state(
                    cdc.num_dict, pj.shape[-1], dtype=jnp.float32,
                    channels=self._C)
            # the carry is donated to the scan: the old state is consumed
            (h, s, o), self._dev_state = encode_decisions_batched(
                pj, state=self._dev_state, **kw)
        h, s, o = (np.asarray(v) for v in (h, s, o))
        return [(h[ci], s[ci], o[ci]) for ci in range(self._C)]

    def _make_header(self, ci: int, nb: int, tail: np.ndarray,
                     more: bool) -> StreamHeader:
        cdc = self.codec
        return StreamHeader(
            mode=cdc.mode_id,
            block_size=cdc.block_size,
            num_dict=cdc.num_dict,
            max_count=cdc.max_count,
            dtype=self.dtype,
            value_range=cdc.value_range,
            n_blocks=nb,
            tail=tail,
            more=more,
            cont=self._started[ci],
        )

    def _emit(self, ci, raw, payload, bases, hit, slot, ovw, tail, more):
        header = self._make_header(ci, len(raw), tail, more)
        seg = stream_mod.assemble_stream(header, raw, payload, bases,
                                         hit, slot, ovw)
        self._started[ci] = True
        st = self._stats[ci]
        st.bytes_out += len(seg)
        st.segments += 1
        if self._writer is not None:
            self._writer.append(seg, channel=ci)
        return seg

    def _empty(self, ci: int):
        B = self.codec.block_size
        n_lem = self.codec._lem_n()
        raw = np.zeros((0, B), dtype=self.dtype)
        payload = np.zeros((0, n_lem), dtype=self.dtype)
        bases = None if self.codec.mode == "std" else np.zeros(0, self.dtype)
        z = np.zeros(0, dtype=np.int32)
        return raw, payload, bases, z.astype(bool), z, z.astype(bool)

    # ------------------------------------------------------------ public API
    def prepare(self, chunk) -> Optional[PreparedChunk]:
        """Stage a chunk host-side: buffer the sample tails, cut complete
        blocks and apply the codec transform.  Returns ``None`` when no
        full block completed.  ``feed`` is ``prepare`` + ``_decide`` +
        ``commit``; the serve-layer coalescer calls prepare/commit around
        one shared batched decide."""
        if self._finished:
            raise RuntimeError("session already finished")
        arr = np.asarray(chunk)
        if self.channels is None:
            if arr.ndim != 1:
                raise ValueError("single-channel session feeds 1-D chunks")
            arr = arr[None, :]
        elif arr.ndim != 2 or arr.shape[0] != self._C:
            raise ValueError(f"expected (C={self._C}, m) chunk, got {arr.shape}")
        if arr.dtype != self.dtype:
            arr = arr.astype(self.dtype)

        B = self.codec.block_size
        joined = [np.concatenate([self._tails[ci], arr[ci]])
                  for ci in range(self._C)]
        nb = len(joined[0]) // B
        self._tails = [j[nb * B:] for j in joined]
        for ci in range(self._C):
            self._stats[ci].bytes_in += arr[ci].nbytes
        if nb == 0:
            return None

        blocks = np.stack([j[: nb * B].reshape(nb, B) for j in joined])
        payloads, bases = [], []
        for ci in range(self._C):
            p, b = self.codec._transform(blocks[ci])
            payloads.append(p)
            bases.append(b)
        return PreparedChunk(blocks, np.stack(payloads), bases, nb)

    def commit(self, prep: PreparedChunk, decisions) -> List[bytes]:
        """Apply per-channel decision triples for a prepared chunk: update
        stats and emit (or buffer) each channel's segment.  Always returns
        a per-channel list; decisions may cover only ``prep.nb`` blocks."""
        outs = []
        for ci in range(self._C):
            hit, slot, ovw = decisions[ci]
            st = self._stats[ci]
            st.blocks += prep.nb
            st.hits += int(np.sum(hit))
            if self.emit_segments:
                outs.append(self._emit(
                    ci, prep.blocks[ci], prep.payloads[ci], prep.bases[ci],
                    hit, slot, ovw, tail=np.zeros(0, dtype=self.dtype),
                    more=True))
            else:
                buf = self._buf[ci]
                buf["raw"].append(prep.blocks[ci])
                buf["payload"].append(prep.payloads[ci])
                if prep.bases[ci] is not None:
                    buf["bases"].append(prep.bases[ci])
                buf["hit"].append(hit)
                buf["slot"].append(slot)
                buf["ovw"].append(ovw)
                outs.append(b"")
        return outs

    def feed(self, chunk) -> Union[bytes, List[bytes]]:
        """Compress the next chunk; returns the emitted segment bytes (one
        ``bytes`` for single-channel sessions, a list for ``channels=C``).
        Samples not filling a block are buffered for the next feed/finish;
        an empty ``bytes`` means no full block completed yet."""
        prep = self.prepare(chunk)
        if prep is None:
            empty = [b""] * self._C
            return empty[0] if self.channels is None else empty
        outs = self.commit(prep, self._decide(prep.payloads))
        return outs[0] if self.channels is None else outs

    def finish(self) -> Union[bytes, List[bytes]]:
        """Close the stream(s): emit the final segment carrying the sample
        tail (segment mode) or assemble the whole classic one-segment stream
        (``emit_segments=False``).

        With ``container=True`` the return value is instead ONE packed
        random-access container (``repro.store``) holding every segment of
        every channel -- ready for ``decode_range`` on the serving read
        path; the final per-channel segments are still emitted through the
        writer like any other."""
        if self._finished:
            raise RuntimeError("session already finished")
        self._finished = True
        outs = []
        for ci in range(self._C):
            if self.emit_segments:
                raw, payload, bases, hit, slot, ovw = self._empty(ci)
                outs.append(self._emit(ci, raw, payload, bases, hit, slot,
                                       ovw, tail=self._tails[ci], more=False))
            else:
                buf = self._buf[ci]
                if buf["raw"]:
                    raw = np.concatenate(buf["raw"])
                    payload = np.concatenate(buf["payload"])
                    bases = (np.concatenate(buf["bases"])
                             if buf["bases"] else None)
                    hit = np.concatenate(buf["hit"])
                    slot = np.concatenate(buf["slot"])
                    ovw = np.concatenate(buf["ovw"])
                else:
                    raw, payload, bases, hit, slot, ovw = self._empty(ci)
                outs.append(self._emit(ci, raw, payload, bases, hit, slot,
                                       ovw, tail=self._tails[ci], more=False))
        if self._writer is not None:
            return self._writer.finalize()
        return outs[0] if self.channels is None else outs

    @property
    def stats(self) -> Union[SessionStats, List[SessionStats]]:
        return self._stats[0] if self.channels is None else list(self._stats)
