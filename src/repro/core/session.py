"""Streaming codec sessions (DESIGN.md Sec. 3).

``IdealemCodec.encode`` is one-shot: dictionary built from scratch per call.
For the paper's deployment scenario -- online compression of continuous
sensor/PMU streams (Sec. I, Fig. 15) -- that destroys the hit rate the FIFO
dictionary exists to provide whenever data arrives in chunks.

``IdealemSession`` owns the persistent encoder state between chunks:

  * per-channel device ``DictState`` (or numpy ``NpDictState``), threaded
    through the resumable ``encode_decisions`` scan so chunked encoding makes
    exactly the same hit/miss decisions as one monolithic pass;
  * per-channel host tail buffers holding samples that do not yet fill a
    block;
  * segment emission: ``feed(chunk) -> bytes`` returns an append-mode stream
    segment (FLAG_MORE/FLAG_CONT framing, see repro.core.stream) and
    ``finish() -> bytes`` the final segment carrying the tail.  The
    concatenation of all returned segments decodes identically to what
    one-shot ``IdealemCodec.encode`` over the concatenated samples decodes
    to.

With ``emit_segments=False`` the session buffers host-side and ``finish``
assembles one classic single-segment stream -- byte-identical to the seed
one-shot format; ``IdealemCodec.encode`` is a thin wrapper over this mode.

Multi-channel: ``channels=C`` batches C independent streams through one
vmapped device scan (blocks stacked ``(C, nb, n)``, per-channel carry);
``feed`` then takes ``(C, m)`` chunks and returns one segment per channel.

Performance note (jax/pallas backends): the device scan compiles per
distinct per-feed block count, so live producers should feed fixed chunk
quanta (ideally a multiple of ``block_size``) to hit steady-state
throughput after the first chunk.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, NamedTuple, Optional, Union

import numpy as np

from . import stream as stream_mod
from .. import obs
from .stream import StreamHeader

# Session-level registry counters (ISSUE 8): process-wide aggregates over
# every session/channel; per-channel detail stays on ``SessionStats``.
# The per-(block, slot) gate attribution lives in ``npref`` (host walk).
_M = {
    key: obs.registry().counter(f"repro_encode_{key}_total", help_text)
    for key, help_text in {
        "bytes_in": "raw sample bytes accepted by sessions",
        "bytes_out": "emitted segment bytes (compressed size)",
        "segments": "stream segments emitted",
        "blocks": "blocks encoded",
        "hits": "blocks replaced by a dictionary reference",
        "mode_switches": "adaptive selector mode/scale switches applied",
    }.items()
}

# Adaptive dispatch accounting (ISSUE 9): the batched mixed scan issues one
# device dispatch per feed regardless of channel count; the fallback loop
# issues one per channel.  Tests pin the per-feed dispatch contract on
# these counters, and the cohort histogram records how many channels each
# adaptive dispatch covered.
_M_DISPATCH = {
    path: obs.registry().counter(
        "repro_encode_dispatches_total",
        "device encode-scan dispatches by path",
        labels={"path": path})
    for path in ("adaptive_batched", "adaptive_loop")
}
_M_COHORT = obs.registry().histogram(
    "repro_encode_adaptive_cohort",
    "channels covered per adaptive encode dispatch (cohort size)",
    buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
             1024.0))

# force the per-channel fallback loop (bench/debug hook; ignored for
# plan-sharded sessions, which require the batched mixed scan)
_ADAPTIVE_LOOP_ENV = "REPRO_ADAPTIVE_LOOP"

if TYPE_CHECKING:  # pragma: no cover
    from .idealem import IdealemCodec

__all__ = ["IdealemSession", "MixedCohort", "PreparedChunk", "SessionStats"]


def _mixed_matcher_name(codec):
    """The batched mixed scan's matcher for a codec config, or ``None``
    when only the per-channel loop can honor it (``"ops"``/``"auto"``/
    custom callables have no masked variant)."""
    m = getattr(codec, "matcher", None)
    if codec.backend == "pallas":
        m = m or "fused"
    if m is None or m == "reference":
        return "reference"
    if m == "fused" or (isinstance(m, tuple) and len(m) == 2
                        and m[0] == "fused"):
        return m
    from .encoder import matcher_reference
    if m is matcher_reference:
        return "reference"
    return None


class MixedCohort:
    """Shared batched carry + dispatcher for heterogeneous (mixed-mode)
    channels (DESIGN.md Sec. 13).

    Owns one ``(capacity, D, n_max)`` ``DictState`` whose lanes stay
    logically per-channel: payload widths are padded to the max across
    live lanes with ``+inf`` (``repad_state_n`` follows the max as lanes
    come and go), tail columns are masked per lane inside the scan, and a
    selector switch resets a lane in place (:meth:`reset_lane`) instead of
    rebuilding the batch.  :meth:`decide` assembles the padded cohort and
    issues ONE device dispatch + ONE host sync per feed/flush no matter
    how many lanes diverge in mode, width, threshold or error metric.
    """

    def __init__(self, num_dict: int, capacity: int, *, rel_tol: float,
                 use_minmax: bool = True, use_ks: bool = True,
                 error_bound: Optional[float] = None, matcher=None,
                 plan=None):
        if plan is not None and capacity != plan.padded_channels:
            raise ValueError(
                f"cohort capacity {capacity} != plan padded_channels "
                f"{plan.padded_channels}")
        self.num_dict = int(num_dict)
        self.capacity = int(capacity)
        self.rel_tol = float(rel_tol)
        self.use_minmax = use_minmax
        self.use_ks = use_ks
        self.error_bound = None if error_bound is None else float(error_bound)
        self.matcher = matcher
        self.plan = plan
        self.state = None  # batched DictState, width padded to _n_max
        self._n_max = 0
        self.lane_n = np.zeros(self.capacity, dtype=np.int64)
        self.dispatches = 0

    def reset_lane(self, lane: int) -> None:
        """Drop one lane's dictionary in place (selector switch, stream
        close): its rows turn ``valid=False`` and its FIFO count rewinds;
        every other lane's carry is untouched."""
        self.lane_n[lane] = 0
        if self.state is not None:
            st = self.state
            self.state = st._replace(valid=st.valid.at[lane].set(False),
                                     count=st.count.at[lane].set(0))

    def grow(self, capacity: int) -> None:
        """Extend the lane axis (coalescer capacity growth); new lanes
        start empty."""
        import jax.numpy as jnp

        add = int(capacity) - self.capacity
        if add <= 0:
            return
        if self.plan is not None:
            raise ValueError("plan-pinned cohorts cannot grow")
        self.lane_n = np.concatenate(
            [self.lane_n, np.zeros(add, dtype=np.int64)])
        if self.state is not None:
            st = self.state
            self.state = st._replace(**{
                f: jnp.pad(getattr(st, f),
                           [(0, add)] + [(0, 0)] * (getattr(st, f).ndim - 1))
                for f in st._fields})
        self.capacity = int(capacity)

    def decide(self, entries, *, nb_pad: Optional[int] = None):
        """One batched mixed-mode dispatch over ``entries``: a list of
        ``(lane, payload (nb_i, n_i), d_crit, err_cum, eb_on)`` tuples.
        Payload widths are padded to the cohort max with +inf and block
        counts to ``nb_pad`` (default: the max over entries) via the valid
        mask.  Returns ``{lane: (is_hit, slot, overwrite)}`` sliced back
        to each entry's real block count, after the single host sync."""
        import jax
        import jax.numpy as jnp
        from .encoder import (encode_decisions_mixed,
                              encode_decisions_mixed_sharded, init_state,
                              repad_state_n)

        for lane, p, *_ in entries:
            self.lane_n[lane] = p.shape[-1]
        n_max = int(self.lane_n.max())
        nb = max(p.shape[0] for _, p, *_ in entries)
        if nb_pad is not None:
            nb = max(nb, int(nb_pad))
        batch = np.full((self.capacity, nb, n_max), np.inf, dtype=np.float32)
        valid = np.zeros((self.capacity, nb), dtype=bool)
        d_crit = np.ones(self.capacity, dtype=np.float32)
        err_cum = np.zeros(self.capacity, dtype=bool)
        eb_on = np.zeros(self.capacity, dtype=bool)
        for lane, p, dc, ec, ebo in entries:
            nb_i, n_i = p.shape
            batch[lane, :nb_i, :n_i] = p
            valid[lane, :nb_i] = True
            d_crit[lane] = dc
            err_cum[lane] = ec
            eb_on[lane] = ebo
        eb = self.error_bound
        if self.state is None:
            st = init_state(self.num_dict, n_max, dtype=jnp.float32,
                            channels=self.capacity, raw=eb is not None)
        elif n_max != self._n_max:
            st = repad_state_n(self.state, n_max)
        else:
            st = self.state
        if st is not self.state and self.plan is not None:
            st = jax.device_put(st, self.plan.state_sharding())
        self._n_max = n_max
        kw = dict(num_dict=self.num_dict, n_valid=np.maximum(self.lane_n, 1),
                  d_crit=d_crit, rel_tol=self.rel_tol,
                  use_minmax=self.use_minmax, use_ks=self.use_ks,
                  error_bound=eb, error_cumulative=err_cum, eb_on=eb_on,
                  matcher=self.matcher, state=st, valid=jnp.asarray(valid))
        pj = jnp.asarray(batch)
        if self.plan is not None:
            (h, s, o), self.state = encode_decisions_mixed_sharded(
                pj, mesh=self.plan.mesh, axis_name=self.plan.axis_name, **kw)
        else:
            (h, s, o), self.state = encode_decisions_mixed(pj, **kw)
        self.dispatches += 1
        _M_DISPATCH["adaptive_batched"].inc()
        _M_COHORT.observe(float(len(entries)))
        h, s, o = jax.device_get((h, s, o))  # the one host sync per feed
        return {lane: (np.asarray(h[lane, :p.shape[0]]),
                       np.asarray(s[lane, :p.shape[0]]),
                       np.asarray(o[lane, :p.shape[0]]))
                for lane, p, *_ in entries}


class PreparedChunk(NamedTuple):
    """Host-side staging of one feed: complete blocks cut from the chunk
    (tails already re-buffered) with their transforms applied.

    ``feed`` prepares and decides in one call; the serve-layer coalescer
    prepares many sessions, batches their payloads into one padded device
    call, then ``commit``s each session's decisions back.
    """

    blocks: np.ndarray            # (C, nb, B) raw values
    payloads: np.ndarray          # (C, nb, n_lem) transformed
    bases: List[Optional[np.ndarray]]  # per channel, (nb,) or None (std)
    nb: int


@dataclass
class SessionStats:
    """Per-channel accounting of a streaming session."""

    blocks: int = 0
    hits: int = 0
    segments: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    # adaptive sessions: accepted selector switches (core.select), as dicts
    mode_switches: int = 0
    events: List[dict] = field(default_factory=list)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.blocks, 1)

    def as_dict(self) -> dict:
        return {
            "blocks": self.blocks, "hits": self.hits,
            "hit_rate": self.hit_rate, "segments": self.segments,
            "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
            "ratio": self.bytes_in / max(self.bytes_out, 1),
            "mode_switches": self.mode_switches,
            "events": list(self.events),
        }


class IdealemSession:
    """Resumable encode session over one codec configuration.

    >>> codec = IdealemCodec(mode="std", block_size=32, num_dict=255)
    >>> s = codec.session()
    >>> parts = [s.feed(chunk) for chunk in chunks] + [s.finish()]
    >>> y = codec.decode(b"".join(parts))   # == decode of one-shot encode
    """

    def __init__(self, codec: "IdealemCodec", channels: Optional[int] = None,
                 emit_segments: bool = True, dtype=np.float64, plan=None,
                 container: bool = False):
        self.codec = codec
        self.channels = channels
        self.emit_segments = emit_segments
        self._writer = None
        if container:
            # every emitted segment is also appended to an in-memory
            # indexed container (repro.store); finish() then returns the
            # random-access packed form instead of the final segment.
            from repro.store.container import ContainerWriter
            self._writer = ContainerWriter()
        self.dtype = np.dtype(dtype)
        C = self._C = channels if channels is not None else 1
        if channels is not None and channels < 1:
            raise ValueError("channels must be >= 1")
        if plan is not None:
            if codec.backend == "numpy":
                raise ValueError("encode plans need a device backend")
            if plan.channels != C:
                raise ValueError(
                    f"plan is for {plan.channels} channels, session has {C}")
        self.plan = plan  # launch.encode_plan.EncodePlan (duck-typed)
        self._tails = [np.zeros(0, dtype=self.dtype) for _ in range(C)]
        self._started = [False] * C  # any segment emitted yet (per channel)
        self._finished = False
        self._stats = [SessionStats() for _ in range(C)]
        self._dev_state = None   # batched DictState (jax / pallas backends)
        self._np_states = None   # list[NpDictState] (numpy backend)
        # adaptive per-channel mode selection (core.select): each channel
        # carries its own current codec variant + quantized d_crit; a switch
        # resets the channel dictionary and restarts its segment chain.
        self.adaptive = bool(getattr(codec, "adaptive", False))
        self._codecs = [codec] * C
        self._d_crit = [float(codec.d_crit)] * C
        self._selectors = None
        self._adapt_states = None  # per-channel DictState list (device)
        if self.adaptive:
            if not emit_segments:
                raise ValueError(
                    "adaptive sessions require emit_segments=True (mode "
                    "switches live at segment restarts)")
            if container:
                raise ValueError(
                    "adaptive sessions do not support container output")
            if plan is not None:
                # the batched mixed scan shards the channel axis only: one
                # lane per channel, widths padded/masked per lane.
                if getattr(plan, "dict_shards", 1) > 1:
                    raise ValueError(
                        "adaptive sessions shard channels only; build the "
                        "plan with dict_shards=1")
                if _mixed_matcher_name(codec) is None:
                    raise ValueError(
                        "adaptive sessions with an encode plan need the "
                        "reference or fused matcher (the batched mixed scan "
                        f"has no masked variant of "
                        f"{getattr(codec, 'matcher', None)!r})")
            from .select import ChannelSelector
            self._selectors = [
                ChannelSelector(codec.block_size, mode=codec.mode,
                                config=getattr(codec, "selector", None))
                for _ in range(C)]
            self._adapt_states = [None] * C
        self._mixed = None           # MixedCohort (device adaptive batch)
        self._mixed_disabled = False  # matcher has no masked variant
        # host-side accumulation for emit_segments=False (one-shot assembly)
        self._buf = [
            {"raw": [], "payload": [], "bases": [], "hit": [], "slot": [],
             "ovw": []}
            for _ in range(C)
        ]

    # ------------------------------------------------------------- internals
    def _decide(self, payload_cn: np.ndarray):
        """(C, nb, n_lem) transformed blocks -> per-channel decision triples,
        threading the persistent dictionary carry."""
        cdc = self.codec
        kw = dict(
            num_dict=cdc.num_dict,
            d_crit=float(cdc.d_crit),
            rel_tol=float(cdc.rel_tol),
            use_minmax=cdc.use_minmax,
            use_ks=cdc.use_ks,
        )
        eb = getattr(cdc, "error_bound", None)
        if eb is not None:
            kw["error_bound"] = float(eb)
            kw["error_cumulative"] = cdc.mode == "delta"
        if cdc.backend == "numpy":
            from .npref import encode_decisions_np, np_init_state
            if self._np_states is None:
                self._np_states = [np_init_state(cdc.num_dict)
                                   for _ in range(self._C)]
            return [
                encode_decisions_np(payload_cn[ci],
                                    state=self._np_states[ci], **kw)[0]
                for ci in range(self._C)
            ]
        import jax
        import jax.numpy as jnp
        from .encoder import (encode_decisions_batched,
                              encode_decisions_dsharded,
                              encode_decisions_sharded, init_state)
        matcher = getattr(cdc, "matcher", None)
        if cdc.backend == "pallas":
            # default to the fused single-dispatch kernel (bitwise-identical
            # decisions to the composed ops matcher); an explicit codec
            # matcher ("ops", "auto", ...) overrides
            kw["matcher"] = matcher or "fused"
        elif matcher:
            kw["matcher"] = matcher
        if self.plan is not None:
            # scale-out path: channel axis sharded over the plan's mesh;
            # pad rows are masked out of the scan and sliced off below.
            plan = self.plan
            Cp = plan.padded_channels
            pad = Cp - self._C
            if pad:
                payload_cn = np.pad(
                    payload_cn, [(0, pad), (0, 0), (0, 0)])
            pj = jnp.asarray(payload_cn, dtype=jnp.float32)
            valid = np.ones(pj.shape[:2], dtype=bool)
            valid[self._C:] = False
            if self._dev_state is None:
                st = init_state(cdc.num_dict, pj.shape[-1],
                                dtype=jnp.float32, channels=Cp,
                                raw=eb is not None)
                self._dev_state = jax.device_put(st, plan.state_sharding())
            if getattr(plan, "dict_shards", 1) > 1:
                (h, s, o), self._dev_state = encode_decisions_dsharded(
                    pj, mesh=plan.mesh, ch_axis=plan.axis_name,
                    dict_axis=plan.dict_axis, state=self._dev_state,
                    valid=jnp.asarray(valid), **kw)
            else:
                (h, s, o), self._dev_state = encode_decisions_sharded(
                    pj, mesh=plan.mesh, axis_name=plan.axis_name,
                    state=self._dev_state, valid=jnp.asarray(valid), **kw)
        else:
            pj = jnp.asarray(payload_cn, dtype=jnp.float32)
            if self._dev_state is None:
                self._dev_state = init_state(
                    cdc.num_dict, pj.shape[-1], dtype=jnp.float32,
                    channels=self._C, raw=eb is not None)
            # the carry is donated to the scan: the old state is consumed
            (h, s, o), self._dev_state = encode_decisions_batched(
                pj, state=self._dev_state, **kw)
        h, s, o = (np.asarray(v) for v in (h, s, o))
        return [(h[ci], s[ci], o[ci]) for ci in range(self._C)]

    # ------------------------------------------------- adaptive mode selection
    def _channel_kw(self, ci: int) -> dict:
        """Per-channel encode kwargs under the channel's current codec
        variant (adaptive sessions only)."""
        cdc0 = self.codec
        cdc = self._codecs[ci]
        kw = dict(
            num_dict=cdc0.num_dict,
            d_crit=float(self._d_crit[ci]),
            rel_tol=float(cdc0.rel_tol),
            use_minmax=cdc0.use_minmax,
            use_ks=cdc0.use_ks,
        )
        eb = getattr(cdc, "error_bound", None)
        if eb is not None:
            kw["error_bound"] = float(eb)
            kw["error_cumulative"] = cdc.mode == "delta"
        return kw

    def _decide_adaptive(self, payloads):
        """Per-channel decisions under per-channel codec variants: one
        batched masked scan when the matcher has a mixed variant (one
        device dispatch + one host sync per feed, DESIGN.md Sec. 13),
        else the per-channel loop with a single deferred sync."""
        cdc0 = self.codec
        if cdc0.backend == "numpy":
            from .npref import encode_decisions_np, np_init_state
            if self._np_states is None:
                self._np_states = [np_init_state(cdc0.num_dict)
                                   for _ in range(self._C)]
            return [
                encode_decisions_np(payloads[ci],
                                    state=self._np_states[ci],
                                    **self._channel_kw(ci))[0]
                for ci in range(self._C)
            ]
        if self._mixed is None and not self._mixed_disabled:
            force_loop = (os.environ.get(_ADAPTIVE_LOOP_ENV)
                          and self.plan is None)
            m = None if force_loop else _mixed_matcher_name(cdc0)
            if m is None:
                self._mixed_disabled = True
            else:
                eb = getattr(cdc0, "error_bound", None)
                self._mixed = MixedCohort(
                    cdc0.num_dict,
                    (self.plan.padded_channels if self.plan is not None
                     else self._C),
                    rel_tol=float(cdc0.rel_tol),
                    use_minmax=cdc0.use_minmax, use_ks=cdc0.use_ks,
                    error_bound=None if eb is None else float(eb),
                    matcher=m, plan=self.plan)
        if self._mixed is not None:
            entries = []
            for ci in range(self._C):
                cdc = self._codecs[ci]
                entries.append((ci, np.asarray(payloads[ci]),
                                float(self._d_crit[ci]),
                                cdc.mode == "delta",
                                getattr(cdc, "error_bound", None) is not None))
            dec = self._mixed.decide(entries)
            return [dec[ci] for ci in range(self._C)]
        return self._decide_adaptive_loop(payloads)

    def _decide_adaptive_loop(self, payloads):
        """Per-channel fallback for matchers without a masked variant
        ("ops"/"auto"/callables): one dispatch per channel, but all
        dispatches issue before the single ``block_until_ready`` barrier
        so device work overlaps across channels."""
        import jax
        import jax.numpy as jnp
        from .encoder import encode_decisions, init_state
        cdc0 = self.codec
        outs = []
        for ci in range(self._C):
            kw = self._channel_kw(ci)
            matcher = getattr(cdc0, "matcher", None)
            if cdc0.backend == "pallas":
                kw["matcher"] = matcher or "fused"
            elif matcher:
                kw["matcher"] = matcher
            pj = jnp.asarray(payloads[ci], dtype=jnp.float32)
            if self._adapt_states[ci] is None:
                self._adapt_states[ci] = init_state(
                    cdc0.num_dict, pj.shape[-1], dtype=jnp.float32,
                    raw="error_bound" in kw)
            out, self._adapt_states[ci] = encode_decisions(
                pj, state=self._adapt_states[ci], **kw)
            _M_DISPATCH["adaptive_loop"].inc()
            outs.append(out)
        jax.block_until_ready(outs)
        _M_COHORT.observe(float(self._C))
        return [tuple(np.asarray(v) for v in out) for out in outs]

    def _apply_switch(self, ci: int, ev) -> None:
        """Commit an accepted selector switch: swap the channel's codec
        variant, quantize its threshold, drop its dictionary and restart its
        segment chain (the next segment is cont=False, so decoders treat it
        as a fresh section)."""
        import dataclasses
        cdc = self.codec if ev.new_mode == self.codec.mode \
            else dataclasses.replace(self.codec, mode=ev.new_mode)
        self._codecs[ci] = cdc
        self._d_crit[ci] = float(cdc.d_crit) * float(ev.new_scale)
        self._started[ci] = False
        if self._np_states is not None:
            from .npref import np_init_state
            self._np_states[ci] = np_init_state(self.codec.num_dict)
        if self._adapt_states is not None:
            self._adapt_states[ci] = None
        if self._mixed is not None:
            self._mixed.reset_lane(ci)
        st = self._stats[ci]
        st.mode_switches += 1
        st.events.append(ev.as_dict())
        _M["mode_switches"].inc()
        # the selector's decision, as a structured trace event: channel +
        # the full SelectionEvent payload (rho1, var ratio, drift, scales)
        obs.event("encode.mode_switch", attrs={"channel": ci,
                                               **ev.as_dict()})

    def _feed_adaptive(self, chunk):
        if self._finished:
            raise RuntimeError("session already finished")
        arr = np.asarray(chunk)
        arr2 = arr[None, :] if self.channels is None else arr
        if arr2.ndim != 2 or arr2.shape[0] != self._C:
            raise ValueError(
                f"expected {'1-D' if self.channels is None else f'(C={self._C}, m)'}"
                f" chunk, got {arr.shape}")
        # switches apply at the feed boundary, from statistics through the
        # *previous* feeds -- a segment never changes transform mid-flight
        for ci in range(self._C):
            ev = self._selectors[ci].decide(self._stats[ci].blocks)
            if ev is not None:
                self._apply_switch(ci, ev)
        for ci in range(self._C):
            self._selectors[ci].observe(arr2[ci])
        prep = self.prepare(chunk)
        if prep is None:
            empty = [b""] * self._C
            return empty[0] if self.channels is None else empty
        outs = self.commit(prep, self._decide_adaptive(prep.payloads))
        return outs[0] if self.channels is None else outs

    def _make_header(self, ci: int, nb: int, tail: np.ndarray,
                     more: bool) -> StreamHeader:
        cdc = self._codecs[ci]
        return StreamHeader(
            mode=cdc.mode_id,
            block_size=cdc.block_size,
            num_dict=cdc.num_dict,
            max_count=cdc.max_count,
            dtype=self.dtype,
            value_range=cdc.value_range,
            n_blocks=nb,
            tail=tail,
            more=more,
            cont=self._started[ci],
            error_bounded=getattr(cdc, "error_bound", None) is not None,
        )

    def _emit(self, ci, raw, payload, bases, hit, slot, ovw, tail, more):
        header = self._make_header(ci, len(raw), tail, more)
        seg = stream_mod.assemble_stream(header, raw, payload, bases,
                                         hit, slot, ovw)
        self._started[ci] = True
        st = self._stats[ci]
        st.bytes_out += len(seg)
        st.segments += 1
        _M["bytes_out"].inc(len(seg))
        _M["segments"].inc()
        if self._writer is not None:
            self._writer.append(seg, channel=ci)
        return seg

    def _empty(self, ci: int):
        cdc = self._codecs[ci]
        B = cdc.block_size
        n_lem = cdc._lem_n()
        raw = np.zeros((0, B), dtype=self.dtype)
        payload = np.zeros((0, n_lem), dtype=self.dtype)
        bases = None if cdc.mode == "std" else np.zeros(0, self.dtype)
        z = np.zeros(0, dtype=np.int32)
        return raw, payload, bases, z.astype(bool), z, z.astype(bool)

    # ------------------------------------------------------------ public API
    def prepare(self, chunk) -> Optional[PreparedChunk]:
        """Stage a chunk host-side: buffer the sample tails, cut complete
        blocks and apply the codec transform.  Returns ``None`` when no
        full block completed.  ``feed`` is ``prepare`` + ``_decide`` +
        ``commit``; the serve-layer coalescer calls prepare/commit around
        one shared batched decide."""
        if self._finished:
            raise RuntimeError("session already finished")
        arr = np.asarray(chunk)
        if self.channels is None:
            if arr.ndim != 1:
                raise ValueError("single-channel session feeds 1-D chunks")
            arr = arr[None, :]
        elif arr.ndim != 2 or arr.shape[0] != self._C:
            raise ValueError(f"expected (C={self._C}, m) chunk, got {arr.shape}")
        if arr.dtype != self.dtype:
            arr = arr.astype(self.dtype)

        B = self.codec.block_size
        joined = [np.concatenate([self._tails[ci], arr[ci]])
                  for ci in range(self._C)]
        nb = len(joined[0]) // B
        self._tails = [j[nb * B:] for j in joined]
        for ci in range(self._C):
            self._stats[ci].bytes_in += arr[ci].nbytes
        _M["bytes_in"].inc(arr.nbytes)
        if nb == 0:
            return None

        blocks = np.stack([j[: nb * B].reshape(nb, B) for j in joined])
        payloads, bases = [], []
        for ci in range(self._C):
            p, b = self._codecs[ci]._transform(blocks[ci])
            payloads.append(p)
            bases.append(b)
        # adaptive channels may carry different payload widths (std vs
        # delta/residual), so they stay a ragged list; the static path keeps
        # the stacked array the batched device scan consumes
        stacked = payloads if self.adaptive else np.stack(payloads)
        return PreparedChunk(blocks, stacked, bases, nb)

    def commit(self, prep: PreparedChunk, decisions) -> List[bytes]:
        """Apply per-channel decision triples for a prepared chunk: update
        stats and emit (or buffer) each channel's segment.  Always returns
        a per-channel list; decisions may cover only ``prep.nb`` blocks."""
        outs = []
        total_hits = 0
        for ci in range(self._C):
            hit, slot, ovw = decisions[ci]
            st = self._stats[ci]
            st.blocks += prep.nb
            n_hits = int(np.sum(hit))
            st.hits += n_hits
            total_hits += n_hits
            if self.emit_segments:
                outs.append(self._emit(
                    ci, prep.blocks[ci], prep.payloads[ci], prep.bases[ci],
                    hit, slot, ovw, tail=np.zeros(0, dtype=self.dtype),
                    more=True))
            else:
                buf = self._buf[ci]
                buf["raw"].append(prep.blocks[ci])
                buf["payload"].append(prep.payloads[ci])
                if prep.bases[ci] is not None:
                    buf["bases"].append(prep.bases[ci])
                buf["hit"].append(hit)
                buf["slot"].append(slot)
                buf["ovw"].append(ovw)
                outs.append(b"")
        _M["blocks"].inc(prep.nb * self._C)
        _M["hits"].inc(total_hits)
        return outs

    def feed(self, chunk) -> Union[bytes, List[bytes]]:
        """Compress the next chunk; returns the emitted segment bytes (one
        ``bytes`` for single-channel sessions, a list for ``channels=C``).
        Samples not filling a block are buffered for the next feed/finish;
        an empty ``bytes`` means no full block completed yet."""
        if self.adaptive:
            return self._feed_adaptive(chunk)
        prep = self.prepare(chunk)
        if prep is None:
            empty = [b""] * self._C
            return empty[0] if self.channels is None else empty
        outs = self.commit(prep, self._decide(prep.payloads))
        return outs[0] if self.channels is None else outs

    def finish(self) -> Union[bytes, List[bytes]]:
        """Close the stream(s): emit the final segment carrying the sample
        tail (segment mode) or assemble the whole classic one-segment stream
        (``emit_segments=False``).

        With ``container=True`` the return value is instead ONE packed
        random-access container (``repro.store``) holding every segment of
        every channel -- ready for ``decode_range`` on the serving read
        path; the final per-channel segments are still emitted through the
        writer like any other."""
        if self._finished:
            raise RuntimeError("session already finished")
        self._finished = True
        outs = []
        for ci in range(self._C):
            if self.emit_segments:
                raw, payload, bases, hit, slot, ovw = self._empty(ci)
                outs.append(self._emit(ci, raw, payload, bases, hit, slot,
                                       ovw, tail=self._tails[ci], more=False))
            else:
                buf = self._buf[ci]
                if buf["raw"]:
                    raw = np.concatenate(buf["raw"])
                    payload = np.concatenate(buf["payload"])
                    bases = (np.concatenate(buf["bases"])
                             if buf["bases"] else None)
                    hit = np.concatenate(buf["hit"])
                    slot = np.concatenate(buf["slot"])
                    ovw = np.concatenate(buf["ovw"])
                else:
                    raw, payload, bases, hit, slot, ovw = self._empty(ci)
                outs.append(self._emit(ci, raw, payload, bases, hit, slot,
                                       ovw, tail=self._tails[ci], more=False))
        if self._writer is not None:
            return self._writer.finalize()
        return outs[0] if self.channels is None else outs

    @property
    def stats(self) -> Union[SessionStats, List[SessionStats]]:
        return self._stats[0] if self.channels is None else list(self._stats)
