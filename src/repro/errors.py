"""Unified exception hierarchy: every typed repro failure under one root.

``ReproError`` is the root; the pre-existing typed exceptions
(``StreamFormatError``, ``ContainerFormatError``, ``AutotuneCacheError``,
``KernelShapeError``) are re-parented under it *without* losing their
``ValueError`` base, so ``except ValueError`` call sites and tests keep
working.  Their historical import paths (``repro.core.stream``,
``repro.store.container``, ``repro.core.tuning``,
``repro.kernels.dict_match``) re-export from here.

Every class carries the protocol mapping the serving front end
(``repro.serve.frontend``) speaks on the wire:

* ``code``        -- stable machine-readable error code (snake_case);
* ``http_status`` -- the HTTP status the front end answers with.

``error_payload`` builds the JSON error body; ``ERROR_CODES`` maps codes
back to classes so wire clients can re-raise typed errors.

This module is dependency-free (stdlib only): it sits below ``core``,
``store``, ``kernels`` and ``serve`` in the import graph.
"""
from __future__ import annotations

from typing import Dict, Optional, Type

__all__ = [
    "ReproError",
    "StreamFormatError",
    "ContainerFormatError",
    "AutotuneCacheError",
    "KernelShapeError",
    "ApiError",
    "AdmissionError",
    "QuotaExceededError",
    "RateLimitedError",
    "OverloadedError",
    "NotFoundError",
    "ERROR_CODES",
    "error_payload",
    "error_from_payload",
]


class ReproError(Exception):
    """Root of every typed repro failure.

    ``code``/``http_status`` are class attributes so subclasses declare
    their protocol mapping declaratively; unknown/unexpected exceptions
    map to the root's ``internal``/500.
    """

    code: str = "internal"
    http_status: int = 500


# ------------------------------------------------------------- re-parented
# The four pre-existing typed exceptions.  Each keeps ``ValueError`` in its
# bases (callers and tests match on it) and gains the ``ReproError`` root +
# a protocol code.  The defining modules import these back, so both the old
# and the new import paths name the SAME class object.

class StreamFormatError(ReproError, ValueError):
    """Malformed/truncated IDEALEM stream.  ``offset`` is the byte position
    at which parsing failed (raw ``struct.error``/``IndexError`` from the
    walk are never surfaced to callers)."""

    code = "stream_format"
    http_status = 400

    def __init__(self, message: str, offset: int = 0):
        super().__init__(f"{message} (at byte {offset})")
        self.offset = offset


class ContainerFormatError(ReproError, ValueError):
    """Malformed container: bad magic/version/CRC or inconsistent index."""

    code = "container_format"
    http_status = 400


class AutotuneCacheError(ReproError, ValueError):
    """A persisted autotune cache failed validation (corrupt JSON, wrong
    structure, or a stale ``version`` field)."""

    code = "autotune_cache"
    http_status = 500


class KernelShapeError(ReproError, ValueError):
    """An operand shape violates a kernel's tiling contract.

    Raised instead of a bare assert so a bad launch plan fails with the
    actual dimensions and the required padding in the message."""

    code = "kernel_shape"
    http_status = 500


# ------------------------------------------------------------ serving layer
class ApiError(ReproError, ValueError):
    """A request payload failed validation (bad JSON, missing field,
    wrong type) before reaching any service."""

    code = "bad_request"
    http_status = 400


class NotFoundError(ReproError, KeyError):
    """A named resource (stream, store, tenant, route) does not exist."""

    code = "not_found"
    http_status = 404

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0] if self.args else ""


class AdmissionError(ReproError):
    """Base of the typed admission-control rejections the front end maps
    onto 429/503.  ``retry_after_s`` (when known) becomes the protocol's
    ``retry_after_s`` field and the ``Retry-After`` header."""

    code = "admission"
    http_status = 429

    def __init__(self, message: str,
                 retry_after_s: Optional[float] = None):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class QuotaExceededError(AdmissionError):
    """A per-tenant quota (streams, stores, staged blocks) is exhausted:
    the tenant must shed load; retrying without closing something is
    futile, so no ``retry_after_s`` is implied."""

    code = "quota_exceeded"
    http_status = 429


class RateLimitedError(AdmissionError):
    """The tenant's bytes/s token bucket is empty; ``retry_after_s`` says
    when enough tokens will have refilled."""

    code = "rate_limited"
    http_status = 429


class OverloadedError(AdmissionError):
    """Global (cross-tenant) backpressure: the server's staged work
    exceeds its flush pipeline's budget.  Retry after the pipeline
    drains -- a server-health condition, hence 503 not 429."""

    code = "overloaded"
    http_status = 503


ERROR_CODES: Dict[str, Type[ReproError]] = {
    cls.code: cls
    for cls in (ReproError, StreamFormatError, ContainerFormatError,
                AutotuneCacheError, KernelShapeError, ApiError,
                NotFoundError, AdmissionError, QuotaExceededError,
                RateLimitedError, OverloadedError)
}


def error_payload(exc: BaseException) -> dict:
    """The protocol error body for an exception: ``{"error": {"code",
    "message", ...}}``.  Non-``ReproError`` exceptions map to the root
    ``internal`` code (the message still travels, the type does not)."""
    code = exc.code if isinstance(exc, ReproError) else ReproError.code
    body = {"code": code, "message": str(exc)}
    retry = getattr(exc, "retry_after_s", None)
    if retry is not None:
        body["retry_after_s"] = float(retry)
    return {"error": body}


def error_from_payload(doc: dict) -> ReproError:
    """Re-raise-able typed error from a protocol error body (the client
    half of :func:`error_payload`); unknown codes become ``ReproError``."""
    body = doc.get("error", doc)
    cls = ERROR_CODES.get(body.get("code", ""), ReproError)
    msg = body.get("message", "")
    if issubclass(cls, AdmissionError):
        return cls(msg, retry_after_s=body.get("retry_after_s"))
    if issubclass(cls, StreamFormatError):
        err = ReproError.__new__(cls)
        Exception.__init__(err, msg)
        err.offset = 0
        return err
    return cls(msg)
