"""Figs. 12-13 analog: DFT amplitude spectra of original vs reconstruction.

Checks the paper's three spectral claims:
  1. low-frequency components are preserved (MAG + ANG channels),
  2. random permutation boosts high-frequency amplitudes (std mode),
  3. duplication (no permutation) concentrates energy at multiples of the
     duplication count K (Prop. 6.3) while permutation spreads it (Cor 6.3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import idealem_paper as papercfg
from repro.core import amplitude_spectrum, spectral_band_error
from repro.data import synthetic

from .common import ang_channels, csv_row, mag_channels


def _dup_spike_score(x: np.ndarray, B: int) -> float:
    """Energy concentration at multiples of K for a duplicated stream."""
    spec = amplitude_spectrum(x)
    nb = len(x) // B
    idx = np.arange(1, len(spec) + 1)
    on = spec[(idx % nb) == 0]
    off = spec[(idx % nb) != 0]
    return float(np.median(on) / np.maximum(np.median(off), 1e-12))


def run(n=65_536):
    rows = []
    mag = mag_channels(n)["A6BUS1C1MAG"]
    ang = ang_channels(n)["A6BUS1C1ANG"]
    for name, x, codec in [
        ("A6BUS1C1MAG", mag, papercfg.mag_codec()),
        ("A6BUS1C1ANG", ang, papercfg.ang_codec()),
    ]:
        t0 = time.time()
        y = codec.decode(codec.encode(x))
        errs = spectral_band_error(x, y)
        rows.append(csv_row(
            f"fig12/{name}", (time.time() - t0) * 1e6 / len(x),
            ";".join(f"{k}={v:.4f}" for k, v in errs.items())))

    # Fig 13: EEG-like data; duplication vs permutation (Prop 6.3 / Cor 6.3)
    t0 = time.time()
    B = 64
    eeg = synthetic.eeg_like(n)
    block = eeg[:B]
    dup = np.tile(block, n // B)  # pure duplication stream
    perm_rng = np.random.default_rng(0)
    perm = np.concatenate(
        [block] + [perm_rng.permutation(block) for _ in range(n // B - 1)])
    s_dup = _dup_spike_score(dup, B)
    s_perm = _dup_spike_score(perm, B)
    rows.append(csv_row(
        "fig13/prop6.3_duplication_spikes", (time.time() - t0) * 1e6 / n,
        f"dup_spike_ratio={s_dup:.2f};perm_spike_ratio={s_perm:.2f};"
        f"confirmed={s_dup > 10 * s_perm}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
