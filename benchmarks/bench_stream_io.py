"""Host stream-I/O throughput: vectorized assemble/parse vs the seed
per-block Python loops, on >= 1e5 blocks.

The device encoder emits fixed-shape decisions; at production ingest rates
the host-side serialization is the next bottleneck (DESIGN.md Sec. 4).  This
measures both directions on a synthetic decision trace with a realistic
hit/miss/overwrite mix and reports the speedup of the numpy offset/scatter
implementation over the seed loop.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.stream import (
    StreamHeader,
    _assemble_stream_py,
    _parse_arrays,
    _parse_stream_py,
    assemble_stream,
    parse_stream,
)

from .common import csv_row


def _synth_decisions(nb: int, num_dict: int, p_hit: float, seed: int = 0):
    """FIFO-consistent random decision trace (no KS math needed)."""
    rng = np.random.default_rng(seed)
    hit_draw = rng.random(nb) < p_hit
    is_hit = np.zeros(nb, dtype=bool)
    slot = np.zeros(nb, dtype=np.int32)
    ovw = np.zeros(nb, dtype=bool)
    count = 0
    for i in range(nb):
        fill = min(count, num_dict)
        if hit_draw[i] and fill > 0:
            is_hit[i] = True
            slot[i] = rng.integers(0, fill)
        else:
            slot[i] = count % num_dict
            ovw[i] = count >= num_dict
            count += 1
    return is_hit, slot, ovw


def _time(fn, repeat=3):
    fn()  # warmup
    t0 = time.time()
    for _ in range(repeat):
        fn()
    return (time.time() - t0) / repeat


def run(nb: int = None, B: int = 16):
    if nb is None:  # --quick smoke shrinks the trace ~10x
        quick = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
        nb = 12_000 if quick else 120_000
    rows = []
    rng = np.random.default_rng(1)
    blocks = rng.normal(size=(nb, B))
    for num_dict, label in [(255, "D255"), (1, "D1")]:
        is_hit, slot, ovw = _synth_decisions(nb, num_dict, p_hit=0.9)
        header = StreamHeader(0, B, num_dict, 255, np.dtype(np.float64),
                              None, nb, np.zeros(0))
        args = (header, blocks, blocks, None, is_hit, slot, ovw)

        t_py = _time(lambda: _assemble_stream_py(*args), repeat=1)
        t_vec = _time(lambda: assemble_stream(*args))
        assert assemble_stream(*args) == _assemble_stream_py(*args)
        rows.append(csv_row(f"stream_io/assemble/{label}/py", t_py * 1e6,
                            f"blocks={nb}"))
        rows.append(csv_row(
            f"stream_io/assemble/{label}/vec", t_vec * 1e6,
            f"blocks={nb};speedup={t_py / t_vec:.1f}x"))

        blob = assemble_stream(*args)
        t_py = _time(lambda: _parse_stream_py(blob), repeat=1)
        t_arr = _time(lambda: _parse_arrays(blob))
        t_ev = _time(lambda: parse_stream(blob))
        rows.append(csv_row(f"stream_io/parse/{label}/py", t_py * 1e6,
                            f"bytes={len(blob)}"))
        # the decode path consumes the struct-of-arrays parser directly;
        # parse_stream adds the per-block event-dict compatibility layer
        rows.append(csv_row(
            f"stream_io/parse/{label}/vec_arrays", t_arr * 1e6,
            f"bytes={len(blob)};speedup={t_py / t_arr:.1f}x"))
        rows.append(csv_row(
            f"stream_io/parse/{label}/vec_events", t_ev * 1e6,
            f"bytes={len(blob)};speedup={t_py / t_ev:.1f}x"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
