"""Shared benchmark utilities: the synthetic uPMU channel set standing in for
the paper's LBNL data (Table I uses 4 MAG + 4 ANG channels from two uPMUs)."""
from __future__ import annotations


from repro.data import synthetic

# paper channels: A6BUS1/BANK514 x C1/L1 x MAG/ANG.  ~1 GB each in the paper;
# we scale to N samples per channel (CPU harness).
N_SAMPLES = 262_144


def mag_channels(n: int = N_SAMPLES):
    return {
        "A6BUS1C1MAG": synthetic.pmu_magnitude(n, level=120.0, noise=0.4,
                                               tap_step=2.0, seed=1),
        "A6BUS1L1MAG": synthetic.pmu_magnitude(n, level=7200.0, noise=1.5,
                                               tap_step=45.0, seed=2),
        "BANK514C1MAG": synthetic.pmu_magnitude(n, level=95.0, noise=1.1,
                                                n_shifts=8, tap_step=3.0, seed=3),
        "BANK514L1MAG": synthetic.pmu_magnitude(n, level=7180.0, noise=0.9,
                                                tap_step=44.9, seed=4),
    }


def ang_channels(n: int = N_SAMPLES):
    return {
        "A6BUS1C1ANG": synthetic.pmu_angle(n, slope=0.72, noise=0.04, seed=5),
        "A6BUS1L1ANG": synthetic.pmu_angle(n, slope=0.31, noise=0.02, seed=6),
        "BANK514C1ANG": synthetic.pmu_angle(n, slope=0.72, noise=0.06, seed=7),
        "BANK514L1ANG": synthetic.pmu_angle(n, slope=0.29, noise=0.03, seed=8),
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
