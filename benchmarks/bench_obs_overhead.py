"""Telemetry overhead: metrics-on vs metrics-off encode + decode.

The obs layer (DESIGN.md Sec. 12) instruments per *flush* and per
*dispatch*, never per sample, so its cost must vanish against the codec
work it measures.  This bench enforces the 3% acceptance bar (ISSUE 8)
with a *measured cost model* rather than a raw wall-clock A/B: on a
shared CI box, back-to-back runs of a 25-170 ms workload jitter by
+/-10%, which would make a 3% wall-clock assertion a coin flip.  Instead:

1. Count every obs write (counter inc, gauge move, histogram observe,
   span, event) one workload call performs, by temporarily wrapping the
   instrument methods.  Counts are exact and deterministic.
2. Measure the per-op cost of each write kind in a tight loop (100k+
   iterations amortize scheduler noise to ~1%), instruments enabled.
3. overhead fraction = sum(count * cost) / workload floor, asserted
   <= 3% for both encode and decode.  A chatty metric added to a hot
   loop inflates the counts; an accidentally expensive write inflates
   the per-op cost -- both realistic regressions fail deterministically.

The classic interleaved on/off wall-clock ratio is still measured and
reported (it is the number an operator would see), but only asserted
against a loose sanity ceiling that machine noise cannot trip.

Rows: ``obs/overhead/encode`` / ``obs/overhead/decode`` report the
metrics-ON time with the on/off ratio and modeled overhead;
``obs/overhead/summary`` is a zero-time derived row pinning both modeled
fractions (zero-time rows are excluded from the perf gate's timing
comparison).
"""
from __future__ import annotations

import gc
import os
import time

import numpy as np

from repro import obs
from repro.core import IdealemCodec
from repro.store import Container, decode_ranges, pack

from .common import csv_row

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
B = 32
NB = 1_500 if QUICK else 6_000          # blocks per arm
FEED = 16 * B                           # samples per session feed
N_RANGES = 64
RANGE_BLOCKS = 64 if QUICK else 256     # fat enough that decode dominates
REPEAT = 3                              # timed calls per interleave round
ROUNDS = 5 if QUICK else 8              # on/off alternations
BAR = 0.03                              # the 3% acceptance ceiling (modeled)
SANITY = 1.25                           # wall-clock on/off ratio ceiling


def _signal(nb: int) -> np.ndarray:
    rng = np.random.default_rng(7)
    return rng.normal(0, 1, size=nb * B)


def _encode_once(x: np.ndarray) -> bytes:
    codec = IdealemCodec(mode="std", block_size=B, num_dict=32,
                         matcher="reference")
    sess = codec.session()
    blob = b""
    for i in range(0, len(x), FEED):
        blob += sess.feed(x[i:i + FEED])
    return blob + sess.finish()


def _decode_once(store: Container, requests) -> None:
    decode_ranges(store, requests, backend="numpy")


def _count_ops(fn) -> dict:
    """Exact invocation counts of every obs write kind during one call."""
    from repro.obs import metrics as _m
    from repro.obs import trace as _t

    counts = {"inc": 0, "observe": 0, "gauge": 0, "span": 0, "event": 0}
    patched = []

    def patch(cls, attr, key):
        orig = getattr(cls, attr)

        def wrapper(self, *args, **kwargs):
            counts[key] += 1
            return orig(self, *args, **kwargs)

        setattr(cls, attr, wrapper)
        patched.append((cls, attr, orig))

    patch(_m.Counter, "inc", "inc")
    patch(_m.Histogram, "observe", "observe")
    patch(_m.Gauge, "set", "gauge")
    patch(_m.Gauge, "inc", "gauge")  # dec() routes through inc()
    patch(_t.SpanTracer, "span", "span")
    patch(_t.SpanTracer, "event", "event")
    try:
        fn()
    finally:
        for cls, attr, orig in patched:
            setattr(cls, attr, orig)
    return counts


def _op_costs() -> dict:
    """Seconds per obs write, measured enabled on scratch instruments.

    Tight loops over 20k-200k ops amortize per-sample noise away -- this
    is the stable half of the cost model."""
    reg = obs.MetricsRegistry()
    trc = obs.SpanTracer(capacity=256)
    c = reg.counter("bench_probe_total", "cost probe")
    g = reg.gauge("bench_probe_gauge", "cost probe")
    h = reg.histogram("bench_probe_seconds", "cost probe")

    def timed(n, op):
        t0 = time.perf_counter()
        for _ in range(n):
            op()
        return (time.perf_counter() - t0) / n

    def one_span():
        with trc.span("bench.probe"):
            pass

    return {
        "inc": timed(200_000, c.inc),
        "gauge": timed(200_000, lambda: g.set(1.0)),
        "observe": timed(100_000, lambda: h.observe(1e-3)),
        "span": timed(20_000, one_span),
        "event": timed(50_000, lambda: trc.event("bench.probe")),
    }


def _timed_pair(fn, repeat: int = REPEAT, rounds: int = ROUNDS):
    """(metrics-on seconds, metrics-off seconds), wall clock.

    Tightly interleaved on/off rounds with a global min per arm; the arm
    order flips every round so within-round warmup cancels, and the
    collector is paused across the timed region (a GC pause is several
    ms against a tens-of-ms workload, far louder than the instruments
    under test).  Still only good to ~10% on a noisy box -- hence the
    cost model above for the 3% assertion."""
    tracer = obs.tracer()
    fn()  # warmup once: jit compile, page-in, allocator steady state
    t_on = t_off = float("inf")
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for r in range(rounds):
            order = (True, False) if r % 2 == 0 else (False, True)
            for enabled in order:
                prev = obs.set_enabled(enabled)
                prev_tr, tracer.enabled = tracer.enabled, enabled
                try:
                    for _ in range(repeat):
                        t0 = time.perf_counter()
                        fn()
                        dt = time.perf_counter() - t0
                        if enabled:
                            t_on = min(t_on, dt)
                        else:
                            t_off = min(t_off, dt)
                finally:
                    obs.set_enabled(prev)
                    tracer.enabled = prev_tr
            gc.collect()  # pay collection between rounds, not mid-sample
    finally:
        if gc_was_enabled:
            gc.enable()
    return t_on, t_off


def run():
    x = _signal(NB)
    enc_on, enc_off = _timed_pair(lambda: _encode_once(x))
    enc_ops = _count_ops(lambda: _encode_once(x))

    codec = IdealemCodec(mode="std", block_size=B, num_dict=32,
                         matcher="reference")
    store = Container(pack(codec.encode(x)))
    total = store.total_blocks(0)
    rng = np.random.default_rng(3)
    starts = rng.integers(0, total - RANGE_BLOCKS, size=N_RANGES)
    requests = [(0, int(s), int(s) + RANGE_BLOCKS) for s in starts]
    dec_on, dec_off = _timed_pair(lambda: _decode_once(store, requests))
    dec_ops = _count_ops(lambda: _decode_once(store, requests))

    costs = _op_costs()
    enc_cost = sum(enc_ops[k] * costs[k] for k in costs)
    dec_cost = sum(dec_ops[k] * costs[k] for k in costs)
    enc_frac = enc_cost / enc_off
    dec_frac = dec_cost / dec_off
    enc_ratio = enc_on / enc_off
    dec_ratio = dec_on / dec_off
    within = enc_frac <= BAR and dec_frac <= BAR
    enc_n = sum(enc_ops.values())
    dec_n = sum(dec_ops.values())
    rows = [
        csv_row("obs/overhead/encode", enc_on * 1e6,
                f"blocks={NB};obs_ops={enc_n};modeled_pct={enc_frac * 100:.3f};"
                f"ratio_vs_off={enc_ratio:.4f}"),
        csv_row("obs/overhead/decode", dec_on * 1e6,
                f"requests={N_RANGES};obs_ops={dec_n};"
                f"modeled_pct={dec_frac * 100:.3f};"
                f"ratio_vs_off={dec_ratio:.4f}"),
        csv_row("obs/overhead/summary", 0.0,
                f"encode_pct={enc_frac * 100:.3f};dec_pct={dec_frac * 100:.3f};"
                f"within_3pct={int(within)}"),
    ]
    if not within:
        raise AssertionError(
            f"telemetry overhead above the 3% bar: encode "
            f"{enc_frac * 100:.3f}%, decode {dec_frac * 100:.3f}% "
            f"(modeled: obs op counts x measured per-op cost)")
    if enc_ratio > SANITY or dec_ratio > SANITY:
        raise AssertionError(
            f"metrics-on wall clock implausibly above metrics-off: encode "
            f"{enc_ratio:.4f}x, decode {dec_ratio:.4f}x (sanity ceiling "
            f"{SANITY}x)")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
