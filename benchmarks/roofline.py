"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md Sec. Roofline).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_chip / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_chip / HBM_bw            [s]
  collective term = wire_bytes_per_chip / ICI_link_bw      [s]

(The dry-run artifacts are per-chip: the analyzed module is the SPMD-
partitioned per-device program; dividing totals by chips is equivalent.)
Hardware: TPU v5e-class -- 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N_active for MoE; the
ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def cell_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["kind"] in ("train", "prefill") else 1)
    factor = 6 if rec["kind"] == "train" else 2
    model_flops = factor * rec["n_active"] * tokens / chips
    t_c = rec["flops_per_chip"] / PEAK_FLOPS
    t_m = rec["bytes_per_chip"] / HBM_BW
    t_x = rec["collective_wire_bytes_per_chip"] / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    useful = model_flops / rec["flops_per_chip"] if rec["flops_per_chip"] else 0
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_chip": model_flops,
        "hlo_flops_per_chip": rec["flops_per_chip"],
        "useful_flop_ratio": useful,
        # fraction of roofline: time the chip would spend at peak on useful
        # work over the critical-path bound (no-overlap worst case)
        "roofline_frac": (model_flops / PEAK_FLOPS) / bound if bound else 0.0,
    }


def load(art_dir: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(f))
        t = cell_terms(rec)
        if t:
            out.append(t)
    return out


def table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16") -> str:
    rows = [t for t in load(art_dir) if t["mesh"] == mesh]
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr]
    for t in rows:
        lines.append(
            f"{t['arch']:24s} {t['shape']:12s} {t['compute_s']:10.4f} "
            f"{t['memory_s']:10.4f} {t['collective_s']:10.4f} "
            f"{t['dominant']:>10s} {t['useful_flop_ratio']:7.3f} "
            f"{100 * t['roofline_frac']:7.2f}")
    return "\n".join(lines)


def run():
    from .common import csv_row
    rows = []
    for label, d in [("baseline", "artifacts/dryrun"),
                     ("optimized", "artifacts/dryrun_opt")]:
        for t in load(d):
            rows.append(csv_row(
                f"roofline[{label}]/{t['arch']}/{t['shape']}/{t['mesh']}", 0.0,
                f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
                f"collective_s={t['collective_s']:.4f};dom={t['dominant']};"
                f"useful={t['useful_flop_ratio']:.3f};"
                f"roofline_frac={t['roofline_frac']:.4f}"))
    return rows


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(table(d, mesh))
