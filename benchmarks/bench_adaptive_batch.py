"""Batched mixed-mode adaptive encode vs the per-channel loop (ISSUE 9,
DESIGN.md Sec. 13).

An adaptive session whose selectors have diverged holds per-channel codec
variants: different payload widths (std vs delta), different quantized
``d_crit`` thresholds.  The PR 7 path dispatched one device scan per
channel per feed; the batched path masks all channels into ONE padded
mixed-mode scan.  This bench builds heterogeneous C-channel sessions
(half std at width B, half switched to delta at width B-1 with a tighter
threshold), asserts decision identity between the two paths, then times
``_decide_adaptive`` on both:

  adaptive_batch/loop/C{C}            us per (channel x block), loop path
  adaptive_batch/batched/C{C}         us per (channel x block), one scan
  adaptive_batch/batched_vs_loop/C{C} dimensionless ratio row (x1000)

``batched_vs_loop`` at C=64 is the acceptance gate: the batched scan must
hold a >= 2x encode-throughput win over the per-channel loop.  The bench
fails below the bar, and the ratio row is pinned in ``BENCH_quick.json``
like ``encode_fused/fused_vs_ops``.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core import IdealemCodec
from repro.core.session import _ADAPTIVE_LOOP_ENV

from .common import csv_row

# already quick-sized: the same two cohorts run in --quick and full mode
CONFIGS = [8, 64]            # channel counts (heterogeneous cohorts)
# blocks per channel per feed: a serving-quantum-sized feed, where the
# loop's per-channel dispatch overhead is the dominant cost being removed
NB = 16
BLOCK = 16
NUM_DICT = 32
MIN_SPEEDUP = 2.0            # ISSUE 9 acceptance bar at C=64


def _session(C: int, loop: bool):
    """An adaptive session with half its channels switched to delta mode
    at a tightened threshold (what a diverged selector fleet looks like),
    locked onto the batched or loop decide path."""
    codec = IdealemCodec(mode="std", block_size=BLOCK, num_dict=NUM_DICT,
                         backend="jax", adaptive=True)
    s = codec.session(channels=C)
    delta = dataclasses.replace(codec, mode="delta")
    for ci in range(1, C, 2):
        s._codecs[ci] = delta
        s._d_crit[ci] = float(codec.d_crit) * 0.75
    prev = os.environ.pop(_ADAPTIVE_LOOP_ENV, None)
    try:
        if loop:
            os.environ[_ADAPTIVE_LOOP_ENV] = "1"
        s._decide_adaptive(_payloads(C, seed=999))  # locks the path + jits
    finally:
        os.environ.pop(_ADAPTIVE_LOOP_ENV, None)
        if prev is not None:
            os.environ[_ADAPTIVE_LOOP_ENV] = prev
    assert (s._mixed is None) == loop
    return s


def _payloads(C: int, seed: int = 0):
    """Ragged per-channel payload list: mixture traffic so hits, misses
    and FIFO overwrites all occur; odd (delta) channels are one narrower."""
    rng = np.random.default_rng(seed)
    out = []
    for ci in range(C):
        n = BLOCK - (ci % 2)
        levels = rng.normal(0, 2, size=4)[rng.integers(0, 4, size=NB)]
        out.append(rng.normal(0, 1, size=(NB, n)) + levels[:, None])
    return out


def _time(fn, repeat=3):
    fn()  # warmup (jit compile already locked in _session)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()  # _decide_adaptive returns host arrays: already synced
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    worst_at_max_c = None
    for C in CONFIGS:
        payloads = _payloads(C, seed=C)
        # decision identity between the paths before any timing
        ref = _session(C, loop=True)._decide_adaptive(payloads)
        got = _session(C, loop=False)._decide_adaptive(payloads)
        for (rh, rs, ro), (gh, gs, go) in zip(ref, got):
            np.testing.assert_array_equal(rh, gh)
            np.testing.assert_array_equal(rs, gs)
            np.testing.assert_array_equal(ro, go)

        s_loop = _session(C, loop=True)
        s_batch = _session(C, loop=False)
        t_loop = _time(lambda: s_loop._decide_adaptive(payloads))
        t_batch = _time(lambda: s_batch._decide_adaptive(payloads))
        per = 1e6 / (C * NB)
        rows.append(csv_row(
            f"adaptive_batch/loop/C{C}", t_loop * per,
            f"nb={NB};B={BLOCK};D={NUM_DICT};dispatches_per_feed={C}"))
        rows.append(csv_row(
            f"adaptive_batch/batched/C{C}", t_batch * per,
            f"nb={NB};B={BLOCK};D={NUM_DICT};dispatches_per_feed=1"))
        speedup = t_loop / t_batch
        rows.append(csv_row(
            f"adaptive_batch/batched_vs_loop/C{C}",
            # dimensionless ratio row (x1000): machine-speed independent,
            # so the committed baseline pins the *speedup*, not a time
            1000.0 * t_batch / t_loop,
            f"speedup={speedup:.2f}x;channels={C}"))
        if C == max(CONFIGS):
            worst_at_max_c = speedup

    if worst_at_max_c is not None and worst_at_max_c < MIN_SPEEDUP:
        raise AssertionError(
            f"batched adaptive encode speedup {worst_at_max_c:.2f}x < "
            f"required {MIN_SPEEDUP}x over the per-channel loop at "
            f"C={max(CONFIGS)}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
