"""Fig. 15 analog: encoder wall time -- min/max check vs KS-test-only.

The paper's claim: the min/max gate filters most dictionary entries before
the (expensive) KS test, cutting encode time several-fold; tuning r is also
cheaper than tuning alpha.  We measure the jitted JAX encoder (batch of
channels) and the sequential numpy reference.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IdealemCodec

from .common import csv_row, mag_channels


def _time_encode(codec: IdealemCodec, x: np.ndarray, repeat=3) -> float:
    codec.encode(x)  # warmup/compile
    t0 = time.time()
    for _ in range(repeat):
        codec.encode(x)
    return (time.time() - t0) / repeat


def run(n=65_536):
    rows = []
    x = mag_channels(n)["A6BUS1C1MAG"]
    for backend in ["numpy", "jax"]:
        for label, kw in [
            ("minmax+ks(r=0.3)", dict(use_minmax=True, rel_tol=0.3)),
            ("ks_only(alpha=0.02)", dict(use_minmax=False, alpha=0.02)),
            ("ks_only(alpha=0.2)", dict(use_minmax=False, alpha=0.2)),
        ]:
            c = IdealemCodec(mode="std", block_size=32, num_dict=255,
                             alpha=kw.pop("alpha", 0.01), backend=backend, **kw)
            dt = _time_encode(c, x)
            blob = c.encode(x)
            rows.append(csv_row(
                f"fig15/{backend}/{label}", dt * 1e6 / (n // 32),
                f"encode_s={dt:.3f};ratio={c.compression_ratio(x, blob):.1f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
