"""Table I analog: compression ratios, IDEALEM vs ZFP/ISABELA/SZ-like.

Paper settings: IDEALEM D=255 alpha=0.01; MAG -> std mode B=32;
ANG -> residual mode B=112 (delta also reported).  Upper bounds: 256 (std),
99.56 (residual).
"""
from __future__ import annotations

import time


from repro.baselines import IsabelaLikeCodec, SzLikeCodec, ZfpLikeCodec
from repro.configs import idealem_paper as papercfg

from .common import ang_channels, csv_row, mag_channels


def run(n=None):
    rows = []
    chans = {}
    chans.update(mag_channels(*([n] if n else [])))
    chans.update(ang_channels(*([n] if n else [])))
    for name, x in chans.items():
        is_ang = name.endswith("ANG")
        t0 = time.time()
        if is_ang:
            codec = papercfg.ang_codec()
            blob = codec.encode(x)
            ratios = {"idealem": codec.compression_ratio(x, blob)}
            dcodec = papercfg.ang_codec(delta=True)
            ratios["idealem_delta"] = dcodec.compression_ratio(x, dcodec.encode(x))
        else:
            codec = papercfg.mag_codec()
            blob = codec.encode(x)
            ratios = {"idealem": codec.compression_ratio(x, blob)}
        t_idealem = time.time() - t0

        ratios["zfp_like"] = ZfpLikeCodec(tolerance=(0.5 if is_ang else 0.08)) \
            .compression_ratio(x, ZfpLikeCodec(tolerance=(0.5 if is_ang else 0.08)).encode(x))
        ratios["sz_like"] = SzLikeCodec(rel_bound_ratio=1e-3) \
            .compression_ratio(x, SzLikeCodec(rel_bound_ratio=1e-3).encode(x))
        isa = IsabelaLikeCodec(window=512, num_coeff=15, error_rate=5.0)
        ratios["isabela_like"] = isa.compression_ratio(x, isa.encode(x))

        derived = ";".join(f"{k}={v:.2f}" for k, v in ratios.items())
        rows.append(csv_row(f"table1/{name}", t_idealem * 1e6 / max(len(x), 1),
                            derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
