"""Fused single-dispatch encode step vs the composed matcher pipeline
(DESIGN.md Sec. 10).

Per (D, n) config the same mixture traffic is encoded through the scan
with three matchers:

  encode_fused/scan/reference -- jnp oracle matcher + jnp step ops
  encode_fused/scan/ops       -- composed pallas ``dict_match`` + jnp step
  encode_fused/scan/fused[t]  -- ``encode_step_pallas``, best swept tile_d

``fused_vs_ops`` is the tentpole gate: the fused kernel must hold a
>=1.3x encode-throughput win over the composed dispatches (ISSUE 6
acceptance).  The bench *fails* below the bar -- a silent slowdown must
not pass CI -- and the row is also pinned in the committed
``BENCH_quick.json`` baseline.  Decisions are asserted identical across
matchers before any timing.

``roofline`` rows model the fused dispatch against the analytic machine
model of ``benchmarks/roofline.py`` (TPU v5e-class constants): bytes =
one streamed pass over the dictionary + carry writeback, flops = the
(D, n, n) rank comparisons, reported as compute/memory terms and the
arithmetic-intensity crossover.  The composed pipeline pays the
dictionary traffic twice (matcher read + step writeback) and
materializes the (D,) ks/mm intermediates; the fused row reports the
modeled traffic ratio.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.encoder import encode_decisions, init_state

from .common import csv_row
from .roofline import HBM_BW, PEAK_FLOPS

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
CONFIGS = [(64, 32, 192)] if QUICK else [(64, 32, 512), (255, 32, 512)]
TILE_SWEEP = (8, 32, 128)
MIN_SPEEDUP = 1.3  # ISSUE 6 acceptance bar, enforced below
ITEM = 4  # f32 state


def _time(fn, repeat=3):
    fn()  # warmup (includes jit compile)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _traffic(nb, n, seed=0):
    rng = np.random.default_rng(seed)
    parts = [rng.normal(m, s, size=(nb // 3, n))
             for m, s in [(0, 1), (5, 0.5), (0, 1)]]
    parts.append(rng.normal(0, 1, size=(nb - 3 * (nb // 3), n)))
    return jnp.asarray(np.concatenate(parts), jnp.float32)


def _roofline_rows(num_dict, n, hit_rate):
    """Analytic model of one fused step vs the composed pipeline."""
    d_bytes = num_dict * n * ITEM
    # fused: stream the dictionary once, write the carry once, plus the
    # candidate and the (8,) decision block (negligible)
    fused_bytes = 2 * d_bytes + n * ITEM
    # composed: matcher reads the dictionary, the step's FIFO write-back
    # rewrites the full carry via dynamic_update_slice (read+write), and
    # the (D,) ks/mm/ok intermediates round-trip through HBM between ops
    composed_bytes = 3 * d_bytes + n * ITEM + 3 * num_dict * ITEM
    # rank work: three (D, n, n) comparison/sum passes, ~2 flops each;
    # the gate skips it for misses-with-cold-gate, modeled via hit_rate
    flops = 6.0 * num_dict * n * n
    t_c = flops / PEAK_FLOPS
    t_m = fused_bytes / HBM_BW
    intensity = flops / fused_bytes
    ridge = PEAK_FLOPS / HBM_BW
    # us_per_call is the modeled per-step bound (machine-independent
    # constant, so the gate sees ratio 1.0; the terms live in derived)
    return [csv_row(
        f"encode_fused/roofline/D{num_dict}/n{n}", max(t_c, t_m) * 1e6,
        f"compute_s={t_c:.3e};memory_s={t_m:.3e};"
        f"intensity={intensity:.1f};ridge={ridge:.1f};"
        f"dom={'compute' if intensity > ridge else 'memory'};"
        f"traffic_vs_composed={fused_bytes / composed_bytes:.2f}x;"
        f"hit_rate={hit_rate:.2f}")]


def run():
    rows = []
    worst = float("inf")
    for num_dict, n, nb in CONFIGS:
        blocks = _traffic(nb, n)
        kw = dict(num_dict=num_dict, d_crit=0.35, rel_tol=0.5)
        state0 = init_state(num_dict, n)

        def scan(matcher):
            out, _ = encode_decisions(blocks, matcher=matcher, state=state0,
                                      **kw)
            return out

        # decision identity across every timed path before timing
        ref = scan("reference")
        hit_rate = float(np.asarray(ref[0]).mean())
        for m in ["ops"] + [("fused", t) for t in TILE_SWEEP]:
            got = scan(m)
            for a, b in zip(ref, got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        blk_s = lambda t: nb / t  # noqa: E731  encode throughput
        t_ref = _time(lambda: scan("reference"))
        t_ops = _time(lambda: scan("ops"))
        rows.append(csv_row(f"encode_fused/scan/reference/D{num_dict}",
                            t_ref * 1e6 / nb,
                            f"blocks={nb};n={n};blocks_per_s={blk_s(t_ref):.0f}"))
        rows.append(csv_row(f"encode_fused/scan/ops/D{num_dict}",
                            t_ops * 1e6 / nb,
                            f"blocks={nb};n={n};blocks_per_s={blk_s(t_ops):.0f}"))

        fused = {t: _time(lambda t=t: scan(("fused", t))) for t in TILE_SWEEP}
        best_t = min(fused, key=fused.get)
        for t in TILE_SWEEP:
            rows.append(csv_row(
                f"encode_fused/scan/fused{t}/D{num_dict}",
                fused[t] * 1e6 / nb,
                f"blocks={nb};n={n};blocks_per_s={blk_s(fused[t]):.0f}"))
        speedup = t_ops / fused[best_t]
        worst = min(worst, speedup)
        rows.append(csv_row(
            f"encode_fused/fused_vs_ops/D{num_dict}",
            # dimensionless ratio row (x1000): machine-speed independent,
            # so the committed baseline pins the *speedup*, not a time
            1000.0 * fused[best_t] / t_ops,
            f"best_tile={best_t};speedup={speedup:.2f}x"
            f";vs_reference={t_ref / fused[best_t]:.2f}x"
            f";hit_rate={hit_rate:.2f}"))
        rows.extend(_roofline_rows(num_dict, n, hit_rate))

    if worst < MIN_SPEEDUP:  # acceptance bar: fail loudly, never silently
        raise AssertionError(
            f"fused encode speedup {worst:.2f}x < required "
            f"{MIN_SPEEDUP}x over composed ops dispatches")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
