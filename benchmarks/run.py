"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline rows are emitted
when dry-run artifacts exist (run scripts/run_dryrun_sweep.sh first).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_fig3_pvalue, bench_fig12_spectral,
                   bench_fig14_tradeoff, bench_fig15_speed, bench_gradcomp,
                   bench_limits, bench_shard_encode, bench_stream_io,
                   bench_table1_ratio, bench_table2_quality, roofline)
    modules = [
        ("table1", bench_table1_ratio),
        ("table2", bench_table2_quality),
        ("fig3", bench_fig3_pvalue),
        ("fig12", bench_fig12_spectral),
        ("fig14", bench_fig14_tradeoff),
        ("fig15", bench_fig15_speed),
        ("limits", bench_limits),
        ("gradcomp", bench_gradcomp),
        ("stream_io", bench_stream_io),
        ("shard_encode", bench_shard_encode),
        ("roofline", roofline),
    ]
    failed = []
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
