"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline rows are emitted
when dry-run artifacts exist (run scripts/run_dryrun_sweep.sh first).

``--quick`` (or ``REPRO_BENCH_QUICK=1``) is the CI smoke profile: modules
that expose a quick knob shrink their workloads, and only the fast,
dependency-light host/codec benches run.

``--json PATH`` additionally writes the rows as a machine-readable
document -- the input of the CI perf gate (``scripts/bench_gate.py``):

    {"version": 1, "quick": bool,
     "results": {name: {"us_per_call": float, "derived": str}},
     "failed": [module, ...],
     "metrics_snapshot": {...}}

``metrics_snapshot`` is the full ``repro.obs`` JSON export (metric
families + recent spans) taken after all benches ran in this process --
the nightly job uploads it as an artifact, so codec health counters
(hit rates, gate rejections, backend choices, stage latencies) ride
along with every full bench run.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import traceback

QUICK_MODULES = ("stream_io", "store_decode", "decode_backends",
                 "encode_fused", "adaptive_batch", "frontier",
                 "obs_overhead")  # fast host/codec smoke set

RESULTS_VERSION = 1


def rows_to_results(rows) -> dict:
    """Parse ``name,us_per_call,derived`` rows into the JSON results map.
    Malformed rows are skipped with a warning instead of failing the run."""
    results = {}
    for row in rows:
        try:
            name, us, derived = row.split(",", 2)
            results[name] = {"us_per_call": float(us), "derived": derived}
        except ValueError:
            print(f"unparseable bench row skipped: {row!r}", file=sys.stderr)
    return results


def carry_tolerances(path: str, doc: dict) -> dict:
    """Refreshing a committed baseline in place must not drop its
    hand-embedded per-bench ``tolerances`` map (the gate's noise
    allowances): carry the existing file's over when the target already
    has one."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            old = json.load(f)
        tol = old.get("tolerances")
        if isinstance(tol, dict) and tol:
            doc["tolerances"] = tol
    except (OSError, ValueError):
        pass
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small workloads, host/codec benches only")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as machine-readable JSON "
                         "(the perf-gate input)")
    args = ap.parse_args(argv)
    # the env var alone activates quick too, as the module docstring says
    if bool(int(os.environ.get("REPRO_BENCH_QUICK", "0") or "0")):
        args.quick = True
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    modules = [
        ("table1", "bench_table1_ratio"),
        ("table2", "bench_table2_quality"),
        ("fig3", "bench_fig3_pvalue"),
        ("fig12", "bench_fig12_spectral"),
        ("fig14", "bench_fig14_tradeoff"),
        ("fig15", "bench_fig15_speed"),
        ("limits", "bench_limits"),
        ("gradcomp", "bench_gradcomp"),
        ("stream_io", "bench_stream_io"),
        ("shard_encode", "bench_shard_encode"),
        ("store_decode", "bench_store_decode"),
        ("decode_backends", "bench_decode_backends"),
        ("encode_fused", "bench_encode_fused"),
        ("adaptive_batch", "bench_adaptive_batch"),
        ("frontier", "bench_frontier"),
        ("obs_overhead", "bench_obs_overhead"),
        # full profile only: socket latency is PR-runner noise, and the
        # quick baseline has no row for it (perf-gate contract)
        ("frontend", "bench_frontend"),
        ("roofline", "roofline"),
    ]
    if args.quick:
        modules = [(n, m) for n, m in modules if n in QUICK_MODULES]
    failed = []
    all_rows = []
    for name, modname in modules:
        try:
            # imported per bench so a missing optional dep (e.g. zstandard
            # for the baseline codecs) only fails its own rows
            mod = importlib.import_module(f"benchmarks.{modname}")
            for row in mod.run():
                all_rows.append(row)
                print(row, flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        doc = carry_tolerances(args.json, {
            "version": RESULTS_VERSION, "quick": args.quick,
            "results": rows_to_results(all_rows), "failed": failed})
        try:  # codec health from this process's bench traffic (obs layer)
            from repro import obs
            doc["metrics_snapshot"] = obs.to_json()
        except Exception:
            traceback.print_exc()
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {len(doc['results'])} results -> {args.json}",
              file=sys.stderr)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
