"""Fig. 14 analog: reconstruction quality vs compression ratio when tuning
alpha (KS threshold) vs r (min/max relative tolerance)."""
from __future__ import annotations

import time


from repro.core import IdealemCodec, quality_measures

from .common import csv_row, mag_channels


def run(n=65_536):
    rows = []
    x = mag_channels(n)["BANK514L1MAG"]
    base = quality_measures(x)
    # alpha sweep at fixed r=0.5 (paper: alpha = 0.02..0.2)
    for alpha in [0.02, 0.05, 0.1, 0.2]:
        c = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=alpha,
                         rel_tol=0.5, backend="numpy")
        t0 = time.time()
        blob = c.encode(x)
        y = c.decode(blob)
        m = quality_measures(y)
        rows.append(csv_row(
            f"fig14/alpha={alpha}", (time.time() - t0) * 1e6 / n,
            f"ratio={c.compression_ratio(x, blob):.1f};"
            f"m1={m['m1_num_peaks']:.0f};m5={m['m5_num_big_jumps']:.0f};"
            f"m1_orig={base['m1_num_peaks']:.0f};m5_orig={base['m5_num_big_jumps']:.0f}"))
    # r sweep at fixed alpha=0.01 (paper: r = 0.1..0.4)
    for r in [0.1, 0.2, 0.3, 0.4]:
        c = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                         rel_tol=r, backend="numpy")
        t0 = time.time()
        blob = c.encode(x)
        y = c.decode(blob)
        m = quality_measures(y)
        rows.append(csv_row(
            f"fig14/r={r}", (time.time() - t0) * 1e6 / n,
            f"ratio={c.compression_ratio(x, blob):.1f};"
            f"m1={m['m1_num_peaks']:.0f};m5={m['m5_num_big_jumps']:.0f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
