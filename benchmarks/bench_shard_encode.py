"""Single-device vs sharded encode throughput (DESIGN.md Sec. 6).

Times the batched (C, nb, n) resumable encode scan on one device against
the same scan with the channel axis shard_map'd over N devices.  Devices
are forced host devices when no accelerator is attached, so the inner
measurement runs in a subprocess that owns XLA_FLAGS (same pattern as the
dry-run); on a real TPU/GPU slice the spawn is unnecessary but harmless.

Rows: ``shard_encode/<cell>/{single|sharded}`` with blocks/s and speedup.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from .common import csv_row

_DEVICES = int(os.environ.get("REPRO_BENCH_SHARD_DEVICES", "4"))


def _time_encode(fn, state, repeat: int = 5) -> float:
    import jax

    out, st = fn(state)  # warmup + compile
    jax.block_until_ready(st)
    t0 = time.time()
    for _ in range(repeat):
        out, st = fn(st)
    jax.block_until_ready(st)
    return (time.time() - t0) / repeat


def _inner(channels: int, nb: int, n: int) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.encoder import (encode_decisions_batched,
                                    encode_decisions_sharded, init_state)
    from repro.launch.encode_plan import make_encode_plan, shard_state

    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.normal(size=(channels, nb, n)), jnp.float32)
    kw = dict(num_dict=255, d_crit=0.4, rel_tol=0.5)

    t_single = _time_encode(
        lambda st: encode_decisions_batched(blocks, state=st, **kw),
        init_state(255, n, channels=channels))

    plan = make_encode_plan(channels, block_size=n)
    st = shard_state(plan, init_state(255, n, channels=plan.padded_channels))
    t_sharded = _time_encode(
        lambda st: encode_decisions_sharded(
            blocks, mesh=plan.mesh, axis_name=plan.axis_name, state=st, **kw),
        st)

    print(json.dumps({
        "devices": jax.device_count(), "channels": channels, "nb": nb,
        "n": n, "t_single": t_single, "t_sharded": t_sharded,
    }))


def run(channels: int = 8, nb: int = 192, n: int = 32):
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEVICES}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard_encode", "--inner",
         str(channels), str(nb), str(n)],
        capture_output=True, text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stdout[-2000:] + out.stderr[-2000:])
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    total_blocks = rec["channels"] * rec["nb"]
    cell = f"C{rec['channels']}xnb{rec['nb']}xn{rec['n']}"
    rows = []
    for kind, t in (("single", rec["t_single"]), ("sharded", rec["t_sharded"])):
        extra = (f";devices={rec['devices']}"
                 f";speedup={rec['t_single'] / rec['t_sharded']:.2f}x"
                 if kind == "sharded" else ";devices=1")
        rows.append(csv_row(
            f"shard_encode/{cell}/{kind}", t * 1e6,
            f"blocks_per_s={total_blocks / t:.0f}{extra}"))
    return rows


if __name__ == "__main__":
    if "--inner" in sys.argv:
        i = sys.argv.index("--inner")
        _inner(int(sys.argv[i + 1]), int(sys.argv[i + 2]),
               int(sys.argv[i + 3]))
    else:
        for row in run():
            print(row)
