"""Beyond-paper: IDEALEM gradient compression -- wire bytes saved vs
convergence on a small LM (cross-pod all-reduce is the target link)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import synthetic
from repro.train import init_train_state, make_train_step

from .common import csv_row


def run(steps=15):
    rows = []
    cfg = get_config("granite_3_8b", smoke=True)
    batches = list(synthetic.token_stream(steps, 8, 64, cfg.vocab_size, seed=0))
    for label, use_gc in [("baseline", False), ("idealem_gradcomp", True)]:
        state = init_train_state(jax.random.key(0), cfg, use_gradcomp=use_gc)
        step = jax.jit(make_train_step(cfg, lr=1e-3, microbatches=1,
                                       use_gradcomp=use_gc))
        t0 = time.time()
        losses, wire = [], []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["loss"]))
            if use_gc:
                wire.append(float(m["wire_ratio"]))
        dt = (time.time() - t0) / steps
        extra = f";wire_ratio={np.mean(wire):.2f}" if wire else ""
        rows.append(csv_row(
            f"gradcomp/{label}", dt * 1e6,
            f"loss0={losses[0]:.3f};lossN={losses[-1]:.3f}{extra}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
