"""Host vs device reconstruction through the unified decode engine
(DESIGN.md Sec. 8).

The same padded ``DecodePlan`` is rebuilt by every backend
(``repro.core.decode.BACKENDS``) in two serving shapes:

  full/<backend>     -- one whole-channel decode (``decode_channels``)
  ranges/<backend>   -- R concurrent small ranges padded into ONE
                        reconstruct dispatch (``decode_ranges``), the
                        ``DecompressionService`` flush shape

Every backend's output is asserted byte-identical to the host before
timing, and the device rows report the engine's fallback counter -- a row
that silently fell back to the host would otherwise masquerade as a
device measurement.  Delta mode is used so the device path exercises the
sequential-cumsum story (the pallas kernel / fori_loop), not just the
gather.  ``REPRO_BENCH_QUICK=1`` (the CI smoke) shrinks the stream.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import IdealemCodec
from repro.core import decode as decode_mod
from repro.core.stream import decode_stream
from repro.store import Container, decode_channels, decode_ranges, pack

from .common import csv_row

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
B = 32
NB = 2_000 if QUICK else 20_000
FEED_BLOCKS = 512
RANGE_BLOCKS = 16
N_RANGES = 32 if QUICK else 256
BACKENDS = ("numpy", "jax", "pallas")


def _time(fn, repeat=3):
    fn()  # warmup (includes any jit compile)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def _build_store():
    rng = np.random.default_rng(0)
    levels = rng.normal(0, 3, size=8)
    n = NB * B
    x = (np.cumsum(rng.normal(0, 0.05, size=n))
         + levels[rng.integers(0, 8, size=NB).repeat(B)])
    codec = IdealemCodec(mode="delta", block_size=B, num_dict=64, alpha=0.05,
                         rel_tol=0.5, backend="jax")
    s = codec.session()
    segs = [s.feed(x[lo:lo + FEED_BLOCKS * B])
            for lo in range(0, n, FEED_BLOCKS * B)]
    segs.append(s.finish())
    stream = b"".join(segs)
    return stream, Container(pack(stream))


def run():
    rows = []
    stream, store = _build_store()
    nb = store.total_blocks(0)
    y = decode_stream(stream)

    rng = np.random.default_rng(1)
    starts = rng.integers(0, nb - RANGE_BLOCKS, size=N_RANGES)
    reqs = [(0, int(s), int(s) + RANGE_BLOCKS) for s in starts]
    blocks = N_RANGES * RANGE_BLOCKS

    times = {}
    for backend in BACKENDS:
        f0 = decode_mod.decode_stats()["fallbacks"]
        out = decode_channels(store, backend=backend)[0]
        np.testing.assert_array_equal(out, y)  # byte identity before timing
        for (_, i, j), got in zip(reqs, decode_ranges(store, reqs,
                                                      backend=backend)):
            np.testing.assert_array_equal(got, y[i * B:j * B])
        fell = decode_mod.decode_stats()["fallbacks"] - f0

        t_full = _time(lambda: decode_channels(store, backend=backend),
                       repeat=1)
        t_rng = _time(lambda: decode_ranges(store, reqs, backend=backend))
        times[backend] = (t_full, t_rng)
        rows.append(csv_row(
            f"decode_backends/full/{backend}", t_full * 1e6,
            f"blocks={nb};fallbacks={fell}"))
        rows.append(csv_row(
            f"decode_backends/ranges/{backend}", t_rng * 1e6,
            f"requests={N_RANGES};blocks={blocks};fallbacks={fell}"
            f";blocks_per_s={blocks / t_rng:.0f}"))

    host_full, host_rng = times["numpy"]
    best = min(BACKENDS[1:], key=lambda b: times[b][1])
    rows.append(csv_row(
        "decode_backends/ranges/device_vs_host", times[best][1] * 1e6,
        f"best_device={best}"
        f";speedup_vs_numpy={host_rng / times[best][1]:.2f}x"
        f";full_speedup={host_full / times[best][0]:.2f}x"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
