"""Host vs device reconstruction through the unified decode engine
(DESIGN.md Sec. 8).

The same padded ``DecodePlan`` is rebuilt by every backend
(``repro.core.decode.BACKENDS``) in three serving shapes:

  full/<backend>     -- one whole-channel decode (``decode_channels``)
  ranges/<backend>   -- R concurrent small ranges padded into ONE
                        reconstruct dispatch (``decode_ranges``), the
                        ``DecompressionService`` flush shape
  serve/...          -- the ``DecompressionService`` itself, streaming R
                        requests through many flushes: ``alternate`` is
                        plan-then-reconstruct (pipeline_depth 1),
                        ``pipelined`` overlaps host planning of flush N+1
                        with reconstruction of flush N (depth 2,
                        DESIGN.md Sec. 9) -- the overlap-vs-alternate
                        comparison the ROADMAP gates the pipeline on

Every backend's output is asserted byte-identical to the host before
timing, and the device rows report the engine's fallback counter -- a row
that silently fell back to the host would otherwise masquerade as a
device measurement.  Delta mode is used so the device path exercises the
sequential-cumsum story (the pallas kernel / fori_loop), not just the
gather.  ``REPRO_BENCH_QUICK=1`` (the CI smoke) shrinks the stream.
"""
from __future__ import annotations

import itertools
import os
import time

import numpy as np

from repro.core import IdealemCodec
from repro.core import decode as decode_mod
from repro.core.stream import decode_stream
from repro.serve import DecompressionService, FlushPolicy
from repro.store import Container, decode_channels, decode_ranges, pack

from .common import csv_row

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
B = 32
NB = 2_000 if QUICK else 20_000
FEED_BLOCKS = 512
RANGE_BLOCKS = 16
N_RANGES = 32 if QUICK else 256
BACKENDS = ("numpy", "jax", "pallas")
SERVE_BATCH = 8                       # requests per service flush
SERVE_RANGE_BLOCKS = 64 if QUICK else 256   # fatter than the ranges shape
N_SERVE = 24 if QUICK else 64
SERVE_BACKENDS = ("numpy", "jax")     # overlap is about host vs device
_rid = itertools.count()              # unique request ids across timed reps


def _time(fn, repeat=3):
    fn()  # warmup (includes any jit compile)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best  # best-of: scheduler noise inflates means, not minima


def _build_store():
    rng = np.random.default_rng(0)
    levels = rng.normal(0, 3, size=8)
    n = NB * B
    x = (np.cumsum(rng.normal(0, 0.05, size=n))
         + levels[rng.integers(0, 8, size=NB).repeat(B)])
    codec = IdealemCodec(mode="delta", block_size=B, num_dict=64, alpha=0.05,
                         rel_tol=0.5, backend="jax")
    s = codec.session()
    segs = [s.feed(x[lo:lo + FEED_BLOCKS * B])
            for lo in range(0, n, FEED_BLOCKS * B)]
    segs.append(s.finish())
    stream = b"".join(segs)
    return stream, Container(pack(stream))


def run():
    rows = []
    stream, store = _build_store()
    nb = store.total_blocks(0)
    y = decode_stream(stream)

    rng = np.random.default_rng(1)
    starts = rng.integers(0, nb - RANGE_BLOCKS, size=N_RANGES)
    reqs = [(0, int(s), int(s) + RANGE_BLOCKS) for s in starts]
    blocks = N_RANGES * RANGE_BLOCKS

    times = {}
    for backend in BACKENDS:
        f0 = decode_mod.decode_stats()["fallbacks"]
        out = decode_channels(store, backend=backend)[0]
        np.testing.assert_array_equal(out, y)  # byte identity before timing
        for (_, i, j), got in zip(reqs, decode_ranges(store, reqs,
                                                      backend=backend)):
            np.testing.assert_array_equal(got, y[i * B:j * B])
        fell = decode_mod.decode_stats()["fallbacks"] - f0

        t_full = _time(lambda: decode_channels(store, backend=backend),
                       repeat=1)
        t_rng = _time(lambda: decode_ranges(store, reqs, backend=backend))
        times[backend] = (t_full, t_rng)
        rows.append(csv_row(
            f"decode_backends/full/{backend}", t_full * 1e6,
            f"blocks={nb};fallbacks={fell}"))
        rows.append(csv_row(
            f"decode_backends/ranges/{backend}", t_rng * 1e6,
            f"requests={N_RANGES};blocks={blocks};fallbacks={fell}"
            f";blocks_per_s={blocks / t_rng:.0f}"))

    host_full, host_rng = times["numpy"]
    best = min(BACKENDS[1:], key=lambda b: times[b][1])
    rows.append(csv_row(
        "decode_backends/ranges/device_vs_host", times[best][1] * 1e6,
        f"best_device={best}"
        f";speedup_vs_numpy={host_rng / times[best][1]:.2f}x"
        f";full_speedup={host_full / times[best][0]:.2f}x"))

    # ---- serving pipeline: alternate (depth 1) vs overlapped (depth 2).
    # The service (and its worker thread) lives across timed reps -- the
    # steady-state serving shape; only submit->flush->drain is timed.
    # Requests are fatter than the ranges shape so a flush's reconstruct
    # has enough device work for the next flush's host plan to hide under.
    rng2 = np.random.default_rng(2)
    starts2 = rng2.integers(0, nb - SERVE_RANGE_BLOCKS, size=N_SERVE)
    serve_reqs = [(int(s), int(s) + SERVE_RANGE_BLOCKS) for s in starts2]
    serve_blocks = N_SERVE * SERVE_RANGE_BLOCKS

    serve_times = {}
    for backend in SERVE_BACKENDS:
        for depth, label in ((1, "alternate"), (2, "pipelined")):
            svc = DecompressionService(
                policy=FlushPolicy(max_batch_streams=SERVE_BATCH,
                                   pipeline_depth=depth),
                backend=backend)
            svc.attach("s", store)

            def burst():
                out = {}
                ids = []
                for i, j in serve_reqs:
                    rid = f"q{next(_rid)}"
                    ids.append((rid, i, j))
                    got = svc.submit(rid, "s", i, j)
                    if got:
                        out.update(got)
                out.update(svc.flush())
                out.update(svc.drain())
                return out, ids

            out, ids = burst()  # warmup + correctness
            assert len(out) == len(ids)
            for rid, i, j in ids:
                np.testing.assert_array_equal(out[rid], y[i * B:j * B])
            t = _time(lambda: burst())
            svc.close()
            serve_times[(backend, label)] = t
            rows.append(csv_row(
                f"decode_backends/serve/{label}/{backend}", t * 1e6,
                f"requests={N_SERVE};range_blocks={SERVE_RANGE_BLOCKS}"
                f";flush_batch={SERVE_BATCH}"
                f";blocks_per_s={serve_blocks / t:.0f}"))
        speedup = (serve_times[(backend, "alternate")]
                   / serve_times[(backend, "pipelined")])
        rows.append(csv_row(
            f"decode_backends/serve/overlap_vs_alternate/{backend}",
            serve_times[(backend, "pipelined")] * 1e6,
            f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
