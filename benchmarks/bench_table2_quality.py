"""Table II analog: six reconstruction-quality measures, original vs each
codec's reconstruction."""
from __future__ import annotations

import time


from repro.baselines import IsabelaLikeCodec, SzLikeCodec, ZfpLikeCodec
from repro.configs import idealem_paper as papercfg
from repro.core import quality_measures

from .common import ang_channels, csv_row, mag_channels


def _measures_str(m):
    return ";".join(f"{k.split('_')[0]}={v:.4g}" for k, v in m.items())


def run(n=None):
    rows = []
    chans = {}
    chans.update(mag_channels(*([n] if n else [])))
    chans.update(ang_channels(*([n] if n else [])))
    for name, x in chans.items():
        is_ang = name.endswith("ANG")
        codecs = {
            "original": None,
            "idealem": papercfg.ang_codec() if is_ang else papercfg.mag_codec(),
            "zfp_like": ZfpLikeCodec(tolerance=0.5 if is_ang else 0.08),
            "sz_like": SzLikeCodec(rel_bound_ratio=1e-3),
            "isabela_like": IsabelaLikeCodec(),
        }
        for cname, codec in codecs.items():
            t0 = time.time()
            y = x if codec is None else codec.decode(codec.encode(x))
            m = quality_measures(y)
            rows.append(csv_row(f"table2/{name}/{cname}",
                                (time.time() - t0) * 1e6 / len(x),
                                _measures_str(m)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
