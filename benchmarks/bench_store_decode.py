"""Read-path throughput: full sequential decode vs indexed range decode vs
batched multi-range decode over a packed container (DESIGN.md Sec. 7).

The write side already batches (PR 2); this measures what the footer index
buys consumers: answering a small block range without walking the whole
stream, and answering MANY concurrent ranges in one padded reconstruct
(the ``DecompressionService`` flush path).  A large multi-segment session
stream is packed once; then we time

  full/stream      -- ``decode_stream`` over the raw segment chain
  full/container   -- ``decode_channels`` through the index
  range/seq_slice  -- a small range served by full decode + slice (naive)
  range/indexed    -- the same range via ``decode_range`` (seek + 1 walk)
  ranges/loop      -- R random ranges, one ``decode_range`` each
  ranges/batched   -- the same R ranges in ONE ``decode_ranges`` batch

``REPRO_BENCH_QUICK=1`` (the CI smoke) shrinks the stream.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import IdealemCodec
from repro.core.stream import decode_stream
from repro.store import Container, decode_channels, decode_range, decode_ranges, pack

from .common import csv_row

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
B = 16
NB = 4_000 if QUICK else 40_000
FEED_BLOCKS = 512          # session chunk quantum -> segments per stream
RANGE_BLOCKS = 16          # "small range" a consumer asks for
N_RANGES = 64              # concurrent requests in the batched case


def _time(fn, repeat=3):
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def _build_store():
    rng = np.random.default_rng(0)
    levels = rng.normal(0, 2, size=8)
    n = NB * B
    x = (rng.normal(0, 1, size=n)
         + levels[rng.integers(0, 8, size=NB).repeat(B)])
    codec = IdealemCodec(mode="std", block_size=B, num_dict=64, alpha=0.05,
                         rel_tol=0.5, backend="jax")
    s = codec.session()
    segs = [s.feed(x[lo:lo + FEED_BLOCKS * B])
            for lo in range(0, n, FEED_BLOCKS * B)]
    segs.append(s.finish())
    stream = b"".join(segs)
    return stream, Container(pack(stream))


def run():
    rows = []
    stream, store = _build_store()
    nb = store.total_blocks(0)
    y = decode_stream(stream)

    t_full = _time(lambda: decode_stream(stream), repeat=1)
    rows.append(csv_row("store_decode/full/stream", t_full * 1e6,
                        f"blocks={nb};segments={store.n_chunks}"))
    t_cont = _time(lambda: decode_channels(store), repeat=1)
    np.testing.assert_array_equal(decode_channels(store)[0], y)
    rows.append(csv_row("store_decode/full/container", t_cont * 1e6,
                        f"blocks={nb};vs_stream={t_full / t_cont:.2f}x"))

    i = nb // 2
    t_naive = _time(lambda: decode_stream(stream)[i * B:(i + RANGE_BLOCKS) * B],
                    repeat=1)
    t_range = _time(lambda: decode_range(store, i, i + RANGE_BLOCKS))
    np.testing.assert_array_equal(decode_range(store, i, i + RANGE_BLOCKS),
                                  y[i * B:(i + RANGE_BLOCKS) * B])
    rows.append(csv_row("store_decode/range/seq_slice", t_naive * 1e6,
                        f"range_blocks={RANGE_BLOCKS}"))
    rows.append(csv_row(
        "store_decode/range/indexed", t_range * 1e6,
        f"range_blocks={RANGE_BLOCKS};speedup={t_naive / t_range:.1f}x"))

    rng = np.random.default_rng(1)
    starts = rng.integers(0, nb - RANGE_BLOCKS, size=N_RANGES)
    reqs = [(0, int(s), int(s) + RANGE_BLOCKS) for s in starts]
    t_loop = _time(lambda: [decode_range(store, i, j) for _, i, j in reqs])
    t_batch = _time(lambda: decode_ranges(store, reqs))
    for (_, i, j), got in zip(reqs, decode_ranges(store, reqs)):
        np.testing.assert_array_equal(got, y[i * B:j * B])
    blocks = N_RANGES * RANGE_BLOCKS
    rows.append(csv_row("store_decode/ranges/loop", t_loop * 1e6,
                        f"requests={N_RANGES};blocks={blocks}"))
    rows.append(csv_row(
        "store_decode/ranges/batched", t_batch * 1e6,
        f"requests={N_RANGES};blocks={blocks}"
        f";speedup={t_loop / t_batch:.1f}x"
        f";blocks_per_s={blocks / t_batch:.0f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
