"""Rate-distortion frontier: error-bounded IDEALEM vs the baseline codecs.

The error-bounded mode (DESIGN.md Sec. 11) turns IDEALEM from a purely
statistical-similarity codec into a pointwise-bounded one, which makes it
directly comparable with SZ/ZFP/ISABELA-style bounded-lossy compressors.
This bench sweeps the bound (as a fraction of the signal range) on the
repeating-waveform signal IDEALEM targets — a sawtooth uPMU phase-angle
channel — measures each codec's ACHIEVED max error and compression ratio,
and reports which measured operating points sit on the non-dominated
(error, ratio) frontier over ALL codecs and bounds.

The regimes split cleanly: the prediction/transform codecs quantize to the
bound, so their achieved error tracks the bound and their ratio grows as it
loosens.  IDEALEM (delta mode) instead reuses whole dictionary blocks, so
once the bound clears the waveform's noise floor its achieved error pins at
that floor — it holds the low-error end of the frontier at a real (~10x)
ratio, which no quantizing codec reaches without giving up its ratio.

Rows:

  frontier/idealem/<rel>    timed: IDEALEM delta encode at bound rel*range
  frontier/<baseline>/<rel> derived-only: the baseline at the same bound
  frontier/summary          derived-only: per-codec frontier membership;
                            the committed quick baseline pins
                            ``idealem_on_frontier=1``

``--quick`` (REPRO_BENCH_QUICK=1) shrinks the channel length.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.baselines import IsabelaLikeCodec, SzLikeCodec, ZfpLikeCodec
from repro.core import IdealemCodec
from repro.data import synthetic

from .common import csv_row

# bound sweep, as fractions of the global signal range
REL_BOUNDS = (0.002, 0.005, 0.01, 0.02, 0.05)


def _signal(n: int) -> np.ndarray:
    # sawtooth phase angle: the repeating-waveform regime where whole-block
    # dictionary reuse pays off (arXiv:1911.06980 Sec. II uPMU data)
    return synthetic.pmu_angle(n, slope=0.72, noise=0.05, seed=1)


def _measure(x: np.ndarray, encode, decode):
    t0 = time.time()
    blob = encode(x)
    dt = time.time() - t0
    y = np.asarray(decode(blob), dtype=np.float64)
    err = float(np.max(np.abs(x - y))) if len(x) else 0.0
    return len(x) * x.itemsize / len(blob), err, dt


def _frontier(points):
    """Indices of non-dominated (err, ratio) points: no other point has
    both a smaller-or-equal error and a strictly larger ratio (or equal
    ratio with strictly smaller error)."""
    keep = []
    for i, (e1, r1) in enumerate(points):
        dominated = any(
            (e2 <= e1 and r2 > r1) or (e2 < e1 and r2 >= r1)
            for j, (e2, r2) in enumerate(points) if j != i)
        if not dominated:
            keep.append(i)
    return keep


def run(n=None):
    quick = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0") or "0"))
    n = n or (16_384 if quick else 131_072)
    x = _signal(n)
    rng = float(np.max(x) - np.min(x))

    rows, points, labels = [], [], []
    for rel in REL_BOUNDS:
        bound = rel * rng
        codec = IdealemCodec(mode="delta", block_size=32, num_dict=255,
                             alpha=0.05, error_bound=bound, backend="numpy")
        ratio, err, dt = _measure(x, codec.encode, codec.decode)
        # f32 payload storage adds rounding on top of the gate's guarantee
        assert err <= bound + 1e-4 * rng, (rel, err, bound)
        points.append((err / rng, ratio))
        labels.append("idealem")
        rows.append(csv_row(f"frontier/idealem/{rel}", dt * 1e6 / n,
                            f"bound={rel};err={err / rng:.5f};"
                            f"ratio={ratio:.2f}"))

        for name, c in (
                ("sz_like", SzLikeCodec(rel_bound_ratio=rel)),
                ("zfp_like", ZfpLikeCodec(tolerance=bound)),
                ("isabela_like", IsabelaLikeCodec(
                    window=512, num_coeff=15, error_rate=rel * 100.0)),
        ):
            ratio, err, _ = _measure(x, c.encode, c.decode)
            points.append((err / rng, ratio))
            labels.append(name)
            rows.append(csv_row(f"frontier/{name}/{rel}", 0.0,
                                f"bound={rel};err={err / rng:.5f};"
                                f"ratio={ratio:.2f}"))

    on = _frontier(points)
    members = sorted({labels[i] for i in on})
    idealem_on = int("idealem" in members)
    rows.append(csv_row(
        "frontier/summary", 0.0,
        f"points={len(points)};frontier={len(on)};"
        f"members={'+'.join(members)};idealem_on_frontier={idealem_on}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
