"""Serving front end throughput: wire feeds/decodes per second through
the asyncio multiplexer (DESIGN.md Sec. 14).

Closed-loop clients over real sockets: N tenants each replay a uPMU-like
trace on a direct stream and then issue batched range decodes, so the
rows price the full path -- HTTP parse, typed validation, admission,
session/coalescer work, response encode.  Derived columns report the
feed rate and the scrape-side p99 the SLO gate would see.

Full-profile only: this bench is deliberately NOT in ``QUICK_MODULES``
(no committed quick-baseline row exists for it, and socket latency on a
shared PR runner is exactly the noise the perf gate excludes).  The
nightly soak covers the sustained version via ``scripts/loadgen.py``.

Rows: ``frontend/feed`` (us per feed request), ``frontend/decode``
(us per decode request).
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro import api, obs
from repro.serve import FlushPolicy, FrontendClient, ServeFrontend
from repro.store import pack
from repro.core import IdealemCodec

from .common import csv_row

TENANTS = 8
FEEDS_PER_TENANT = 48
DECODES_PER_TENANT = 24
CHUNK = 512
CFG = api.CodecConfig(mode="std", block_size=32, num_dict=63,
                      backend="numpy")


async def _tenant(fe, i, counts):
    rng = np.random.default_rng(i)
    x = rng.normal(0, 1, size=CHUNK)
    async with FrontendClient(fe.host, fe.port, f"b{i}") as c:
        await c.open("s", CFG)
        t0 = time.perf_counter()
        for _ in range(FEEDS_PER_TENANT):
            await c.feed("s", x)
        counts["feed_s"] += time.perf_counter() - t0
        await c.close_stream("s")

        codec = IdealemCodec.from_config(CFG)
        stream = codec.encode(rng.normal(0, 1, size=64 * 32))
        await c.attach("st", pack(stream))
        t0 = time.perf_counter()
        for k in range(DECODES_PER_TENANT):
            await c.decode("st", k % 48, k % 48 + 8)
        counts["decode_s"] += time.perf_counter() - t0


async def _run(counts):
    policy = FlushPolicy(max_batch_blocks=2048, max_batch_streams=32,
                         max_age_s=0.01)
    async with ServeFrontend(policy=policy, decode_backend="numpy") as fe:
        await asyncio.gather(*(_tenant(fe, i, counts)
                               for i in range(TENANTS)))
        async with FrontendClient(fe.host, fe.port, "probe") as c:
            return await c.metrics()


def main() -> None:
    counts = {"feed_s": 0.0, "decode_s": 0.0}
    text = asyncio.run(_run(counts))
    parsed = obs.parse_prometheus(text)
    n_feed = TENANTS * FEEDS_PER_TENANT
    n_dec = TENANTS * DECODES_PER_TENANT
    p99_feed = obs.quantile_from_parsed(
        parsed, "repro_frontend_request_seconds", 0.99,
        {"route": "POST /v1/feed"})
    p99_dec = obs.quantile_from_parsed(
        parsed, "repro_frontend_request_seconds", 0.99,
        {"route": "POST /v1/decode"})
    print(csv_row("frontend/feed", counts["feed_s"] / n_feed * 1e6,
                  f"rate={n_feed / counts['feed_s']:.0f}/s "
                  f"p99={0 if p99_feed is None else p99_feed * 1e3:.2f}ms "
                  f"tenants={TENANTS}"))
    print(csv_row("frontend/decode", counts["decode_s"] / n_dec * 1e6,
                  f"rate={n_dec / counts['decode_s']:.0f}/s "
                  f"p99={0 if p99_dec is None else p99_dec * 1e3:.2f}ms"))


if __name__ == "__main__":
    main()
