"""Fig. 3 analog: p-value vs KS statistic for n in {8..256} -- the
sensitivity-with-n effect that drives the block-size trade-off."""
from __future__ import annotations

import time

import numpy as np

from repro.core.npref import ks_pvalue_np

from .common import csv_row


def run():
    rows = []
    for n in [8, 16, 32, 64, 128, 256]:
        t0 = time.time()
        # distance at which p crosses alpha=0.01 for this n
        ds = np.linspace(0.01, 1.0, 400)
        ps = np.array([ks_pvalue_np(d, n, n) for d in ds])
        d01 = float(ds[np.argmax(ps < 0.01)])
        p_at_02 = ks_pvalue_np(0.2, n, n)
        rows.append(csv_row(
            f"fig3/n={n}", (time.time() - t0) * 1e6 / len(ds),
            f"p_at_D0.2={p_at_02:.4g};D_crit_alpha0.01={d01:.3f}"))
    # monotonicity check (larger n -> smaller p at same D)
    ps = [ks_pvalue_np(0.2, n, n) for n in [8, 16, 32, 64, 128, 256]]
    ok = all(a > b for a, b in zip(ps, ps[1:]))
    rows.append(csv_row("fig3/sensitivity_monotone", 0.0, f"ok={ok}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
