"""Props 6.1/6.2 + Cors 6.1/6.2: empirical convergence to the theoretical
maximum compression ratios (8B std, 8cB std-D1, (8/9)B residual)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import IdealemCodec
from repro.data import synthetic

from .common import csv_row


def run():
    rows = []
    B = 16
    # Prop 6.1: single gaussian source, multi-dict -> 8B
    x = np.random.default_rng(0).normal(size=B * 20_000)
    c = IdealemCodec(mode="std", block_size=B, num_dict=8, alpha=0.01,
                     rel_tol=0.5, backend="numpy")
    t0 = time.time()
    ratio = c.compression_ratio(x, c.encode(x))
    rows.append(csv_row("limits/prop6.1_std", (time.time() - t0) * 1e6 / len(x),
                        f"ratio={ratio:.1f};limit={8 * B};frac={ratio / (8 * B):.3f}"))
    # Cor 6.1: identical blocks, D=1, c=255 -> 8cB
    x = np.tile(np.random.default_rng(1).normal(size=B), 60_000)
    c = IdealemCodec(mode="std", block_size=B, num_dict=1, alpha=0.01,
                     rel_tol=0.5, max_count=255, backend="numpy")
    t0 = time.time()
    ratio = c.compression_ratio(x, c.encode(x))
    rows.append(csv_row("limits/cor6.1_std_D1", (time.time() - t0) * 1e6 / len(x),
                        f"ratio={ratio:.1f};limit={8 * 255 * B};"
                        f"frac={ratio / (8 * 255 * B):.3f}"))
    # Prop 6.2: smooth ramp, residual mode -> (8/9)B
    B2 = 112
    x = synthetic.pmu_angle(B2 * 3_000, noise=0.01)
    c = IdealemCodec(mode="residual", block_size=B2, num_dict=8, alpha=0.01,
                     rel_tol=0.5, value_range=(0.0, 360.0), backend="numpy")
    t0 = time.time()
    ratio = c.compression_ratio(x, c.encode(x))
    lim = 8 * B2 / 9
    rows.append(csv_row("limits/prop6.2_residual",
                        (time.time() - t0) * 1e6 / len(x),
                        f"ratio={ratio:.2f};limit={lim:.2f};frac={ratio / lim:.3f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
