"""flash_decode Pallas kernel vs pure-jnp oracle: shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.ref import flash_decode_ref


def _case(B, H, Hkv, hd, C, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, C, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, C, Hkv, hd)), dtype)
    valid = jnp.asarray(rng.random((B, C)) > 0.3)
    # ensure at least one valid position per row
    valid = valid.at[:, 0].set(True)
    return q, k, v, valid


@pytest.mark.parametrize("B,H,Hkv,hd,C", [
    (2, 8, 2, 16, 1024),   # GQA group 4
    (1, 4, 4, 32, 512),    # MHA
    (3, 16, 8, 64, 2048),  # multi-chunk sweep
    (2, 6, 6, 64, 512),    # whisper-like head count
])
def test_kernel_matches_ref(B, H, Hkv, hd, C):
    q, k, v, valid = _case(B, H, Hkv, hd, C)
    out = flash_decode_pallas(q, k, v, valid)
    ref = flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q, k, v, valid = _case(2, 8, 4, 32, 512, dtype=dtype)
    out = flash_decode_pallas(q, k, v, valid)
    ref = flash_decode_ref(q, k, v, valid)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


def test_window_masking_equivalence():
    """Masking the cache to a window inside `valid` == windowed attention."""
    B, H, Hkv, hd, C = 1, 4, 2, 16, 512
    q, k, v, _ = _case(B, H, Hkv, hd, C, seed=3)
    pos = jnp.arange(C)
    cur = 400
    window = 128
    valid = ((pos <= cur) & (cur - pos < window))[None, :]
    out = flash_decode_pallas(q, k, v, valid)
    ref = flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
