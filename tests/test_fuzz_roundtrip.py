"""Differential fuzz: vectorized stream path vs the retained seed oracles.

One shared check runs every case through three independent layers:

  1. byte-identity: ``assemble_stream`` (vectorized) == ``_assemble_stream_py``
     (seed loop) == ``IdealemCodec.encode``;
  2. parse-identity: ``parse_stream`` (vectorized gather) event-for-event
     equal to ``_parse_stream_py`` (seed walk);
  3. decode round-trip structure: length, exact tail, miss blocks
     reproduced, hit blocks sourced from their dictionary entry;
  4. segment framing: chunked session output decodes and parses like the
     one-shot stream (CONT/MORE paths the seed oracle cannot produce).

A deterministic sweep pins the mode x D regimes so the differential runs
even without hypothesis; the hypothesis test widens the same check over
random (mode, D, B, dtype, value_range, signal) draws (ISSUE 2).
"""
import numpy as np
import pytest

from conftest import mixed_signal
from repro.core import IdealemCodec
from repro.core.npref import encode_decisions_np
from repro.core.stream import (StreamHeader, _assemble_stream_py,
                               _parse_stream_py, assemble_stream,
                               decode_stream, parse_stream)


def _events_equal(ev_a, ev_b):
    assert len(ev_a) == len(ev_b)
    for a, b in zip(ev_a, ev_b):
        assert a["kind"] == b["kind"]
        assert a["slot"] == b["slot"]
        if a["kind"] == "miss":
            assert a["overwrite"] == b["overwrite"]
            np.testing.assert_array_equal(np.asarray(a["payload"]),
                                          np.asarray(b["payload"]))
        if "base" in a or "base" in b:
            assert float(a["base"]) == float(b["base"])


def check_roundtrip(kwargs: dict, x: np.ndarray) -> None:
    codec = IdealemCodec(**kwargs)
    B = codec.block_size
    nb = len(x) // B
    blob = codec.encode(x)

    # --- oracle re-derivation of the exact same stream ---
    blocks = np.ascontiguousarray(x[:nb * B]).reshape(nb, B)
    payload, bases = codec._transform(blocks)
    hit, slot, ovw = encode_decisions_np(
        payload, num_dict=codec.num_dict, d_crit=float(codec.d_crit),
        rel_tol=float(codec.rel_tol), use_minmax=codec.use_minmax,
        use_ks=codec.use_ks)
    header = StreamHeader(codec.mode_id, B, codec.num_dict, codec.max_count,
                          x.dtype, codec.value_range, nb, x[nb * B:])
    args = (header, blocks, payload, bases, hit, slot, ovw)
    oracle = _assemble_stream_py(*args)
    assert assemble_stream(*args) == oracle  # vectorized == seed loop
    assert blob == oracle                    # full codec == seed loop

    # --- parse differential ---
    hdr_py, ev_py = _parse_stream_py(blob)
    hdr_vec, ev_vec = parse_stream(blob)
    assert (hdr_py.mode, hdr_py.block_size, hdr_py.num_dict,
            hdr_py.n_blocks) == (hdr_vec.mode, hdr_vec.block_size,
                                 hdr_vec.num_dict, hdr_vec.n_blocks)
    np.testing.assert_array_equal(hdr_py.tail, hdr_vec.tail)
    _events_equal(ev_py, ev_vec)

    # --- decode round-trip structure ---
    y = decode_stream(blob)
    assert len(y) == len(x)
    np.testing.assert_array_equal(y[nb * B:], x[nb * B:])  # tail verbatim
    tol = 1e-5 if x.dtype == np.float32 else 1e-9
    last_miss = {}
    for i, ev in enumerate(ev_vec):
        yb, xb = y[i * B:(i + 1) * B], blocks[i]
        if ev["kind"] == "miss":
            last_miss[ev["slot"]] = i
            if codec.mode == "std":
                np.testing.assert_array_equal(yb, xb)  # stored verbatim
            else:
                np.testing.assert_allclose(yb, xb, atol=tol * 400)
        elif codec.mode == "std":
            # hit: a permutation of the dictionary source block
            src = last_miss[ev["slot"]]
            np.testing.assert_array_equal(np.sort(yb), np.sort(blocks[src]))
        else:
            assert abs(float(yb[0]) - float(ev["base"])) <= tol * 400

    # --- segment framing: chunked session == one-shot ---
    s = codec.session(dtype=x.dtype)
    step = max(2 * B + 3, len(x) // 3)
    segs = [s.feed(x[lo:lo + step]) for lo in range(0, len(x), step)]
    segs.append(s.finish())
    chunked = b"".join(segs)
    np.testing.assert_array_equal(decode_stream(chunked), y)
    _, ev_chunked = parse_stream(chunked)
    assert ([(e["kind"], e["slot"]) for e in ev_chunked]
            == [(e["kind"], e["slot"]) for e in ev_vec])


# ------------------------------------------------------ deterministic sweep
SWEEP = [
    ("std", 1, 8, np.float64, None),
    ("std", 2, 16, np.float32, None),
    ("std", 32, 16, np.float64, None),
    ("std", 255, 5, np.float64, None),
    ("residual", 1, 16, np.float64, (0.0, 360.0)),
    ("residual", 32, 4, np.float32, None),
    ("residual", 255, 16, np.float64, (0.0, 360.0)),
    ("delta", 1, 16, np.float32, None),
    ("delta", 2, 7, np.float64, (0.0, 360.0)),
    ("delta", 32, 16, np.float64, None),
]


@pytest.mark.parametrize("mode,num_dict,B,dtype,vr", SWEEP)
def test_differential_sweep(mode, num_dict, B, dtype, vr):
    x = mixed_signal(B * 60 + B // 2, seed=num_dict)
    if vr is not None:
        x = np.mod(x * 40.0, 360.0)
    kwargs = dict(mode=mode, block_size=B, num_dict=num_dict, alpha=0.05,
                  rel_tol=0.5, value_range=vr, backend="numpy")
    check_roundtrip(kwargs, x.astype(dtype))


def test_differential_tail_only_stream():
    kwargs = dict(mode="std", block_size=16, num_dict=3, backend="numpy")
    check_roundtrip(kwargs, mixed_signal(7, seed=1))


# --------------------------------------------------------- hypothesis fuzz
try:
    import hypothesis  # noqa: F401

    from hypothesis import given, settings

    from conftest import codec_cases

    @given(codec_cases())
    @settings(max_examples=30, deadline=None)
    def test_fuzz_roundtrip_property(case):
        kwargs, x = case
        check_roundtrip(kwargs, x)

except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fuzz_roundtrip_property():
        pass
