"""Streaming architecture tests: resumable encoder state, chunked session
equivalence to one-shot encoding, batched multi-channel sessions, and the
serve-layer CompressionService."""
import numpy as np
import pytest

from repro.core import IdealemCodec
from repro.core.npref import encode_decisions_np, np_init_state
from repro.core.stream import decode_stream, parse_stream

CHUNKINGS = [
    [1_000_000],                 # everything at once
    [7, 16, 100, 1_000_000],     # sub-block then large
    [256] * 100,                 # uniform
    [1, 31, 32, 33, 999, 1_000_000],
]


def _mixed(n, seed=0):
    rng = np.random.default_rng(seed)
    # mixture of sources => hits, misses and overwrites all occur
    parts = [rng.normal(m, s, size=n // 3) for m, s in [(0, 1), (5, 0.5), (0, 1)]]
    return np.concatenate(parts + [rng.normal(0, 1, size=n - 3 * (n // 3))])


def _take(x, sizes):
    out, lo = [], 0
    for s in sizes:
        out.append(x[lo:lo + s])
        lo += s
        if lo >= len(x):
            break
    return out


# -------------------------------------------------- resumable encoder state
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_chunked_decisions_match_one_shot(backend):
    """Threading the dictionary carry across chunks must reproduce the
    decisions of a single scan over the concatenated blocks."""
    rng = np.random.default_rng(7)
    blocks = np.concatenate([
        rng.normal(m, s, size=(30, 24)) for m, s in [(0, 1), (5, 0.5), (0, 1)]
    ]).astype(np.float32)
    kw = dict(num_dict=7, d_crit=0.4, rel_tol=0.5)

    if backend == "numpy":
        ref = encode_decisions_np(blocks, **kw)
        state = np_init_state(kw["num_dict"])
        parts = [encode_decisions_np(blocks[lo:lo + 17], state=state, **kw)[0]
                 for lo in range(0, len(blocks), 17)]
    else:
        import jax.numpy as jnp
        from repro.core.encoder import encode_decisions, init_state
        matcher = None
        if backend == "pallas":
            from repro.kernels.ops import dict_match
            matcher = dict_match
        jb = jnp.asarray(blocks)
        ref = encode_decisions(jb, matcher=matcher, **kw)
        state = init_state(kw["num_dict"], blocks.shape[-1])
        parts = []
        for lo in range(0, len(blocks), 17):
            out, state = encode_decisions(jb[lo:lo + 17], matcher=matcher,
                                          state=state, **kw)
            parts.append(out)
    for i in range(3):
        got = np.concatenate([np.asarray(p[i]) for p in parts])
        np.testing.assert_array_equal(np.asarray(ref[i]), got)


def test_batched_state_matches_per_channel():
    """(C, nb, n) blocks with per-channel DictState == C independent scans."""
    import jax.numpy as jnp
    from repro.core.encoder import (encode_decisions,
                                    encode_decisions_batched, init_state)
    rng = np.random.default_rng(1)
    blocks = jnp.asarray(rng.normal(size=(3, 40, 16)), jnp.float32)
    kw = dict(num_dict=5, d_crit=0.45, rel_tol=0.5)
    state = init_state(5, 16, channels=3)
    (h, s, o), state2 = encode_decisions_batched(blocks, state=state, **kw)
    assert h.shape == (3, 40) and state2.sorted_blocks.shape == (3, 5, 16)
    for ci in range(3):
        hc, sc, oc = encode_decisions(blocks[ci], **kw)
        np.testing.assert_array_equal(np.asarray(h[ci]), np.asarray(hc))
        np.testing.assert_array_equal(np.asarray(s[ci]), np.asarray(sc))
        np.testing.assert_array_equal(np.asarray(o[ci]), np.asarray(oc))


# ----------------------------------------------- session chunked == one-shot
@pytest.mark.parametrize("mode,num_dict", [
    ("std", 255), ("std", 3), ("std", 1),
    ("residual", 255), ("residual", 1),
    ("delta", 3), ("delta", 1),
])
@pytest.mark.parametrize("chunking", CHUNKINGS)
def test_session_chunked_decodes_like_one_shot(mode, num_dict, chunking):
    """Acceptance: any chunk split through feed()/finish() decodes to exactly
    the bytes one-shot encode decodes to, with dictionary state preserved."""
    vr = (0.0, 360.0) if mode != "std" else None
    x = _mixed(16 * 150 + 9, seed=2)
    if vr:
        x = np.mod(np.abs(x) * 40.0, 360.0)
    c = IdealemCodec(mode=mode, block_size=16, num_dict=num_dict, alpha=0.05,
                     rel_tol=0.5, value_range=vr, backend="numpy")
    one_shot = c.encode(x)
    y_ref = c.decode(one_shot)

    s = c.session()
    segs = [s.feed(ch) for ch in _take(x, chunking)]
    segs.append(s.finish())
    blob = b"".join(segs)
    y = c.decode(blob)
    np.testing.assert_array_equal(y_ref, y)

    # dictionary state (and therefore hit rate) is preserved across chunks
    _, ev_ref = parse_stream(one_shot)
    _, ev = parse_stream(blob)
    kinds_ref = [(e["kind"], e["slot"]) for e in ev_ref]
    kinds = [(e["kind"], e["slot"]) for e in ev]
    assert kinds_ref == kinds


def test_session_single_feed_bytes_equal_one_shot():
    """A one-feed buffered session is the one-shot path: byte-equal output."""
    x = _mixed(32 * 80 + 3, seed=5)
    c = IdealemCodec(mode="std", block_size=32, num_dict=31, alpha=0.05,
                     rel_tol=0.5, backend="numpy")
    s = c.session(emit_segments=False)
    s.feed(x)
    assert s.finish() == c.encode(x)


def test_session_hit_rate_preserved_vs_naive_chunking():
    """The whole point of the carry: chunked sessions keep the one-shot hit
    rate while naive per-chunk encodes rebuild the dictionary and lose it."""
    x = _mixed(32 * 400, seed=9)
    c = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.05,
                     rel_tol=0.5, backend="numpy")
    one = c.encode_stats(x)

    s = c.session()
    for lo in range(0, len(x), 640):
        s.feed(x[lo:lo + 640])
    s.finish()
    assert s.stats.blocks == one["blocks"]
    assert s.stats.hits == one["hits"]  # identical decisions => identical hits

    naive_hits = sum(c.encode_stats(x[lo:lo + 640])["hits"]
                     for lo in range(0, len(x), 640))
    assert naive_hits < one["hits"]  # the naive path must lose hits


def test_session_multi_channel_segments():
    rng = np.random.default_rng(4)
    C = 3
    chans = np.stack([rng.normal(i, 1.0, size=16 * 60 + 5) for i in range(C)])
    c = IdealemCodec(mode="std", block_size=16, num_dict=31, alpha=0.05,
                     rel_tol=0.5)
    s = c.session(channels=C)
    parts = [s.feed(chans[:, :333]), s.feed(chans[:, 333:]), s.finish()]
    for ci in range(C):
        blob = b"".join(p[ci] for p in parts)
        np.testing.assert_array_equal(c.decode(blob),
                                      c.decode(c.encode(chans[ci])))
    assert all(st.blocks == 60 for st in s.stats)


def test_session_misuse_raises():
    c = IdealemCodec(mode="std", block_size=16, num_dict=3, backend="numpy")
    s = c.session()
    with pytest.raises(ValueError):
        s.feed(np.zeros((2, 16)))  # 2-D chunk into a single-channel session
    s.finish()
    with pytest.raises(RuntimeError):
        s.feed(np.zeros(16))
    with pytest.raises(RuntimeError):
        s.finish()


# ------------------------------------------------------- serve-layer service
def test_compression_service_lifecycle():
    from repro.serve.compress import CompressionService
    rng = np.random.default_rng(0)
    x = rng.normal(size=32 * 120 + 11)
    svc = CompressionService(mode="std", block_size=32, num_dict=255,
                             alpha=0.01, rel_tol=0.5, backend="numpy")
    svc.open_stream("a")
    svc.open_stream("b", num_dict=3)
    with pytest.raises(KeyError):
        svc.open_stream("a")
    segs = [svc.feed("a", x[:1000]), svc.feed("a", x[1000:]),
            svc.close_stream("a")]
    y = decode_stream(b"".join(segs))
    codec = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                         rel_tol=0.5, backend="numpy")
    np.testing.assert_array_equal(y, codec.decode(codec.encode(x)))
    # stats survive close; unknown streams raise
    assert svc.stats("a")["blocks"] == 120
    assert "a" not in svc.active_streams and "b" in svc.active_streams
    with pytest.raises(KeyError):
        svc.feed("a", x)
    svc.feed("b", x[:100])
    assert svc.stats()["blocks"] >= 120
    svc.close_stream("b")


# ------------------------------------------------ time-based flush trigger
def test_flush_policy_deadline_is_pure():
    """max_age_s trips on the reported age alone -- no wall clock, and only
    when something is actually staged."""
    from repro.serve import FlushPolicy
    p = FlushPolicy(max_batch_blocks=100, max_batch_streams=10, max_age_s=2.0)
    assert not p.should_flush(1, 5, age_s=1.9)
    assert p.should_flush(1, 5, age_s=2.0)
    assert not p.should_flush(0, 0, age_s=50.0)  # nothing ready: no flush
    assert p.should_flush(1, 100, age_s=None)    # count triggers still work
    # age is optional: legacy two-argument callers are unaffected
    assert not FlushPolicy(max_age_s=0.1).should_flush(1, 1)


def test_coalescer_deadline_flush_injected_clock():
    """The coalescer measures batch age with an injectable clock: old
    staged payloads flush via poll()/submit() without count pressure."""
    from repro.serve import FlushPolicy
    from repro.serve.compress import StreamCoalescer
    t = [0.0]
    co = StreamCoalescer(
        policy=FlushPolicy(max_age_s=2.0, max_batch_blocks=10 ** 9,
                           max_batch_streams=10 ** 9),
        clock=lambda: t[0], mode="std", block_size=16, num_dict=8,
        alpha=0.05, rel_tol=0.5, backend="jax")
    rng = np.random.default_rng(0)
    co.open_stream("a")
    co.open_stream("b")
    assert co.submit("a", rng.normal(size=100)) is None  # batch born at t=0
    t[0] = 1.0
    assert co.submit("b", rng.normal(size=50)) is None
    assert co.poll() is None                   # oldest age 1.0 < 2.0
    t[0] = 2.5
    out = co.poll()                            # deadline expired
    assert out is not None and set(out) == {"a", "b"}
    y = decode_stream(out["a"] + co.close_stream("a"))
    assert len(y) == 100
    assert co.poll() is None                   # rearmed: nothing staged

    # sub-block staging alone must not trip the deadline (nothing to cut)
    co.submit("b", rng.normal(size=3))
    t[0] = 10.0
    assert co.poll() is None

    # a partial flush (close_stream) must not leave survivors aged by the
    # departed stream's older submissions
    co.open_stream("c")
    t[0] = 20.0
    co.submit("b", rng.normal(size=40))   # b staged at t=20
    t[0] = 21.5
    co.submit("c", rng.normal(size=40))   # c staged at t=21.5
    co.close_stream("b")
    t[0] = 22.5
    assert co.poll() is None              # c is only 1.0s old, not 2.5s
    t[0] = 23.6
    out = co.poll()                       # now c's own age crossed 2.0
    assert out is not None and set(out) == {"c"}
