"""Shared test infrastructure.

* ``lcg_signal`` / ``mixed_signal``: deterministic signal generators.  The
  golden-corpus streams are generated from ``lcg_signal`` -- a hand-rolled
  LCG, so the reference bytes cannot drift with numpy RNG stream changes.
* ``GOLDEN_CASES``: the mode x D-regime corpus table shared by the golden
  regression test and ``tests/golden/make_golden.py``.
* hypothesis profiles + strategies for the differential fuzz suite
  (``test_fuzz_roundtrip.py``); everything hypothesis-related is guarded so
  the suite still collects when hypothesis is not installed.
"""
import os

import numpy as np

# --------------------------------------------------- deterministic signals
_LCG_A, _LCG_C, _LCG_M = 6364136223846793005, 1442695040888963407, 2**64


def lcg_signal(n: int, seed: int = 1, lo: float = 0.0,
               hi: float = 1.0) -> np.ndarray:
    """Uniform-ish values in [lo, hi) from a fixed 64-bit LCG (independent
    of any library's RNG stream; safe to pin golden bytes against)."""
    out = np.empty(n, dtype=np.float64)
    s = (seed * 2 + 1) & (_LCG_M - 1)
    for i in range(n):
        s = (_LCG_A * s + _LCG_C) % _LCG_M
        out[i] = s / _LCG_M
    return lo + out * (hi - lo)


def mixed_signal(n: int, seed: int = 0) -> np.ndarray:
    """Multi-source mixture (numpy RNG): hits, misses and FIFO overwrites
    all occur.  For tests that compare paths within one process only."""
    rng = np.random.default_rng(seed)
    parts = [rng.normal(m, s, size=n // 3)
             for m, s in [(0, 1), (5, 0.5), (0, 1)]]
    return np.concatenate(parts + [rng.normal(0, 1, size=n - 3 * (n // 3))])


# -------------------------------------------------------- golden corpus map
# name -> codec kwargs; one case per mode x D regime (ISSUE 2).  The signal
# is lcg_signal(16 * 40 + 5, seed=<case index>), scaled into the
# value_range when one is set.
GOLDEN_CASES = {
    "std_D1": dict(mode="std", num_dict=1),
    "std_D32": dict(mode="std", num_dict=32),
    "residual_D1": dict(mode="residual", num_dict=1),
    "residual_D32_vr": dict(mode="residual", num_dict=32,
                            value_range=(0.0, 360.0)),
    "delta_D1_vr": dict(mode="delta", num_dict=1,
                        value_range=(0.0, 360.0)),
    "delta_D32": dict(mode="delta", num_dict=32),
    # small FIFO + wandering level: pins the 0xFF overwrite prefix bytes
    "std_D4_ovw": dict(mode="std", num_dict=4),
    # half precision: pins the v3 FLAG_F16 header byte and the raw f16
    # payload layout (appended last -- signal seeds are by case index)
    "std_D8_f16": dict(mode="std", num_dict=8, dtype=np.float16),
}
GOLDEN_BLOCK = 16
GOLDEN_SAMPLES = 16 * 40 + 5


def golden_signal(name: str) -> np.ndarray:
    idx = list(GOLDEN_CASES).index(name)
    vr = GOLDEN_CASES[name].get("value_range")
    lo, hi = vr if vr is not None else (-4.0, 4.0)
    x = lcg_signal(GOLDEN_SAMPLES, seed=idx + 1, lo=lo, hi=hi)
    # step the level so the FIFO sees misses (and, for _ovw, overwrites)
    n_lvl, scale = (16, 0.9) if name.endswith("_ovw") else (5, 0.07)
    x += np.repeat(np.arange(n_lvl), len(x) // n_lvl + 1)[:len(x)] \
        * (hi - lo) * scale
    x = np.mod(x, hi - lo) + lo if vr is not None else x
    return x.astype(GOLDEN_CASES[name].get("dtype", np.float64))


def golden_codec_kwargs(name: str) -> dict:
    # "dtype" parameterizes the SIGNAL (golden_signal), not the codec
    case = {k: v for k, v in GOLDEN_CASES[name].items() if k != "dtype"}
    return dict(block_size=GOLDEN_BLOCK, alpha=0.05, rel_tol=0.5,
                backend="numpy", **case)


# ------------------------------------------------------ hypothesis plumbing
try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as st

    settings.register_profile(
        "quick", max_examples=10, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("ci", max_examples=60, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))

    @st.composite
    def codec_cases(draw):
        """(codec kwargs, signal) pairs spanning mode x D x B x dtype x
        bounded value_range -- the fuzz axes named in ISSUE 2."""
        mode = draw(st.sampled_from(["std", "residual", "delta"]))
        num_dict = draw(st.sampled_from([1, 2, 32, 255]))
        block_size = draw(st.integers(min_value=4, max_value=40))
        dtype = draw(st.sampled_from([np.float64, np.float32]))
        value_range = (None if mode == "std"
                       else draw(st.sampled_from([None, (0.0, 360.0)])))
        nb = draw(st.integers(min_value=0, max_value=50))
        tail = draw(st.integers(min_value=0, max_value=block_size - 1))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        n = nb * block_size + tail
        # mixture of a few levels so hit/miss/overwrite all happen
        levels = rng.normal(0, 2, size=4)
        x = (rng.normal(0, 1, size=n)
             + levels[rng.integers(0, 4, size=n // max(block_size, 1) + 1)
                      .repeat(block_size)[:n]])
        if value_range is not None:
            x = np.mod(x * 40.0, 360.0)
        kwargs = dict(mode=mode, block_size=block_size, num_dict=num_dict,
                      alpha=0.05, rel_tol=0.5, value_range=value_range,
                      backend="numpy")
        return kwargs, x.astype(dtype)

    @st.composite
    def switch_schedules(draw):
        """Per-channel regime schedules for the adaptive (mixed-mode)
        session fuzz (ISSUE 9): each channel is a drawn sequence of
        (regime, n_blocks) segments, so selector switches land at
        different, per-channel feed boundaries.  Returns
        ``(codec kwargs, (C, m) signal, feed size)``; the differential
        runs the same schedule through the numpy oracle session and the
        batched device session and compares bytes."""
        B = draw(st.sampled_from([8, 16]))
        C = draw(st.integers(min_value=1, max_value=4))
        eb = draw(st.sampled_from([None, 0.75]))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        schedules = [
            [(draw(st.sampled_from(["noise", "smooth", "trend"])),
              draw(st.integers(min_value=6, max_value=20)))
             for _ in range(draw(st.integers(min_value=1, max_value=3)))]
            for _ in range(C)]
        total = max(sum(nb for _, nb in sch) for sch in schedules)
        x = np.zeros((C, total * B))
        for ci, sch in enumerate(schedules):
            # channels shorter than the longest extend their last regime
            segs = list(sch) + [
                (sch[-1][0], total - sum(nb for _, nb in sch))]
            t0 = 0
            for regime, nb in segs:
                n = nb * B
                if n <= 0:
                    continue
                t = np.arange(t0, t0 + n)
                if regime == "noise":
                    seg = rng.normal(0.0, 1.0, n)
                elif regime == "smooth":
                    seg = np.sin(t * 0.01) * 5 + rng.normal(0, 0.01, n)
                else:
                    seg = t * 0.02 + rng.normal(0, 0.05, n)
                x[ci, t0:t0 + n] = seg
                t0 += n
        feed = draw(st.integers(min_value=B, max_value=4 * B))
        kwargs = dict(mode="std", block_size=B, num_dict=8, alpha=0.05,
                      adaptive=True, error_bound=eb)
        return kwargs, x, feed

except ImportError:  # hypothesis is optional (requirements-dev.txt)
    pass
