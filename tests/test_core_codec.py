"""End-to-end codec properties: roundtrip, format, theory limits, decisions."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import IdealemCodec
from repro.core.npref import encode_decisions_np
from repro.core.encoder import encode_decisions
from repro.core.stream import parse_stream


def _stationary(n, seed=0):
    return np.random.default_rng(seed).normal(0.0, 1.0, size=n)


def _ramp_angles(n, slope=0.7, noise=0.05, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    return np.mod(t * slope + rng.normal(0, noise, size=n), 360.0)


# --------------------------------------------------------------- decisions
@pytest.mark.parametrize("num_dict", [1, 2, 7, 255])
@pytest.mark.parametrize("use_minmax", [True, False])
def test_jax_decisions_match_numpy_reference(num_dict, use_minmax):
    rng = np.random.default_rng(42)
    # mixture of three sources => hits, misses and overwrites all occur
    blocks = np.concatenate([
        rng.normal(m, s, size=(30, 24)) for m, s in [(0, 1), (5, 0.5), (0, 1)]
    ]).astype(np.float32)
    kw = dict(num_dict=num_dict, d_crit=0.4, rel_tol=0.5, use_minmax=use_minmax)
    ref = encode_decisions_np(blocks, **kw)
    import jax.numpy as jnp
    out = encode_decisions(jnp.asarray(blocks), **kw)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(r, np.asarray(o))


# --------------------------------------------------------------- roundtrip
@pytest.mark.parametrize("mode", ["std", "residual", "delta"])
@pytest.mark.parametrize("num_dict", [1, 3, 255])
def test_roundtrip_length_and_misses(mode, num_dict):
    vr = (0.0, 360.0) if mode != "std" else None
    x = _ramp_angles(16 * 40 + 5) if mode != "std" else _stationary(16 * 40 + 5)
    c = IdealemCodec(mode=mode, block_size=16, num_dict=num_dict, alpha=0.05,
                     rel_tol=0.5, value_range=vr, backend="numpy")
    blob = c.encode(x)
    y = c.decode(blob)
    assert len(y) == len(x)
    # tail is verbatim
    np.testing.assert_allclose(y[-5:], x[-5:])
    # miss blocks reconstruct (near-)exactly
    _, events = parse_stream(blob)
    B = c.block_size
    for i, ev in enumerate(events):
        if ev["kind"] == "miss":
            tol = 0 if mode != "delta" else 1e-9  # delta re-accumulates
            np.testing.assert_allclose(y[i * B:(i + 1) * B], x[i * B:(i + 1) * B],
                                       atol=tol)


def test_std_hits_are_permutations_of_dictionary_entry():
    x = _stationary(32 * 100)
    c = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                     rel_tol=0.5, backend="numpy")
    blob = c.encode(x)
    y = c.decode(blob)
    _, events = parse_stream(blob)
    dictionary = {}
    B = c.block_size
    n_hits = 0
    for i, ev in enumerate(events):
        if ev["kind"] == "miss":
            dictionary[ev["slot"]] = ev["payload"]
        else:
            n_hits += 1
            got = np.sort(y[i * B:(i + 1) * B])
            want = np.sort(dictionary[ev["slot"]])
            np.testing.assert_array_equal(got, want)  # multiset equality
    assert n_hits > 50  # stationary noise must compress


def test_statistical_similarity_preserved():
    """The paper's exact guarantee: every decoded block is within the KS
    acceptance distance d_crit of its original block (hits are permutations
    of a dictionary entry that passed the test; misses are verbatim)."""
    import scipy.stats
    x = _stationary(32 * 300)
    c = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                     rel_tol=0.5, backend="numpy")
    y = c.decode(c.encode(x))
    B = c.block_size
    for i in range(len(x) // B):
        d = scipy.stats.ks_2samp(x[i * B:(i + 1) * B], y[i * B:(i + 1) * B]).statistic
        assert d <= c.d_crit + 1e-9
    # and the global distribution stays sane (block-level alpha, not global)
    assert scipy.stats.ks_2samp(x, y).statistic < 0.25


def test_residual_mode_wraps_into_range():
    x = _ramp_angles(112 * 60)
    c = IdealemCodec(mode="residual", block_size=112, num_dict=255, alpha=0.01,
                     rel_tol=0.5, value_range=(0.0, 360.0), backend="numpy")
    y = c.decode(c.encode(x))
    assert np.all(y >= 0.0) and np.all(y < 360.0)
    # circular error should be small (wrap-aware)
    err = np.abs(y - x)
    err = np.minimum(err, 360.0 - err)
    assert np.percentile(err, 95) < 20.0


# ------------------------------------------------------------ theory limits
def test_prop_6_1_std_ratio_limit():
    """Ratio -> 8B on a single-source stream; never exceeds it."""
    B = 16
    x = _stationary(B * 4000)
    c = IdealemCodec(mode="std", block_size=B, num_dict=4, alpha=0.01,
                     rel_tol=0.5, backend="numpy")
    blob = c.encode(x)
    ratio = c.compression_ratio(x, blob)
    assert ratio <= 8 * B + 1e-9
    assert ratio > 0.8 * 8 * B  # single gaussian source compresses near limit


def test_cor_6_1_single_dict_byte_accounting():
    """Cor. 6.1: ideal single-source stream costs 8B + ceil(i/c) body bytes,
    so the D=1 mode exceeds the multi-dict 8B limit (and -> 8cB as i -> inf)."""
    B, cmax, nb = 16, 255, 4000
    x = np.tile(_stationary(B), nb)  # identical blocks: ideal stream
    c = IdealemCodec(mode="std", block_size=B, num_dict=1, alpha=0.01,
                     rel_tol=0.5, max_count=cmax, backend="numpy")
    blob = c.encode(x)
    i = nb - 1  # hits after the initiating block
    header = len(c.encode(np.zeros(0)))  # fixed header cost
    assert len(blob) == header + 8 * B + int(np.ceil(i / cmax))
    ratio = c.compression_ratio(x, blob)
    assert ratio <= 8 * cmax * B
    assert ratio > 8 * B  # beats the multi-dict limit (Prop 6.1)


def test_prop_6_2_residual_ratio_limit():
    B = 112
    x = _ramp_angles(B * 2000, noise=0.01)
    c = IdealemCodec(mode="residual", block_size=B, num_dict=4, alpha=0.01,
                     rel_tol=0.5, value_range=(0.0, 360.0), backend="numpy")
    ratio = c.compression_ratio(x, c.encode(x))
    limit = (8.0 / 9.0) * B
    assert ratio <= limit + 1e-9
    assert ratio > 0.8 * limit


# ------------------------------------------------------------ property tests
@given(st.integers(min_value=2, max_value=6),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_roundtrip_any_shape(bexp, ndexp, seed):
    B = 2 ** bexp
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, B * 50))
    x = rng.normal(size=n)
    c = IdealemCodec(mode="std", block_size=B, num_dict=2 ** ndexp - 1 or 1,
                     alpha=0.05, rel_tol=0.4, backend="numpy")
    y = c.decode(c.encode(x))
    assert len(y) == len(x)
    # global multiset is drawn from stored blocks + tail: value range preserved
    if n:
        assert y.min() >= x.min() - 1e-12 and y.max() <= x.max() + 1e-12


@given(st.sampled_from(["residual", "delta"]),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_transform_modes_roundtrip(mode, seed):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.normal(0.5, 0.1, size=64 * 20))
    c = IdealemCodec(mode=mode, block_size=64, num_dict=16, alpha=0.05,
                     rel_tol=0.5, backend="numpy")
    y = c.decode(c.encode(x))
    assert len(y) == len(x)
    assert np.all(np.isfinite(y))
