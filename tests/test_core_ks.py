"""KS statistic / p-value / critical distance vs scipy oracles."""
import numpy as np
import pytest
import scipy.special
import scipy.stats

from repro.core.ks import critical_distance, ks_pvalue, ks_statistic
from repro.core.npref import ks_pvalue_np, ks_statistic_np

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


@pytest.mark.parametrize("n1,n2", [(16, 16), (32, 32), (64, 31), (111, 111)])
def test_statistic_matches_scipy(n1, n2):
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = rng.normal(size=n1)
        y = rng.normal(0.2, 1.3, size=n2)
        ref = scipy.stats.ks_2samp(x, y).statistic
        assert np.isclose(float(ks_statistic(x, y)), ref, atol=1e-7)
        assert np.isclose(ks_statistic_np(x, y), ref, atol=1e-12)


def test_pvalue_matches_kolmogorov_sf():
    for n in [8, 16, 64, 256]:
        for d in [0.05, 0.1, 0.3, 0.7]:
            en = n * n / (2 * n)
            ref = scipy.special.kolmogorov(np.sqrt(en) * d)
            assert np.isclose(float(ks_pvalue(d, n, n)), ref, atol=1e-6)
            assert np.isclose(ks_pvalue_np(d, n, n), ref, atol=1e-9)


def test_critical_distance_inverts_pvalue():
    for alpha in [0.01, 0.05, 0.1, 0.2]:
        for n in [16, 32, 112, 255]:
            dc = critical_distance(alpha, n, n)
            # decision boundary: p(dc) == alpha
            assert np.isclose(ks_pvalue_np(dc, n, n), alpha, atol=1e-6)
            # monotone: slightly inside/outside flips the decision
            assert ks_pvalue_np(dc * 0.98, n, n) > alpha
            assert ks_pvalue_np(dc * 1.02, n, n) < alpha


def test_sensitivity_with_n():
    """Paper Fig. 3: same distance, larger n => smaller p-value."""
    ps = [ks_pvalue_np(0.2, n, n) for n in [8, 16, 32, 64, 128, 256]]
    assert all(a > b for a, b in zip(ps, ps[1:]))


@given(
    st.integers(min_value=4, max_value=128),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_statistic_properties(n, seed):
    rng = np.random.default_rng(seed)
    x, y = rng.normal(size=n), rng.normal(size=n)
    d = ks_statistic_np(x, y)
    assert 0.0 <= d <= 1.0
    assert ks_statistic_np(x, x) == 0.0
    # symmetry & permutation invariance
    assert np.isclose(d, ks_statistic_np(y, x), atol=1e-12)
    assert np.isclose(d, ks_statistic_np(rng.permutation(x), y), atol=1e-12)
