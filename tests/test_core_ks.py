"""KS statistic / p-value / critical distance vs scipy oracles."""
import numpy as np
import pytest
import scipy.special
import scipy.stats

from repro.core.ks import critical_distance, ks_pvalue, ks_statistic
from repro.core.npref import ks_pvalue_np, ks_statistic_np

try:  # only the property test needs hypothesis (optional dep)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.mark.parametrize("n1,n2", [(16, 16), (32, 32), (64, 31), (111, 111)])
def test_statistic_matches_scipy(n1, n2):
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = rng.normal(size=n1)
        y = rng.normal(0.2, 1.3, size=n2)
        ref = scipy.stats.ks_2samp(x, y).statistic
        assert np.isclose(float(ks_statistic(x, y)), ref, atol=1e-7)
        assert np.isclose(ks_statistic_np(x, y), ref, atol=1e-12)


def test_pvalue_matches_kolmogorov_sf():
    for n in [8, 16, 64, 256]:
        for d in [0.05, 0.1, 0.3, 0.7]:
            en = n * n / (2 * n)
            ref = scipy.special.kolmogorov(np.sqrt(en) * d)
            assert np.isclose(float(ks_pvalue(d, n, n)), ref, atol=1e-6)
            assert np.isclose(ks_pvalue_np(d, n, n), ref, atol=1e-9)


def test_critical_distance_inverts_pvalue():
    for alpha in [0.01, 0.05, 0.1, 0.2]:
        for n in [16, 32, 112, 255]:
            dc = critical_distance(alpha, n, n)
            # decision boundary: p(dc) == alpha
            assert np.isclose(ks_pvalue_np(dc, n, n), alpha, atol=1e-6)
            # monotone: slightly inside/outside flips the decision
            assert ks_pvalue_np(dc * 0.98, n, n) > alpha
            assert ks_pvalue_np(dc * 1.02, n, n) < alpha


def test_sensitivity_with_n():
    """Paper Fig. 3: same distance, larger n => smaller p-value."""
    ps = [ks_pvalue_np(0.2, n, n) for n in [8, 16, 32, 64, 128, 256]]
    assert all(a > b for a, b in zip(ps, ps[1:]))


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128, 255])
def test_identical_samples_always_accepted(n):
    """d=0 must give p == 1.0 exactly.  The asymptotic series used to
    collapse to 0 for small lambda (sum of zero terms); the small-lambda
    cutoff pins the fix in BOTH implementations, byte-consistently."""
    assert float(ks_pvalue(0.0, n, n)) == 1.0
    assert ks_pvalue_np(0.0, n, n) == 1.0
    # a tiny-but-nonzero distance still lands in the cutoff region
    assert ks_pvalue_np(1e-6, n, n) == 1.0
    # and an identical-block encode can therefore never KS-reject
    for alpha in [0.01, 0.05, 0.2]:
        assert ks_pvalue_np(0.0, n, n) > alpha


def test_small_lambda_agrees_with_scipy_asymp():
    """Across the cutoff: our p-values track scipy's asymptotic two-sample
    KS (mode="asymp") and never resurrect the small-lambda collapse."""
    rng = np.random.default_rng(7)
    for n in [16, 32, 64]:
        x = rng.normal(size=n)
        for d in [0.0, 1.0 / (4 * n), 1.0 / n, 2.0 / n, 0.2, 0.5]:
            p_ours = ks_pvalue_np(d, n, n)
            lam = np.sqrt(n / 2.0) * d  # en = n1*n2/(n1+n2) = n/2
            ref = scipy.special.kolmogorov(lam)
            if lam < 0.1:
                assert p_ours == 1.0  # cutoff region: exact by construction
            else:
                assert np.isclose(p_ours, ref, atol=1e-9)
        # end-to-end cross-check on a realized pair
        ref = scipy.stats.ks_2samp(x, x, method="asymp").pvalue
        assert ks_pvalue_np(ks_statistic_np(x, x), n, n) == pytest.approx(
            ref, abs=1e-12) == 1.0


if HAVE_HYPOTHESIS:
    @given(
        st.integers(min_value=4, max_value=128),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_statistic_properties(n, seed):
        rng = np.random.default_rng(seed)
        x, y = rng.normal(size=n), rng.normal(size=n)
        d = ks_statistic_np(x, y)
        assert 0.0 <= d <= 1.0
        assert ks_statistic_np(x, x) == 0.0
        # symmetry & permutation invariance
        assert np.isclose(d, ks_statistic_np(y, x), atol=1e-12)
        assert np.isclose(
            d, ks_statistic_np(rng.permutation(x), y), atol=1e-12)
