"""Serving front end integration: concurrent multi-tenant traffic over
the real asyncio wire protocol -- byte identity on the golden corpus,
typed quota/rate/backpressure rejections, deadline flushes under an
injected clock, tenant isolation, error mapping, and the control loop's
policy broadcast (ISSUE 10)."""
import asyncio

import numpy as np
import pytest

from conftest import GOLDEN_CASES, golden_codec_kwargs, golden_signal
from repro import api, obs
from repro.core import IdealemCodec
from repro.errors import (NotFoundError, OverloadedError, QuotaExceededError,
                          RateLimitedError, ReproError)
from repro.serve import (FlushPolicy, FrontendClient, ServeFrontend,
                         TenantQuota)
from repro.serve.control import ControlConfig, ControlLoop
from repro.store import pack


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def run(coro):
    return asyncio.run(coro)


def counter_total(name):
    """Sum a counter family across children from the global registry."""
    parsed = obs.parse_prometheus(obs.to_prometheus())
    return sum(v for (n, _items), v in parsed.items() if n == name)


# ----------------------------------------------------- golden byte identity
def test_concurrent_tenants_golden_byte_identity():
    """One tenant per golden-corpus case, all replaying concurrently over
    the wire on direct streams: every concatenated segment stream must be
    byte-identical to a direct ``IdealemSession`` fed the same chunks."""
    cases = list(GOLDEN_CASES)

    async def one_tenant(fe, name):
        kw = golden_codec_kwargs(name)
        cfg = api.CodecConfig(**kw)
        x = golden_signal(name).astype(np.float64)
        shadow = IdealemCodec(**kw).session()
        async with FrontendClient(fe.host, fe.port, f"g-{name}") as c:
            await c.open("s", cfg)
            segs, ref, i = [], [], 0
            rng = np.random.default_rng(hash(name) % 2**31)
            while i < len(x):
                step = int(rng.integers(5, 700))
                segs.append((await c.feed("s", x[i:i + step])).segment)
                ref.append(shadow.feed(x[i:i + step]))
                i += step
            segs.append((await c.close_stream("s")).segment)
            ref.append(shadow.finish())
            wire = b"".join(segs)
        assert wire == b"".join(ref), name
        # and the wire stream decodes to the same samples as the one-shot
        codec = IdealemCodec(**kw)
        np.testing.assert_array_equal(codec.decode(wire),
                                      codec.decode(codec.encode(x)))

    async def main():
        async with ServeFrontend(run_control=False) as fe:
            await asyncio.gather(*(one_tenant(fe, n) for n in cases))

    run(main())


# ------------------------------------------------------------- admission
def test_stream_quota_rejection_is_typed_and_counted():
    before = counter_total("repro_frontend_rejections_total")

    async def main():
        async with ServeFrontend(
                default_quota=TenantQuota(max_streams=1),
                run_control=False) as fe:
            cfg = api.CodecConfig(backend="numpy")
            async with FrontendClient(fe.host, fe.port, "tq") as c:
                await c.open("a", cfg)
                with pytest.raises(QuotaExceededError):
                    await c.open("b", cfg)
                # raw status check: 429 + retry hint semantics
                status, _h, _p = await c.request_raw(
                    "POST", "/v1/open",
                    b'{"stream_id": "c"}\n')
                assert status == 429

    run(main())
    assert counter_total("repro_frontend_rejections_total") >= before + 2


def test_rate_limit_carries_retry_after():
    clock = FakeClock()

    async def main():
        async with ServeFrontend(
                clock=clock, tick_interval_s=None, run_control=False,
                default_quota=TenantQuota(max_bytes_per_s=800.0,
                                          burst_bytes=800.0)) as fe:
            cfg = api.CodecConfig(backend="numpy")
            async with FrontendClient(fe.host, fe.port, "rl") as c:
                await c.open("s", cfg)
                await c.feed("s", np.zeros(100))       # drains the bucket
                with pytest.raises(RateLimitedError) as ei:
                    await c.feed("s", np.zeros(100))
                assert ei.value.retry_after_s == pytest.approx(1.0)
                # a request that can NEVER fit the bucket is a quota error
                with pytest.raises(QuotaExceededError):
                    await c.feed("s", np.zeros(200))
                clock.advance(2.0)                     # bucket refills
                await c.feed("s", np.zeros(100))

    run(main())


def test_per_tenant_staged_block_quota():
    async def main():
        policy = FlushPolicy(max_batch_blocks=10**6,
                             max_batch_streams=10**6, max_age_s=None)
        async with ServeFrontend(
                policy=policy, run_control=False, tick_interval_s=None,
                max_staged_blocks_total=10**6,
                default_quota=TenantQuota(max_staged_blocks=4)) as fe:
            cfg = api.CodecConfig(block_size=32)
            async with FrontendClient(fe.host, fe.port, "sq") as c:
                await c.open("s", cfg, coalesce=True)
                await c.feed("s", np.zeros(4 * 32))    # stages 4 blocks
                with pytest.raises(QuotaExceededError):
                    await c.feed("s", np.zeros(32))    # the 5th

    run(main())


def test_global_backpressure_force_flushes_then_503():
    async def main():
        hold = FlushPolicy(max_batch_blocks=10**6, max_batch_streams=10**6,
                           max_age_s=None)
        # budget of 4 blocks across ALL tenants
        async with ServeFrontend(policy=hold, run_control=False,
                                 tick_interval_s=None,
                                 max_staged_blocks_total=4) as fe:
            cfg = api.CodecConfig(block_size=32)
            async with FrontendClient(fe.host, fe.port, "bp-a") as a, \
                    FrontendClient(fe.host, fe.port, "bp-b") as b:
                await a.open("s", cfg, coalesce=True)
                await b.open("s", cfg, coalesce=True)
                await a.feed("s", np.zeros(4 * 32))    # saturates budget
                before = counter_total(
                    "repro_frontend_backpressure_flushes_total")
                # b's feed crosses the budget: the front end force-flushes
                # a's cohort (backpressure FEEDS the flush policy) and then
                # admits b
                r = await b.feed("s", np.ones(32))
                assert r.stream_id == "s"
                assert counter_total(
                    "repro_frontend_backpressure_flushes_total") == before + 1
                # a's flushed segment is buffered for its next collect
                got = (await a.collect("s")).segment
                assert got != b""
            # budget 0: relief is impossible -> typed 503
            fe.max_staged_blocks_total = 0
            async with FrontendClient(fe.host, fe.port, "bp-c") as c:
                await c.open("s", cfg, coalesce=True)
                with pytest.raises(OverloadedError):
                    await c.feed("s", np.zeros(32))
                status, _h, _p = await c.request_raw(
                    "POST", "/v1/feed",
                    (api_feed_body("s", np.zeros(32))))
                assert status == 503

    run(main())


def api_feed_body(stream_id, arr):
    import json
    return (json.dumps(
        api.CompressRequest(stream_id, arr).to_json()) + "\n").encode()


# -------------------------------------------------------- deadline flushes
def test_deadline_flush_under_injected_clock():
    clock = FakeClock()

    async def main():
        policy = FlushPolicy(max_batch_blocks=10**6, max_batch_streams=10**6,
                             max_age_s=5.0)
        async with ServeFrontend(policy=policy, clock=clock,
                                 tick_interval_s=None,
                                 run_control=False) as fe:
            cfg = api.CodecConfig(block_size=32)
            x = np.sin(np.linspace(0, 30, 8 * 32))
            async with FrontendClient(fe.host, fe.port, "dl") as c:
                await c.open("s", cfg, coalesce=True)
                r = await c.feed("s", x)
                assert r.segment == b""               # staged, not flushed
                fe.tick()                              # age 0: still held
                assert (await c.collect("s")).segment == b""
                clock.advance(6.0)                     # past max_age_s
                fe.tick()                              # deadline trips
                seg = (await c.collect("s")).segment
                assert seg != b""
                seg += (await c.close_stream("s")).segment
            codec = IdealemCodec.from_config(cfg)
            np.testing.assert_array_equal(
                codec.decode(seg), codec.decode(codec.encode(x)))

    run(main())


# ------------------------------------------------------------- decode path
def test_decode_roundtrip_and_tenant_isolation():
    async def main():
        async with ServeFrontend(run_control=False,
                                 decode_backend="numpy") as fe:
            kw = dict(mode="std", block_size=32, num_dict=15,
                      backend="numpy")
            codec = IdealemCodec(**kw)
            x = np.sin(np.linspace(0, 50, 64 * 32))
            stream = codec.encode(x)
            ref = codec.decode(stream)
            async with FrontendClient(fe.host, fe.port, "iso-a") as a, \
                    FrontendClient(fe.host, fe.port, "iso-b") as b:
                await a.attach("st", pack(stream))
                rr = await a.decode("st", 3, 11)
                np.testing.assert_allclose(
                    np.asarray(rr.values).ravel(), ref[3 * 32:11 * 32])
                # tenant b cannot see tenant a's store
                with pytest.raises((NotFoundError, ReproError, KeyError)):
                    await b.decode("st", 0, 1)
                status, _h, _p = await b.request_raw(
                    "POST", "/v1/decode",
                    b'{"store_id": "st", "start_block": 0,'
                    b' "stop_block": 1}\n')
                assert status == 404

    run(main())


# ---------------------------------------------------------- wire protocol
def test_json_lines_batched_feed():
    async def main():
        async with ServeFrontend(run_control=False) as fe:
            cfg = api.CodecConfig(backend="numpy", block_size=32)
            async with FrontendClient(fe.host, fe.port, "jl") as c:
                await c.open("s", cfg)
                x = np.sin(np.linspace(0, 9, 96))
                docs = [api.CompressRequest("s", x[:32]).to_json(),
                        api.CompressRequest("ghost", x[32:64]).to_json(),
                        api.CompressRequest("s", x[32:96]).to_json()]
                outs = await c.post_lines("/v1/feed", docs)
                assert len(outs) == 3
                assert outs[0]["stream_id"] == "s"
                assert outs[1]["error"]["code"] == "not_found"  # per line
                assert outs[2]["stream_id"] == "s"
                fin = await c.close_stream("s")
            wire = (b"".join(
                api.FeedResult.from_json(o).segment
                for o in (outs[0], outs[2])) + fin.segment)
            sess = IdealemCodec.from_config(cfg).session()
            direct = sess.feed(x[:32]) + sess.feed(x[32:96]) + sess.finish()
            assert wire == direct

    run(main())


def test_protocol_error_mapping():
    async def main():
        async with ServeFrontend(run_control=False) as fe:
            async with FrontendClient(fe.host, fe.port, "em") as c:
                for path, body, want in [
                        ("/v1/nope", b"{}\n", 404),
                        ("/v1/open", b"not json\n", 400),
                        ("/v1/open", b'{"stream_id": ""}\n', 400),
                        ("/v1/feed", b'{"stream_id": "missing", "samples":'
                         b' {"dtype": "<f8", "b64": ""}}\n', 404),
                        ("/v1/open", b'{"stream_id": "s", "bogus": 1}\n',
                         400)]:
                    status, _h, payload = await c.request_raw(
                        "POST", path, body)
                    assert status == want, (path, payload)
                # missing tenant header
                c.tenant = ""
                status, _h, payload = await c.request_raw(
                    "POST", "/v1/open", b'{"stream_id": "s"}\n')
                assert status == 400 and b"x-tenant" in payload
                c.tenant = "em"
                status, _h, payload = await c.request_raw("GET", "/healthz")
                assert status == 200

    run(main())


# ------------------------------------------------------------ control loop
def test_control_loop_broadcasts_policy_to_tenants():
    """Live decode traffic populates the real stage histograms; a
    hair-trigger control loop must then move the FlushPolicy and the
    front end must broadcast it into every tenant's services."""

    async def main():
        policy = FlushPolicy(max_batch_blocks=1024, max_batch_streams=1,
                             max_age_s=0.4)
        loop = ControlLoop(policy=policy, config=ControlConfig(
            target_p99_s=1e-9, min_observations=1, min_age_s=0.2),
            on_reprobe=lambda: None)
        async with ServeFrontend(policy=policy, control=loop,
                                 control_interval_s=0.0,
                                 tick_interval_s=None,
                                 decode_backend="numpy") as fe:
            kw = dict(mode="std", block_size=32, num_dict=15,
                      backend="numpy")
            codec = IdealemCodec(**kw)
            x = np.sin(np.linspace(0, 50, 64 * 32))
            async with FrontendClient(fe.host, fe.port, "cl") as c:
                await c.attach("st", pack(codec.encode(x)))
                for k in range(4):     # flushes via max_batch_streams=1
                    await c.decode("st", k, k + 2, request_id=f"r{k}")
                fe.tick()
                assert fe.policy.max_batch_blocks == 512  # halved
                ctl = await c.control()
                assert ctl["policy"]["max_batch_blocks"] == 512
            tenant = fe.tenants.get("cl", create=False)
            assert tenant.policy.max_batch_blocks == 512
            assert tenant.decomp.policy.max_batch_blocks == 512

    run(main())
