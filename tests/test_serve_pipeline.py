"""Pipelined serving decode (ISSUE 5): stage ordering, byte identity of
pipelined vs alternating flushes, quarantine across stage boundaries, and
the measured backend autotuner.

The load-bearing properties: (1) with ``pipeline_depth`` 2 the service
really does run batch N+1's host stages while batch N's reconstruct is in
flight -- proven with a deterministic lazy executor whose futures only
execute when collected, so the recorded stage order is the pipeline's,
not a thread scheduler's; (2) however deep the pipeline and whichever
backend reconstructs, every answer is byte-identical to the alternating
depth-1 flush (itself pinned byte-identical to ``decode_stream`` slices);
(3) a store failing in ANY stage fails alone, in ``last_errors``, without
poisoning batches ahead of or behind it in the pipeline.
"""
import json
import time

import pytest

from conftest import GOLDEN_CASES, GOLDEN_BLOCK, golden_codec_kwargs, \
    golden_signal
from repro.core import IdealemCodec, StreamFormatError
from repro.core import decode as decode_mod
from repro.core import stream as stream_mod
from repro.core.stream import decode_stream
from repro.serve import (DecompressionService, FlushPolicy, StageFuture,
                         StagePipeline, SyncExecutor, ThreadStageExecutor)
from repro.store import Container, pack

BACKENDS = ["numpy", "jax", "pallas"]
FEED = 100


def _session_stream(name, feed=FEED):
    codec = IdealemCodec(**golden_codec_kwargs(name))
    x = golden_signal(name)
    s = codec.session()
    segs = [s.feed(x[lo:lo + feed]) for lo in range(0, len(x), feed)]
    segs.append(s.finish())
    return b"".join(segs)


_PREPPED = {}


def _prepped(name):
    if name not in _PREPPED:
        blob = _session_stream(name)
        _PREPPED[name] = (pack(blob), decode_stream(blob))
    return _PREPPED[name]


# ----------------------------------------------- deterministic fake executor
class LazyFuture:
    """Runs its stage only when collected -- 'in flight' is a visible,
    test-controlled state instead of a thread race."""

    def __init__(self, fn, args, log, tag):
        self._fn, self._args, self._log, self._tag = fn, args, log, tag

    def result(self):
        self._log.append(("execute", self._tag))
        return self._fn(*self._args)


class LazyExecutor:
    def __init__(self, log):
        self.log = log
        self._n = 0

    def submit(self, fn, *args):
        self._n += 1
        self.log.append(("submit", self._n))
        return LazyFuture(fn, args, self.log, self._n)

    def shutdown(self):
        self.log.append(("shutdown", None))


# ------------------------------------------------------------ stage ordering
def test_plan_of_next_batch_runs_while_reconstruct_in_flight():
    """The pipeline invariant itself: with depth 2, batch 2's plan+gather
    stages run BEFORE batch 1's reconstruct executes (batch 1 is in
    flight, lazily run only when batch 2's flush collects it)."""
    packed, y = _prepped("std_D32")
    log = []
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy", executor=LazyExecutor(log),
        trace=lambda stage, seq: log.append((stage, seq)))
    svc.attach("s", packed)

    assert svc.submit("a", "s", 0, 2) is None
    r1 = svc.submit("b", "s", 2, 4)        # trips flush 1
    assert r1 == {} and svc.inflight == 1  # batch 1 parked, not answered
    assert svc.submit("c", "s", 4, 6) is None
    r2 = svc.submit("d", "s", 6, 8)        # trips flush 2, collects batch 1
    assert set(r2) == {"a", "b"}
    assert set(svc.drain()) == {"c", "d"}
    assert svc.inflight == 0

    i = log.index
    # batch 2's host stages precede batch 1's reconstruct execution
    assert i(("plan", 2)) < i(("execute", 1))
    assert i(("gather", 2)) < i(("execute", 1))
    # and each batch walks plan -> gather -> reconstruct -> emit in order
    for seq in (1, 2):
        assert (i(("plan", seq)) < i(("gather", seq))
                < i(("reconstruct", seq)) < i(("emit", seq)))
    assert svc.stats["inflight_peak"] == 2


def test_depth1_is_the_alternating_path():
    """pipeline_depth 1 (the default policy): a flush answers its own
    batch synchronously and nothing is ever left in flight."""
    packed, y = _prepped("std_D32")
    log = []
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2), backend="numpy",
        trace=lambda stage, seq: log.append((stage, seq)))
    svc.attach("s", packed)
    assert svc.submit("a", "s", 0, 2) is None
    out = svc.submit("b", "s", 2, 4)
    assert set(out) == {"a", "b"} and svc.inflight == 0
    assert log == [("plan", 1), ("gather", 1), ("reconstruct", 1),
                   ("emit", 1)]
    assert svc.drain() == {}
    B = GOLDEN_BLOCK
    assert out["a"].tobytes() == y[0:2 * B].tobytes()


# -------------------------------------------------- pipelined == alternating
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_pipelined_flushes_byte_identical(name, backend):
    """Every golden case x backend: a depth-3 pipelined service (real
    worker thread) answers every request byte-identically to the
    alternating depth-1 service and to the sequential decode's slices."""
    packed, y = _prepped(name)
    nb = Container(packed).total_blocks(0)
    B = GOLDEN_BLOCK
    reqs = [(i, min(i + 3, nb)) for i in range(0, nb, 3)] + [(0, nb)]

    def run(depth):
        svc = DecompressionService(
            policy=FlushPolicy(max_batch_streams=3, pipeline_depth=depth),
            backend=backend)
        svc.attach("s", packed)
        out = {}
        for k, (i, j) in enumerate(reqs):
            got = svc.submit(f"r{k}", "s", i, j)
            if got:
                out.update(got)
        out.update(svc.close())
        assert not svc.last_errors
        return out

    alt, pip = run(1), run(3)
    assert set(alt) == set(pip) == {f"r{k}" for k in range(len(reqs))}
    for k, (i, j) in enumerate(reqs):
        want = y[i * B:j * B].tobytes()
        assert alt[f"r{k}"].tobytes() == want, (name, backend, k)
        assert pip[f"r{k}"].tobytes() == want, (name, backend, k)


# ------------------------------------------------- quarantine across stages
def _corrupt_copy(packed: bytes) -> bytes:
    """Corrupt the first decision byte of a mid-stream chunk body (0xFF =
    bogus overwrite prefix => the walk overruns the indexed chunk length);
    attach-time validation still passes (footer CRC covers the index)."""
    store = Container(packed)
    off = (int(store._cols["offset"][store.n_chunks - 2])
           + stream_mod._HDR.size)
    bad = bytearray(packed)
    bad[off] = 0xFF
    return bytes(bad)


def test_plan_failure_quarantines_store_mid_pipeline():
    """A store whose PLAN stage raises while another batch is in flight
    fails alone and immediately (last_errors at flush time); neither the
    in-flight batch nor healthy stores of the same batch are poisoned."""
    packed, y = _prepped("std_D32")
    nb = Container(packed).total_blocks(0)
    B = GOLDEN_BLOCK
    log = []
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy", executor=LazyExecutor(log))
    svc.attach("good", packed)
    svc.attach("bad", _corrupt_copy(packed))

    assert svc.submit("g1", "good", 0, 2) is None
    assert svc.submit("g2", "good", 2, 4) == {}   # batch 1 in flight
    assert svc.submit("rb", "bad", 0, nb) is None  # walks the corrupt chunk
    r2 = svc.submit("rg", "good", 3, 7)            # trips flush 2
    # the bad store was quarantined when batch 2 was CUT -- batch 1 had
    # not reconstructed yet
    assert isinstance(svc.last_errors["rb"], StreamFormatError)
    assert set(r2) == {"g1", "g2"}
    rest = svc.close()
    assert set(rest) == {"rg"}
    assert rest["rg"].tobytes() == y[3 * B:7 * B].tobytes()
    assert svc.stats["failed_requests"] == 1


def test_reconstruct_failure_quarantines_unit(monkeypatch):
    """A reconstruct-stage failure surfaces at emit -- only the failing
    unit's requests, with every other unit of the batch still answered."""
    std_packed, y_std = _prepped("std_D32")
    delta_packed, y_delta = _prepped("delta_D32")
    B = GOLDEN_BLOCK
    real = decode_mod.reconstruct

    def boom(plan, backend="numpy"):
        if plan.mode == decode_mod.MODE_DELTA:
            raise RuntimeError("device lost")
        return real(plan, backend=backend)

    monkeypatch.setattr(decode_mod, "reconstruct", boom)
    log = []
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy", executor=LazyExecutor(log))
    svc.attach("std", std_packed)
    svc.attach("delta", delta_packed)
    assert svc.submit("rs", "std", 0, 4) is None
    assert svc.submit("rd", "delta", 0, 4) == {}  # one flush, two units
    out = svc.close()
    assert set(out) == {"rs"}
    assert out["rs"].tobytes() == y_std[: 4 * B].tobytes()
    assert isinstance(svc.last_errors["rd"], RuntimeError)
    assert svc.stats["failed_requests"] == 1
    assert svc.stats["dispatches"] == 1  # only the healthy unit dispatched


def test_dead_executor_fails_whole_batch():
    """If the stage executor itself dies, every request of the batch is
    reported in last_errors -- never silently dropped."""

    class ExplodingExecutor:
        def submit(self, fn, *args):
            fut = StageFuture()
            fut.set_exception(RuntimeError("executor died"))
            return fut

        def shutdown(self):
            pass

    packed, _ = _prepped("std_D32")
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=2),
                               backend="numpy",
                               executor=ExplodingExecutor())
    svc.attach("s", packed)
    svc.submit("a", "s", 0, 2)
    out = svc.submit("b", "s", 2, 4)
    assert out == {}
    assert isinstance(svc.last_errors["a"], RuntimeError)
    assert isinstance(svc.last_errors["b"], RuntimeError)
    assert svc.stats["failed_requests"] == 2


def test_completed_batches_not_stranded_without_new_traffic():
    """Once traffic stops, a parked batch whose reconstruct has finished
    must come out of poll() / an empty flush() -- not only drain()."""
    packed, y = _prepped("std_D32")
    B = GOLDEN_BLOCK
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy")
    svc.attach("s", packed)
    svc.submit("a", "s", 0, 2)
    assert svc.submit("b", "s", 2, 4) == {}   # batch parked in flight
    # the worker thread finishes promptly; poll (the timer hook) must
    # deliver without a new flush being cut
    deadline = time.monotonic() + 5.0
    out = None
    while out is None and time.monotonic() < deadline:
        out = svc.poll()
    assert out is not None and set(out) == {"a", "b"}
    assert out["a"].tobytes() == y[: 2 * B].tobytes()
    assert svc.flush() == {} and svc.poll() is None  # nothing left

    # same, via an explicit empty flush
    svc.submit("c", "s", 4, 6)
    assert svc.submit("d", "s", 6, 8) == {}
    deadline = time.monotonic() + 5.0
    out = {}
    while not out and time.monotonic() < deadline:
        out = svc.flush()
    assert set(out) == {"c", "d"}
    svc.close()


def test_closed_service_rejects_new_work():
    """close() shuts the executor down; later submits must raise instead
    of queueing onto a dead worker (which would hang forever) -- but a
    second close() is a safe no-op."""
    packed, _ = _prepped("std_D32")
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy")
    svc.attach("s", packed)
    svc.submit("a", "s", 0, 2)
    out = svc.close()
    assert set(out) == {"a"}
    assert svc.close() == {}  # idempotent
    with pytest.raises(RuntimeError):
        svc.submit("b", "s", 0, 2)
    with pytest.raises(RuntimeError):
        svc.flush()


def test_duplicate_id_rejected_while_batch_in_flight():
    """A request id stays reserved while its batch is in flight: reusing
    it would silently collide in the answer dict at emit."""
    packed, _ = _prepped("std_D32")
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="numpy", executor=LazyExecutor([]))
    svc.attach("s", packed)
    svc.submit("a", "s", 0, 2)
    assert svc.submit("b", "s", 2, 4) == {}  # batch with 'a','b' in flight
    with pytest.raises(KeyError):
        svc.submit("a", "s", 4, 6)
    out = svc.drain()
    assert set(out) == {"a", "b"}
    svc.submit("a", "s", 4, 6)               # delivered: id free again


def test_cold_autotune_probe_quiesces_pipeline(monkeypatch):
    """With backend="auto" at depth 2, a COLD (mode, dtype, bucket)
    combination must drain the in-flight batch before the timing probe
    runs (an overlapping reconstruct would skew the measurements), and
    the drained answers ride out with the same flush."""
    packed, y = _prepped("std_D32")
    B = GOLDEN_BLOCK
    decode_mod.reset_autotune()
    log = []
    real_probe = decode_mod._probe_autotune

    def spy_probe(*args, **kw):
        log.append(("probe",))
        return real_probe(*args, **kw)

    monkeypatch.setattr(decode_mod, "_probe_autotune", spy_probe)
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=2, pipeline_depth=2),
        backend="auto", executor=LazyExecutor(log))
    svc.attach("s", packed)
    svc.submit("a", "s", 0, 2)
    r1 = svc.submit("b", "s", 2, 4)   # flush 1: cold probe, no in-flight yet
    assert ("probe",) in log
    n_probes = log.count(("probe",))
    assert r1 == {}                   # batch 1 parked
    decode_mod.reset_autotune()       # force the NEXT flush cold again
    svc.submit("c", "s", 4, 6)
    r2 = svc.submit("d", "s", 6, 8)   # flush 2: cold + batch 1 in flight
    # the in-flight batch was executed (drained) BEFORE the new probe ran
    i_exec1 = log.index(("execute", 1))
    i_probe2 = len(log) - 1 - log[::-1].index(("probe",))
    assert log.count(("probe",)) == n_probes + 1
    assert i_exec1 < i_probe2
    # and its answers were not swallowed: they ride out with flush 2
    assert set(r2) == {"a", "b"}
    out = svc.close()
    assert set(out) == {"c", "d"}
    for rid, i, j in [("a", 0, 2), ("b", 2, 4)]:
        assert r2[rid].tobytes() == y[i * B:j * B].tobytes()
    for rid, i, j in [("c", 4, 6), ("d", 6, 8)]:
        assert out[rid].tobytes() == y[i * B:j * B].tobytes()


def test_auto_resolves_at_merged_dispatch_size(monkeypatch):
    """The autotuner must be consulted at the MERGED group's total block
    count (the real dispatch), not at per-request sizes."""
    packed, _ = _prepped("std_D32")
    seen = []
    real = decode_mod.resolve_backend

    def spy(backend, mode, dtype, nb, value_range=None, block_size=32):
        if backend == "auto":
            seen.append(nb)
        return real("numpy", mode, dtype, nb, value_range, block_size)

    monkeypatch.setattr(decode_mod, "resolve_backend", spy)
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=4))
    svc.attach("s", packed)
    for k, (i, j) in enumerate([(0, 2), (4, 6), (8, 10)]):
        svc.submit(f"r{k}", "s", i, j)
    out = svc.submit("r3", "s", 12, 14)
    assert len(out) == 4
    assert seen == [8]  # one resolution, at 4 requests x 2 blocks


# ------------------------------------------------------- pipeline primitives
def test_stage_pipeline_window_and_error_delivery():
    # lazy (never "done") futures: the depth window is what forces
    # collection, so the bound is observable
    pipe = StagePipeline(LazyExecutor([]), depth=2)
    assert pipe.push("m1", lambda: 1) == []        # within the window
    assert pipe.inflight == 1
    done = pipe.push("m2", lambda: 2)              # bumps m1 out
    assert done == [("m1", 1, None)]
    (meta, value, exc), = pipe.drain()
    assert (meta, value) == ("m2", 2) and exc is None

    def boom():
        raise ValueError("stage died")

    pipe.push("m3", boom)
    (meta, value, exc), = pipe.drain()
    assert meta == "m3" and value is None
    assert isinstance(exc, ValueError)
    with pytest.raises(ValueError):
        StagePipeline(SyncExecutor(), depth=0)
    with pytest.raises(ValueError):
        FlushPolicy(pipeline_depth=0)


def test_stage_pipeline_sync_executor_delivers_immediately():
    """A completed batch never waits for the window: SyncExecutor futures
    are done at push time, so even depth 2 returns them right away."""
    pipe = StagePipeline(SyncExecutor(), depth=2)
    assert pipe.push("m1", lambda: 1) == [("m1", 1, None)]
    assert pipe.inflight == 0


def test_thread_executor_runs_off_thread():
    import threading
    ex = ThreadStageExecutor()
    try:
        ident = ex.submit(lambda: threading.get_ident()).result()
        assert ident != threading.get_ident()
        with pytest.raises(RuntimeError):
            ex.submit(lambda: (_ for _ in ()).throw(
                RuntimeError("worker"))).result()
        assert ex.submit(lambda a, b: a + b, 2, 3).result() == 5
    finally:
        ex.shutdown()


# ------------------------------------------------------- measured autotuner
@pytest.fixture
def autotune_file(tmp_path, monkeypatch):
    path = tmp_path / "decode_autotune.json"
    monkeypatch.setenv("REPRO_DECODE_AUTOTUNE", str(path))
    decode_mod.reset_autotune()
    decode_mod.reset_decode_stats()
    yield path
    decode_mod.reset_autotune()


def test_autotune_cold_probe_then_warm_hit(autotune_file):
    b1 = decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 10)
    st = decode_mod.decode_stats()
    assert st["autotune_probes"] == 1 and st["autotune_hits"] == 0
    assert b1 in decode_mod.BACKENDS
    # the probe persisted a versioned cache
    doc = json.loads(autotune_file.read_text())
    assert doc["version"] == decode_mod.AUTOTUNE_VERSION
    assert len(doc["entries"]) == 1
    # same bucket: warm hit, no new probe; same choice
    b2 = decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 33)
    st = decode_mod.decode_stats()
    assert (b2, st["autotune_probes"], st["autotune_hits"]) == (b1, 1, 1)
    # a different bucket probes again
    decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 900)
    assert decode_mod.decode_stats()["autotune_probes"] == 2
    assert len(decode_mod.autotune_choices()) == 2
    assert decode_mod.decode_stats()["autotune_choices"] \
        == decode_mod.autotune_choices()


def test_autotune_persisted_choice_honored_without_probing(autotune_file):
    """A persisted cache IS the routing table: backend="auto" follows it
    even when the probe would have chosen differently."""
    key = decode_mod._autotune_key(decode_mod.MODE_STD, "f8", 10)
    autotune_file.write_text(json.dumps({
        "version": decode_mod.AUTOTUNE_VERSION,
        "entries": {key: {"backend": "pallas", "times_us": {}}}}))
    got = decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 10)
    st = decode_mod.decode_stats()
    assert (got, st["autotune_probes"], st["autotune_hits"]) \
        == ("pallas", 0, 1)


def test_autotune_version_mismatch_reprobes(autotune_file):
    key = decode_mod._autotune_key(decode_mod.MODE_STD, "f8", 10)
    autotune_file.write_text(json.dumps({
        "version": decode_mod.AUTOTUNE_VERSION + 1,
        "entries": {key: {"backend": "pallas", "times_us": {}}}}))
    with pytest.raises(decode_mod.AutotuneCacheError):
        decode_mod.load_autotune(str(autotune_file), strict=True)
    decode_mod.reset_autotune()
    got = decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 10)
    st = decode_mod.decode_stats()
    assert st["autotune_probes"] == 1 and st["autotune_hits"] == 0
    assert got in decode_mod.BACKENDS
    # the re-probe rewrote the cache at the CURRENT version
    doc = json.loads(autotune_file.read_text())
    assert doc["version"] == decode_mod.AUTOTUNE_VERSION


def test_autotune_unwritable_cache_path_is_non_fatal(tmp_path, monkeypatch):
    """Persistence is an optimization: an unwritable cache path must not
    fail the resolution (and through it the serving flush)."""
    monkeypatch.setenv("REPRO_DECODE_AUTOTUNE",
                       str(tmp_path / "no" / "such" / "dir" / "at.json"))
    decode_mod.reset_autotune()
    decode_mod.reset_decode_stats()
    got = decode_mod.resolve_backend("auto", decode_mod.MODE_STD, "f8", 10)
    assert got in decode_mod.BACKENDS
    assert decode_mod.decode_stats()["autotune_probes"] == 1
    decode_mod.reset_autotune()


def test_autotune_corrupt_cache_reprobes(autotune_file):
    autotune_file.write_bytes(b"\xffnot json at all")
    with pytest.raises(decode_mod.AutotuneCacheError):
        decode_mod.load_autotune(str(autotune_file), strict=True)
    decode_mod.reset_autotune()
    decode_mod.resolve_backend("auto", decode_mod.MODE_DELTA, "f8", 10)
    assert decode_mod.decode_stats()["autotune_probes"] == 1


def test_reconstruct_auto_routes_through_autotune(autotune_file):
    """reconstruct(backend="auto") resolves per plan and stays
    byte-identical to the host path whatever the measured choice."""
    plan = decode_mod._probe_plan(decode_mod.MODE_DELTA, "f8", None, 16)
    want = decode_mod.reconstruct(plan, backend="numpy")
    got = decode_mod.reconstruct(plan, backend="auto")
    assert got.tobytes() == want.tobytes()
    assert decode_mod.decode_stats()["autotune_probes"] == 1


def test_service_default_backend_is_auto():
    assert DecompressionService().backend == "auto"
    with pytest.raises(ValueError):
        DecompressionService(backend="gpu")
