"""Multi-pod dry-run path validation (CI-scale).

Runs the real dryrun module in a subprocess (it must own the XLA device-count
flag) with reduced configs on the 512-device multi-pod mesh: lowering,
SPMD compile, cost/collective analysis and artifact writing all execute.
The FULL-config sweep is scripts/run_dryrun_sweep.sh (EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys

import pytest

ARCH_CASES = [
    ("granite-3-8b", "train_4k", "multi"),
    ("granite-moe-1b-a400m", "train_4k", "single"),
    ("rwkv6-3b", "long_500k", "multi"),
    ("zamba2-1.2b", "decode_32k", "single"),
]


@pytest.mark.parametrize("arch,shape,mesh", ARCH_CASES)
def test_dryrun_smoke_cell(arch, shape, mesh, tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", arch, "--shape", shape, "--mesh", mesh,
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=os.getcwd())
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    tag = f"{arch.replace('-', '_').replace('.', '_')}_{shape}_{mesh}"
    rec = json.load(open(tmp_path / f"{tag}.json"))
    assert rec["status"] == "ok"
    assert rec["flops_per_chip"] > 0
    assert rec["bytes_per_chip"] > 0
    assert rec["chips"] == (512 if mesh == "multi" else 256)
