"""Serving engine, prefill/decode consistency, data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import IdealemCodec
from repro.data import Prefetcher, compress_channels, synthetic
from repro.models import lm
from repro.serve import ServeEngine


def test_decode_matches_forward_dense():
    """Token-by-token decode logits == teacher-forced forward logits."""
    cfg = get_config("granite_3_8b", smoke=True)
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S))
    toks = jnp.asarray(toks, jnp.int32)
    # teacher-forced logits
    x, _ = lm.forward_hidden(params, toks, cfg)
    from repro.models.layers import unembed
    full_logits = unembed(params["embed"], x, cfg)
    # decode loop
    cache = lm.init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(params, cache, toks[:, t:t + 1], cfg)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), atol=0.75, rtol=0.1)
    # argmax agreement is the serving-level contract
    agree = np.mean(np.argmax(np.asarray(dec_logits), -1)
                    == np.argmax(np.asarray(full_logits), -1))
    assert agree > 0.9


@pytest.mark.parametrize("arch", ["rwkv6_3b", "zamba2_1_2b"])
def test_decode_matches_forward_recurrent(arch):
    """SSM/RWKV recurrence must agree with the chunked training path.

    Run in f32: at bf16 an UNTRAINED model's near-uniform logits flip argmax
    on rounding noise, which says nothing about the recurrence math."""
    cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32)
    params = lm.init_params(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    x, _ = lm.forward_hidden(params, toks, cfg)
    from repro.models.layers import unembed
    full_logits = unembed(params["embed"], x, cfg)
    cache = lm.init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        lg, cache = lm.decode_step(params, cache, toks[:, t:t + 1], cfg)
        outs.append(lg)
    dec = np.asarray(jnp.concatenate(outs, axis=1))
    ref = np.asarray(full_logits)
    agree = np.mean(np.argmax(dec, -1) == np.argmax(ref, -1))
    assert agree > 0.9, f"decode/train divergence: argmax agree {agree}"


def test_serve_engine_generates():
    cfg = get_config("granite_3_8b", smoke=True)
    params = lm.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_seq=64)
    prompts = np.ones((2, 4), dtype=np.int32)
    out = eng.generate(prompts, 8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(out, out2)


def test_prefetcher_preserves_order():
    it = iter(range(20))
    pf = Prefetcher(it, prefetch=4, place=lambda x: x * 2)
    assert list(pf) == [2 * i for i in range(20)]


def test_compressed_telemetry_pipeline():
    chans = np.stack([synthetic.pmu_magnitude(32 * 200, seed=s)
                      for s in range(4)])
    codec = IdealemCodec(mode="std", block_size=32, num_dict=255, alpha=0.01,
                         rel_tol=0.5, backend="numpy")
    blobs, ratio = compress_channels(chans, codec)
    assert ratio > 10
    for i, b in enumerate(blobs):
        y = codec.decode(b)
        assert len(y) == chans.shape[1]
