"""Error-bounded mode (DESIGN.md Sec. 11): the pointwise demotion gate.

The contract: with ``error_bound=t``, every decoded sample differs from its
original by at most ``t`` (circular distance when a wrapping ``value_range``
is set), because would-be hits whose stored dictionary row violates the
bound are demoted to misses and FLAG_EB decode skips the hit permutation.
Property-tested with hypothesis when installed, plus a deterministic seeded
sweep that always runs.
"""
import numpy as np
import pytest

from conftest import mixed_signal
from repro.core import IdealemCodec
from repro.core.npref import encode_decisions_np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

BACKENDS = ["numpy", "jax", "pallas"]
# f32 payload storage rounds on top of the float64 gate decision
_F32_SLOP = 1e-4


def _err(x, y, value_range=None):
    d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
    if value_range is not None:
        w = value_range[1] - value_range[0]
        d = np.minimum(d, w - d)
    return float(np.max(d)) if len(d) else 0.0


def _check(x, mode, bound, backend="numpy", value_range=None, **kw):
    codec = IdealemCodec(mode=mode, block_size=16, num_dict=32, alpha=0.05,
                         value_range=value_range, error_bound=bound,
                         backend=backend, **kw)
    blob = codec.encode(x)
    y = codec.decode(blob)
    assert _err(x, y, value_range) <= bound + _F32_SLOP * max(bound, 1.0)
    return codec, blob, y


@pytest.mark.parametrize("mode,value_range", [
    ("std", None), ("residual", (-12.0, 12.0)), ("delta", None)])
@pytest.mark.parametrize("bound", [0.05, 0.5, 2.5])
def test_bound_honored_end_to_end(mode, value_range, bound):
    x = mixed_signal(16 * 60 + 3, seed=1)
    _check(x, mode, bound, value_range=value_range)


@pytest.mark.parametrize("backend", BACKENDS)
def test_bound_honored_on_every_backend(backend):
    x = mixed_signal(16 * 40, seed=2)
    for mode in ("std", "delta"):
        _check(x, mode, 0.5, backend=backend)


def test_demotion_is_monotone_and_only_demotes():
    """Adding a bound can only turn hits into misses (never the reverse),
    and a looser bound admits at least as many hits as a tighter one."""
    x = mixed_signal(16 * 80, seed=3).reshape(-1, 16)
    base = dict(num_dict=32, d_crit=0.45, rel_tol=0.5)
    free, _, _ = encode_decisions_np(x, **base)
    prev = None
    for bound in (0.1, 0.5, 2.0, 50.0):
        hit, _, _ = encode_decisions_np(x, error_bound=bound, **base)
        assert not np.any(hit & ~free)        # demotion only
        if prev is not None:
            assert hit.sum() >= prev.sum()    # monotone in the bound
        prev = hit
    # a bound far above the signal spread demotes nothing
    assert np.array_equal(prev, free)


def test_tight_bound_demotes_everything():
    x = mixed_signal(16 * 40, seed=4).reshape(-1, 16)
    hit, _, _ = encode_decisions_np(x, num_dict=32, d_crit=0.45,
                                    rel_tol=0.5, error_bound=1e-9)
    assert not np.any(hit)


def test_error_bound_reduces_decode_error():
    """The point of the feature: bounding provably shrinks the worst-case
    reconstruction error a statistical-similarity hit would otherwise
    introduce (at some ratio cost)."""
    x = mixed_signal(16 * 120, seed=5)
    loose = IdealemCodec(mode="std", block_size=16, num_dict=32,
                         alpha=0.05, backend="numpy")
    e_free = _err(x, loose.decode(loose.encode(x)))
    _, blob, y = _check(x, "std", bound=e_free / 4)
    assert _err(x, y) <= e_free / 4 + _F32_SLOP
    assert len(blob) >= len(loose.encode(x))  # paid for in hits


def test_error_bound_validation():
    with pytest.raises(ValueError, match="positive"):
        IdealemCodec(mode="std", error_bound=-1.0)
    with pytest.raises(ValueError, match="value_range"):
        IdealemCodec(mode="std", error_bound_rel=0.01)
    c = IdealemCodec(mode="residual", value_range=(0.0, 10.0),
                     error_bound_rel=0.05)
    assert c.error_bound == pytest.approx(0.5)


if HAVE_HYPOTHESIS:
    @given(
        st.sampled_from(["std", "residual", "delta"]),
        st.floats(min_value=0.05, max_value=5.0),
        st.integers(min_value=0, max_value=2**31 - 1),
        st.booleans(),
    )
    @settings(max_examples=20, deadline=None)
    def test_bound_property(mode, bound, seed, wrap):
        rng = np.random.default_rng(seed)
        n = 16 * int(rng.integers(4, 40)) + int(rng.integers(0, 16))
        x = mixed_signal(n, seed=seed)
        vr = None
        if wrap and mode != "std":
            vr = (0.0, 360.0)
            x = np.mod(x * 40.0, 360.0)
        codec = IdealemCodec(mode=mode, block_size=16, num_dict=32,
                             alpha=0.05, value_range=vr, error_bound=bound,
                             backend="numpy")
        y = codec.decode(codec.encode(x))
        assert _err(x, y, vr) <= bound + _F32_SLOP * max(bound, 1.0)
