"""Optimizer, checkpoint, fault-tolerance, gradient-compression tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("zstandard")
from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import synthetic
from repro.optim import adamw, gradcomp
from repro.runtime import FaultInjector, FaultTolerantTrainer
from repro.train import init_train_state, make_train_step


# ------------------------------------------------------------------ optimizer
def test_adamw_matches_reference_math():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw.init(params)
    new, st2, m = adamw.update(grads, st, params, lr=0.1, b1=0.9, b2=0.999,
                               eps=1e-8, weight_decay=0.0, clip_norm=None)
    g = np.array([0.1, 0.2, -0.3])
    mu = 0.1 * g
    nu = 0.001 * g * g
    mhat = mu / (1 - 0.9)
    vhat = nu / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    st = adamw.init(params)
    lossf = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(lossf)(params)
        params, st, _ = adamw.update(g, st, params, lr=0.1, weight_decay=0.0)
    assert float(lossf(params)) < 1e-2


def test_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    st = adamw.init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw.update(grads, st, params, lr=0.0, clip_norm=1.0)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-5)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_exact(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.float64(3.5) * np.ones((7,))}}
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    out = ckpt.restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_zstd_exact_and_idealem_lossy(tmp_path):
    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(64, 128)).astype(np.float32)}
    ckpt.save(str(tmp_path / "z"), 1, tree, codec="zstd")
    out = ckpt.restore(str(tmp_path / "z"), 1, tree)
    np.testing.assert_array_equal(out["w"], tree["w"])
    # idealem codec: lossy but statistically close + smaller on noise-like data
    ckpt.save(str(tmp_path / "i"), 1, tree, codec="idealem")
    out = ckpt.restore(str(tmp_path / "i"), 1, tree)
    assert out["w"].shape == tree["w"].shape
    assert abs(np.std(out["w"]) - np.std(tree["w"])) < 0.1


def test_checkpoint_atomicity_tmp_not_visible(tmp_path):
    tree = {"a": np.ones(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "step_00000099.tmp"))  # simulated crash
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_save(tmp_path):
    tree = {"a": np.ones((128,))}
    t = ckpt.async_save(str(tmp_path), 3, tree)
    t.join()
    out = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])


# ------------------------------------------------------------- fault tolerance
def _tiny_setup(tmp_path, use_gradcomp=False, **inj):
    cfg = get_config("granite_3_8b", smoke=True)
    state = init_train_state(jax.random.key(0), cfg, use_gradcomp=use_gradcomp)
    step = jax.jit(make_train_step(cfg, lr=1e-3, microbatches=1,
                                   use_gradcomp=use_gradcomp))
    batches = list(synthetic.token_stream(12, 4, 32, cfg.vocab_size))
    trainer = FaultTolerantTrainer(
        train_step=step, state=state, ckpt_dir=str(tmp_path), ckpt_every=4,
        injector=FaultInjector(inj.get("schedule", {})),
        step_deadline_s=inj.get("deadline"))
    return trainer, batches


def test_crash_recovery_resumes_and_completes(tmp_path):
    trainer, batches = _tiny_setup(tmp_path, schedule={6: "crash"})
    trainer.run(batches, 10)
    events = [e for e in trainer.log if e.get("event") == "restore"]
    assert len(events) == 1
    assert events[0]["resumed_from"] == 4  # last checkpoint before step 6
    steps_done = [e["step"] for e in trainer.log if "loss" in e]
    assert max(steps_done) == 9  # completed all 10 steps (0..9)


def test_nan_detection_triggers_restore(tmp_path):
    trainer, batches = _tiny_setup(tmp_path, schedule={2: "nan"})
    trainer.run(batches, 6)
    assert any(e.get("event") == "restore" for e in trainer.log)


def test_straggler_skip_rescales(tmp_path):
    trainer, batches = _tiny_setup(tmp_path, schedule={3: "straggler"},
                                   deadline=1e-9)
    trainer.run(batches, 6)
    ev = [e for e in trainer.log if e.get("event") == "straggler_skip"]
    assert len(ev) == 1 and ev[0]["dropped_frac"] == 0.25


# --------------------------------------------------------- gradient compression
def test_gradcomp_error_feedback_preserves_convergence():
    key = jax.random.key(0)
    w_true = jax.random.normal(key, (64,))

    def loss(w, x):
        return jnp.mean(jnp.square(x @ w - x @ w_true))

    x = jax.random.normal(jax.random.key(1), (256, 64))
    w = jnp.zeros((64,))
    gc = gradcomp.init({"w": w})
    for i in range(60):
        g = jax.grad(loss)(w, x)
        comp, gc, metrics = gradcomp.compress(
            {"w": g}, gc, block=16, num_dict=8, alpha=0.05)
        w = w - 0.1 * comp["w"]
    assert float(loss(w, x)) < 0.1 * float(loss(jnp.zeros((64,)), x))


def test_gradcomp_reports_wire_savings():
    rng = np.random.default_rng(0)
    # gradient blocks drawn from one distribution: highly exchangeable
    g = {"w": jnp.asarray(rng.normal(0, 1e-3, size=(64 * 256,)), jnp.float32)}
    gc = gradcomp.init(g)
    _, _, m = gradcomp.compress(g, gc, block=256, num_dict=32, alpha=0.01,
                                rel_tol=0.5)
    assert float(m["hit_rate"]) > 0.5
    assert float(m["wire_ratio"]) > 2.0
