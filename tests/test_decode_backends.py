"""Unified decode engine (ISSUE 4): backend parity and the fallback rule.

The load-bearing acceptance ring: for every golden mode x D case, every
backend in ``repro.core.decode.BACKENDS`` must reconstruct BYTE-identically
to the host path -- full decodes, random single ranges, and batched
multi-range plans.  The device backends' auto-fallback (exactness probe
fails or the device path raises) must be logged and observable, never
silent: a fallback that pretended to be a device result would make the
parity sweep vacuous.
"""
import logging
import zlib

import numpy as np
import pytest

from conftest import GOLDEN_CASES, golden_codec_kwargs, golden_signal
from repro.core import IdealemCodec
from repro.core import decode as decode_mod
from repro.core.decode import DecodePlan, PlanPart, pad_parts, reconstruct
from repro.core.stream import decode_stream
from repro.serve import DecompressionService, FlushPolicy
from repro.store import Container, decode_range, decode_ranges, pack
from test_golden_corpus import _golden_bytes

BACKENDS = ["numpy", "jax", "pallas"]
DEVICE_BACKENDS = ["jax", "pallas"]
FEED = 100


def _session_stream(name, feed=FEED):
    codec = IdealemCodec(**golden_codec_kwargs(name))
    x = golden_signal(name)
    s = codec.session()
    segs = [s.feed(x[lo:lo + feed]) for lo in range(0, len(x), feed)]
    segs.append(s.finish())
    return b"".join(segs)


_PREPPED = {}


def _prepped(name):
    if name not in _PREPPED:
        blob = _session_stream(name)
        _PREPPED[name] = (blob, Container(pack(blob)), decode_stream(blob))
    return _PREPPED[name]


# ------------------------------------------------------------ parity sweep
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_full_decode_parity(name, backend):
    """decode_stream on every backend == the host decode, bytes-for-bytes
    (one-shot golden stream AND the chunked multi-segment form)."""
    blob = _golden_bytes(name)
    want = decode_stream(blob)
    got = decode_stream(blob, backend=backend)
    assert got.tobytes() == want.tobytes()
    sblob, _, swant = _prepped(name)
    assert decode_stream(sblob, backend=backend).tobytes() == swant.tobytes()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_range_decode_parity(name, backend):
    """Random single ranges through the container on every backend equal
    the host full decode's slices."""
    _, store, y = _prepped(name)
    nb = store.total_blocks(0)
    B = store.header_of(int(store.chunks_of(0)[0])).block_size
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    ranges = [(0, nb), (0, 1), (nb - 1, nb)]
    ranges += [sorted((int(a), int(a) + int(b) + 1))
               for a, b in zip(rng.integers(0, nb - 1, size=6),
                               rng.integers(0, 8, size=6))]
    for i, j in ranges:
        j = min(j, nb)
        got = decode_range(store, i, j, backend=backend)
        assert got.tobytes() == y[i * B:j * B].tobytes(), (name, backend, i, j)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_batched_ranges_parity(name, backend):
    """Many ragged requests in ONE padded plan/dispatch, on every backend."""
    _, store, y = _prepped(name)
    nb = store.total_blocks(0)
    B = store.header_of(int(store.chunks_of(0)[0])).block_size
    reqs = [(0, 0, nb), (0, 3, 5), (0, nb - 1, nb), (0, 7, 29),
            (0, nb // 2, nb // 2 + 1)]
    for (_, i, j), got in zip(reqs, decode_ranges(store, reqs,
                                                  backend=backend)):
        assert got.tobytes() == y[i * B:j * B].tobytes(), (name, backend, i, j)


def test_device_backends_actually_ran_on_device():
    """The sweep above is vacuous if every device call silently fell back;
    on the CPU harness the probe must pass and route to the device path."""
    decode_mod.reset_decode_stats()
    blob = _golden_bytes("delta_D32")
    want = decode_stream(blob)
    for backend in DEVICE_BACKENDS:
        assert decode_stream(blob, backend=backend).tobytes() == want.tobytes()
    stats = decode_mod.decode_stats()
    assert stats["device_calls"] == len(DEVICE_BACKENDS)
    assert stats["fallbacks"] == 0


def test_unknown_backend_rejected():
    blob = _golden_bytes("std_D1")
    with pytest.raises(ValueError, match="unknown decode backend"):
        decode_stream(blob, backend="tpu9000")


# ------------------------------------------------------- fallback contract
def test_fallback_is_logged_and_exact(monkeypatch, caplog):
    """A device backend that fails the exactness probe must (a) log the
    decision, (b) count it in decode_stats, and (c) still return the
    byte-exact host result."""
    blob = _golden_bytes("delta_D32")
    want = decode_stream(blob)

    def broken_run_device(plan, backend):
        out = decode_mod._reconstruct_numpy(plan).copy()
        out += 1e-9  # byte-wrong, numerically plausible
        return out

    monkeypatch.setattr(decode_mod, "_run_device", broken_run_device)
    monkeypatch.setattr(decode_mod, "_exact_cache", {})
    decode_mod.reset_decode_stats()
    with caplog.at_level(logging.WARNING, logger="repro.core.decode"):
        got = decode_stream(blob, backend="jax")
    assert got.tobytes() == want.tobytes()
    assert decode_mod.decode_stats()["fallbacks"] == 1
    assert decode_mod.decode_stats()["device_calls"] == 0
    assert any("not byte-exact" in r.message for r in caplog.records)


def test_crashing_device_backend_falls_back(monkeypatch, caplog):
    blob = _golden_bytes("std_D32")
    want = decode_stream(blob)

    def crashing(plan, backend):
        raise RuntimeError("no f64 on this accelerator")

    monkeypatch.setattr(decode_mod, "_run_device", crashing)
    monkeypatch.setattr(decode_mod, "_exact_cache", {})
    decode_mod.reset_decode_stats()
    with caplog.at_level(logging.WARNING, logger="repro.core.decode"):
        got = decode_stream(blob, backend="pallas")
    assert got.tobytes() == want.tobytes()
    assert decode_mod.decode_stats()["fallbacks"] == 1
    assert any("falling back to host" in r.message for r in caplog.records)


def test_dispatch_failure_serves_from_host(monkeypatch, caplog):
    """The probe can pass while the REAL (bigger) dispatch fails -- device
    OOM, shape-specific compile error.  The call must then be served from
    the host path instead of failing the request, and counted as a
    fallback, not a device call."""
    blob = _golden_bytes("delta_D32")
    want = decode_stream(blob)
    real_run = decode_mod._run_device

    def flaky(plan, backend):
        if plan.nb > 16:  # probe plans are 16 blocks; real calls are bigger
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")
        return real_run(plan, backend)

    monkeypatch.setattr(decode_mod, "_run_device", flaky)
    monkeypatch.setattr(decode_mod, "_exact_cache", {})
    decode_mod.reset_decode_stats()
    with caplog.at_level(logging.WARNING, logger="repro.core.decode"):
        got = decode_stream(blob, backend="jax")
    assert got.tobytes() == want.tobytes()
    stats = decode_mod.decode_stats()
    assert stats["device_calls"] == 0 and stats["fallbacks"] == 1
    assert any("failed at dispatch" in r.message for r in caplog.records)


def test_fallback_probe_runs_once_per_combination(monkeypatch):
    """The probe is cached: a failing combination probes the device once,
    then every later call routes straight to the host."""
    calls = []

    def crashing(plan, backend):
        calls.append(backend)
        raise RuntimeError("boom")

    monkeypatch.setattr(decode_mod, "_run_device", crashing)
    monkeypatch.setattr(decode_mod, "_exact_cache", {})
    blob = _golden_bytes("std_D32")
    for _ in range(3):
        decode_stream(blob, backend="jax")
    assert calls == ["jax"]


# ------------------------------------------------- engine-internal parity
def test_seq_cumsum_kernels_match_numpy_bitwise():
    """The delta-mode exactness story: XLA's associative cumsum is NOT
    byte-exact in f64, the sequential fori_loop and the pallas kernel are."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.seq_cumsum import seq_cumsum

    rng = np.random.default_rng(0)
    for dtype, rows in [(np.float64, 37), (np.float32, 8), (np.float64, 1)]:
        x = rng.normal(0, 100, size=(rows, 31)).astype(dtype)
        x[0, 0] = -0.0  # a leading -0.0 must survive bit-for-bit
        want = np.cumsum(x, axis=1)
        with enable_x64():
            got = np.asarray(seq_cumsum(jnp.asarray(x)))
            assert got.tobytes() == want.tobytes(), dtype
            xla = np.asarray(jnp.cumsum(jnp.asarray(x), axis=1))
        if dtype is np.float64 and rows > 8:
            # the premise for the kernel: plain XLA cumsum drifts
            assert xla.tobytes() != want.tobytes()


def test_pad_parts_padding_is_inert():
    """Pad blocks (all-miss, zero payload, block_idx 0) must not perturb
    the real blocks on any backend."""
    rng = np.random.default_rng(5)
    B = 8
    rows_a = rng.normal(size=(5, B - 1))
    rows_b = rng.normal(size=(2, B - 1))
    parts = [
        PlanPart(rows=rows_a, bases=rng.normal(size=5),
                 is_hit=np.array([False, True, False, True, True]),
                 block_idx=np.arange(10, 15)),
        PlanPart(rows=rows_b, bases=rng.normal(size=2),
                 is_hit=np.array([False, False]),
                 block_idx=np.arange(2)),
    ]
    plan, nbm = pad_parts(decode_mod.MODE_DELTA, B, np.float64, None, parts)
    assert nbm == 5
    for backend in BACKENDS:
        out = reconstruct(plan, backend=backend).reshape(2, nbm, B)
        solo = [reconstruct(pad_parts(decode_mod.MODE_DELTA, B, np.float64,
                                      None, [p])[0], backend=backend)
                for p in parts]
        assert out[0, :5].tobytes() == solo[0].tobytes()
        assert out[1, :2].tobytes() == solo[1].tobytes()


def test_empty_plan_reconstructs_empty():
    plan = DecodePlan(
        mode=decode_mod.MODE_STD, block_size=4, dtype=np.dtype(np.float64),
        value_range=None, payloads=np.zeros((0, 4)),
        src=np.zeros(0, np.int64), bases=None, is_hit=np.zeros(0, bool),
        block_idx=np.zeros(0, np.int64))
    for backend in BACKENDS:
        assert reconstruct(plan, backend=backend).shape == (0, 4)


# --------------------------------------------------- serving read parity
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_decompression_service_device_flush(backend):
    """A device-backed service flush merges compatible requests -- across
    TWO attached stores -- into one dispatch and answers byte-identically
    to the host service."""
    blob = _session_stream("delta_D32")
    y = decode_stream(blob)
    packed = pack(blob)
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=4),
                               backend=backend)
    svc.attach("a", packed)
    svc.attach("b", packed)
    nb = Container(packed).total_blocks(0)
    assert svc.submit("r1", "a", 0, 4) is None
    assert svc.submit("r2", "b", 10, 12) is None
    assert svc.submit("r3", "a", 0, nb) is None
    d0 = svc.stats["dispatches"]
    ans = svc.submit("r4", "b", nb - 1, nb)  # trips the policy
    assert set(ans) == {"r1", "r2", "r3", "r4"}
    assert svc.stats["dispatches"] - d0 == 1  # ONE device dispatch, 2 stores
    B = 16
    for rid, i, j in [("r1", 0, 4), ("r2", 10, 12), ("r3", 0, nb),
                      ("r4", nb - 1, nb)]:
        assert ans[rid].tobytes() == y[i * B:j * B].tobytes()
    # immediate read path rides the same backend
    assert svc.read("a", 2, 6).tobytes() == y[2 * B:6 * B].tobytes()


def test_host_flush_buckets_device_flush_merges():
    """The host backend splits dissimilar request lengths into pow-2
    buckets (padding control); a device backend merges them into one
    dispatch (dispatch control)."""
    blob = _session_stream("std_D32")
    packed = pack(blob)
    nb = Container(packed).total_blocks(0)
    reqs = [("s1", 0, 1), ("s2", 0, nb)]  # 1 block vs nb blocks

    host = DecompressionService(policy=FlushPolicy(max_batch_streams=2),
                                backend="numpy")
    host.attach("s", packed)
    for rid, i, j in reqs[:1]:
        host.submit(rid, "s", i, j)
    host.submit(*("s2", "s", 0, nb))
    assert host.stats["dispatches"] == 2

    dev = DecompressionService(policy=FlushPolicy(max_batch_streams=2),
                               backend="jax")
    dev.attach("s", packed)
    dev.submit("s1", "s", 0, 1)
    dev.submit("s2", "s", 0, nb)
    assert dev.stats["dispatches"] == 1


def test_device_flush_splits_pathological_padding():
    """Merging buckets on a device backend must not let one huge request
    pad hundreds of tiny ones: when the padded batch exceeds both the
    policy block budget and 4x the real work, the group re-splits by
    length bucket -- and every answer stays exact."""
    blob = _session_stream("std_D32")
    y = decode_stream(blob)
    packed = pack(blob)
    nb = Container(packed).total_blocks(0)  # 40
    n_tiny = 30
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=n_tiny + 2,
                           max_batch_blocks=nb + n_tiny),
        backend="jax")
    svc.attach("s", packed)
    reqs = [("big", 0, nb)] + [(f"t{k}", k, k + 1) for k in range(n_tiny)]
    for rid, i, j in reqs[:-1]:
        assert svc.submit(rid, "s", i, j) is None
    rid, i, j = reqs[-1]
    ans = svc.submit(rid, "s", i, j)  # trips max_batch_streams
    # padded merged batch would be 31*40=1240 >> sum(70)*4 and > budget:
    # must have split into (at least) the 1-block and 64-block buckets
    assert svc.stats["dispatches"] >= 2
    B = 16
    for rid, i, j in reqs:
        assert ans[rid].tobytes() == y[i * B:j * B].tobytes(), rid


# ------------------------------------------------------- hypothesis widen
try:
    import hypothesis  # noqa: F401

    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(name=st.sampled_from(sorted(GOLDEN_CASES)),
           backend=st.sampled_from(BACKENDS),
           data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_random_range_sets_any_backend(name, backend, data):
        """Property form: ANY set of ranges, batched on ANY backend,
        equals the host full decode's slices."""
        _, store, y = _prepped(name)
        nb = store.total_blocks(0)
        B = store.header_of(int(store.chunks_of(0)[0])).block_size
        n_req = data.draw(st.integers(min_value=1, max_value=6))
        reqs = []
        for _ in range(n_req):
            i = data.draw(st.integers(min_value=0, max_value=nb - 1))
            j = data.draw(st.integers(min_value=i + 1, max_value=nb))
            reqs.append((0, i, j))
        for (_, i, j), got in zip(reqs, decode_ranges(store, reqs,
                                                      backend=backend)):
            assert got.tobytes() == y[i * B:j * B].tobytes()

except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_range_sets_any_backend():
        pass
