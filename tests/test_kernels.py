"""Pallas dict_match kernel vs pure-jnp oracle: shape/dtype sweep + properties."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ks import ks_statistic_many
from repro.kernels.ops import dict_match, dict_match_ks, dict_match_reference


def _case(D, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    xs = np.sort(rng.normal(size=n)).astype(dtype)
    ds = rng.normal(size=(D, n)).astype(dtype)
    return jnp.asarray(xs), jnp.asarray(ds)


@pytest.mark.parametrize("D", [1, 3, 8, 17, 255])
@pytest.mark.parametrize("n", [8, 32, 111, 256])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernel_matches_ref_sweep(D, n, dtype):
    xs, ds = _case(D, n, dtype)
    dmin, dmax = ds.min(axis=1), ds.max(axis=1)
    ks_k, mm_k = dict_match(xs, ds, dmin, dmax, 0.3)
    ks_r, mm_r = dict_match_reference(xs, ds, dmin, dmax, 0.3)
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_r), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mm_k), np.asarray(mm_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_low_precision_runs(dtype):
    rng = np.random.default_rng(3)
    xs = jnp.sort(jnp.asarray(rng.normal(size=64), dtype=dtype))
    ds = jnp.asarray(rng.normal(size=(16, 64)), dtype=dtype)
    ks, mm = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.3)
    assert ks.shape == (16,) and mm.shape == (16,)
    assert bool(jnp.all((ks >= 0) & (ks <= 1)))


def test_kernel_matches_searchsorted_core():
    """Independent third implementation (searchsorted ECDF) agrees."""
    xs, ds = _case(31, 64, np.float32, seed=7)
    ks_k, _ = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.5)
    ks_c = ks_statistic_many(xs, jnp.sort(ds, axis=1))
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_c), atol=1e-6)


def test_matcher_signature_for_encoder():
    xs, ds = _case(16, 32, np.float32, seed=9)
    ds_sorted = jnp.sort(ds, axis=1)
    ks = dict_match_ks(xs, ds_sorted)
    np.testing.assert_allclose(
        np.asarray(ks),
        np.asarray(ks_statistic_many(xs, ds_sorted)),
        atol=1e-6,
    )


@given(st.integers(min_value=1, max_value=40),
       st.integers(min_value=4, max_value=96),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_kernel_property_identical_block_zero_distance(D, n, seed):
    rng = np.random.default_rng(seed)
    xs = jnp.sort(jnp.asarray(rng.normal(size=n), dtype=jnp.float32))
    ds = jnp.tile(xs[None, :], (D, 1))
    ks, mm = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.0)
    np.testing.assert_allclose(np.asarray(ks), 0.0, atol=1e-7)
    assert bool(jnp.all(mm))  # zero tolerance still passes: identical extremes
