"""Pallas dict_match kernel vs pure-jnp oracle: shape/dtype sweep + edge
sizes (TILE_D padding, D=1, the n=256 block cap, min/max gate boundaries)
+ hypothesis properties (skipped when hypothesis is absent)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.ks import ks_statistic_many
from repro.kernels.dict_match import TILE_D
from repro.kernels.ops import dict_match, dict_match_ks, dict_match_reference


def _case(D, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    xs = np.sort(rng.normal(size=n)).astype(dtype)
    ds = rng.normal(size=(D, n)).astype(dtype)
    return jnp.asarray(xs), jnp.asarray(ds)


@pytest.mark.parametrize("D", [1, 3, 8, 17, 255])
@pytest.mark.parametrize("n", [8, 32, 111, 256])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernel_matches_ref_sweep(D, n, dtype):
    xs, ds = _case(D, n, dtype)
    dmin, dmax = ds.min(axis=1), ds.max(axis=1)
    ks_k, mm_k = dict_match(xs, ds, dmin, dmax, 0.3)
    ks_r, mm_r = dict_match_reference(xs, ds, dmin, dmax, 0.3)
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_r), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mm_k), np.asarray(mm_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_low_precision_runs(dtype):
    rng = np.random.default_rng(3)
    xs = jnp.sort(jnp.asarray(rng.normal(size=64), dtype=dtype))
    ds = jnp.asarray(rng.normal(size=(16, 64)), dtype=dtype)
    ks, mm = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.3)
    assert ks.shape == (16,) and mm.shape == (16,)
    assert bool(jnp.all((ks >= 0) & (ks <= 1)))


def test_kernel_matches_searchsorted_core():
    """Independent third implementation (searchsorted ECDF) agrees."""
    xs, ds = _case(31, 64, np.float32, seed=7)
    ks_k, _ = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.5)
    ks_c = ks_statistic_many(xs, jnp.sort(ds, axis=1))
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_c), atol=1e-6)


def test_matcher_signature_for_encoder():
    xs, ds = _case(16, 32, np.float32, seed=9)
    ds_sorted = jnp.sort(ds, axis=1)
    ks = dict_match_ks(xs, ds_sorted)
    np.testing.assert_allclose(
        np.asarray(ks),
        np.asarray(ks_statistic_many(xs, ds_sorted)),
        atol=1e-6,
    )


# -------------------------------------------------------- edge-size parity
# D off the TILE_D grid (pad-and-slice wrapper), D=1, and n at the 256
# block-size cap; the fused mm gate is asserted alongside ks everywhere.
EDGE_D = [1, TILE_D - 1, TILE_D + 1, 2 * TILE_D + 5, 255]


@pytest.mark.parametrize("D", EDGE_D)
@pytest.mark.parametrize("n", [2, 256])
def test_kernel_parity_edge_sizes(D, n):
    assert 255 % TILE_D != 0  # the max-D case must exercise the pad path
    xs, ds = _case(D, n, np.float32, seed=D * 1000 + n)
    dmin, dmax = ds.min(axis=1), ds.max(axis=1)
    ks_k, mm_k = dict_match(xs, ds, dmin, dmax, 0.3)
    ks_r, mm_r = dict_match_reference(xs, ds, dmin, dmax, 0.3)
    assert ks_k.shape == mm_k.shape == (D,)
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_r), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mm_k), np.asarray(mm_r))


def test_kernel_minmax_gate_boundary():
    """mm parity exactly at the eq. (3) tolerance boundary: both paths
    compute t = (dmax - dmin) * r in f32, so the <=/>= comparisons must
    agree bitwise, including extremes landing exactly on dmin/dmax +- t."""
    n = 32
    xs = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    base = jnp.tile(xs[None, :], (6, 1))
    r = jnp.float32(0.25)
    t = (base[:, -1] - base[:, 0]) * r
    # rows shifted so candidate extremes sit below/at/above the gate edges
    shift = jnp.asarray([0.0, 1.0, -1.0, 1.0001, 0.5, 2.0],
                        dtype=jnp.float32)[:, None] * t[:, None]
    ds = base + shift
    dmin, dmax = ds.min(axis=1), ds.max(axis=1)
    ks_k, mm_k = dict_match(xs, ds, dmin, dmax, float(r))
    ks_r, mm_r = dict_match_reference(xs, ds, dmin, dmax, float(r))
    np.testing.assert_array_equal(np.asarray(mm_k), np.asarray(mm_r))
    assert bool(mm_k[0]) and bool(mm_k[1]) and bool(mm_k[2])  # on-edge pass
    assert not bool(mm_k[3]) and not bool(mm_k[5])            # outside fail
    np.testing.assert_allclose(np.asarray(ks_k), np.asarray(ks_r), atol=1e-6)


def test_kernel_mm_independent_of_stored_order():
    """The gate reads only (dmin, dmax): shuffling each dictionary row must
    not change mm (the encoder stores rows sorted; the kernel must not
    rely on it)."""
    rng = np.random.default_rng(5)
    xs, ds = _case(24, 64, np.float32, seed=5)
    dmin, dmax = ds.min(axis=1), ds.max(axis=1)
    perm = rng.permutation(64)
    ks_a, mm_a = dict_match(xs, ds, dmin, dmax, 0.4)
    ks_b, mm_b = dict_match(xs, ds[:, perm], dmin, dmax, 0.4)
    np.testing.assert_array_equal(np.asarray(mm_a), np.asarray(mm_b))
    np.testing.assert_allclose(np.asarray(ks_a), np.asarray(ks_b), atol=1e-6)


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=40),
           st.integers(min_value=4, max_value=96),
           st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_kernel_property_identical_block_zero_distance(D, n, seed):
        rng = np.random.default_rng(seed)
        xs = jnp.sort(jnp.asarray(rng.normal(size=n), dtype=jnp.float32))
        ds = jnp.tile(xs[None, :], (D, 1))
        ks, mm = dict_match(xs, ds, ds.min(axis=1), ds.max(axis=1), 0.0)
        np.testing.assert_allclose(np.asarray(ks), 0.0, atol=1e-7)
        assert bool(jnp.all(mm))  # zero tolerance passes: identical extremes
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_kernel_property_identical_block_zero_distance():
        pass
