"""Regenerate the golden stream corpus from the seed-oracle path.

Every stream is assembled with the retained seed implementations
(``encode_decisions_np`` decisions + ``_assemble_stream_py`` serializer)
over the LCG-deterministic signals in tests/conftest.py, so the bytes are
independent of both the vectorized stream path under test and numpy's RNG
stream.  Run from the repo root:

  PYTHONPATH=src python tests/golden/make_golden.py

Regenerating is only legitimate when the stream FORMAT deliberately
changes (a header version bump); commit the new bytes with that change.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np  # noqa: E402

from conftest import GOLDEN_CASES, golden_codec_kwargs, golden_signal  # noqa: E402
from repro.core import IdealemCodec  # noqa: E402
from repro.core.npref import encode_decisions_np  # noqa: E402
from repro.core.stream import StreamHeader, _assemble_stream_py  # noqa: E402


def oracle_encode(name: str) -> bytes:
    codec = IdealemCodec(**golden_codec_kwargs(name))
    x = golden_signal(name)
    B = codec.block_size
    nb = len(x) // B
    blocks = np.ascontiguousarray(x[:nb * B]).reshape(nb, B)
    payload, bases = codec._transform(blocks)
    hit, slot, ovw = encode_decisions_np(
        payload, num_dict=codec.num_dict, d_crit=float(codec.d_crit),
        rel_tol=float(codec.rel_tol), use_minmax=codec.use_minmax,
        use_ks=codec.use_ks)
    header = StreamHeader(codec.mode_id, B, codec.num_dict, codec.max_count,
                          x.dtype, codec.value_range, nb, x[nb * B:])
    return _assemble_stream_py(header, blocks, payload, bases, hit, slot, ovw)


def main() -> None:
    out_dir = os.path.dirname(os.path.abspath(__file__))
    for name in GOLDEN_CASES:
        blob = oracle_encode(name)
        path = os.path.join(out_dir, f"{name}.idlm")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"{name}.idlm  {len(blob)} bytes")


if __name__ == "__main__":
    main()
