"""Trip-count-aware HLO cost analysis: exactness on known programs."""
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import analyze

def f(xs, w):
    def body(c, x):
        return c @ w + x @ w, ()
    c, _ = jax.lax.scan(body, xs[0], xs)
    return c

xs = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
res = analyze(jax.jit(f).lower(xs, w).compile().as_text())
expected = 2 * 2 * 5 * 64 * 64 * 64
assert abs(res["flops"] - expected) < 1e-6, (res["flops"], expected)

def g(xs, w):
    def outer(c, x):
        def inner(c2, _):
            return c2 @ w, ()
        c2, _ = jax.lax.scan(inner, c + x, jnp.arange(3))
        return c2, ()
    c, _ = jax.lax.scan(outer, xs[0], xs)
    return c

res2 = analyze(jax.jit(g).lower(xs, w).compile().as_text())
expected2 = 5 * 3 * 2 * 64 ** 3
assert abs(res2["flops"] - expected2) < 1e-6, (res2["flops"], expected2)
assert res["bytes"] > 0
print("HLO_COST_OK")
"""


def test_analyzer_exact_on_nested_scans():
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.getcwd(),
                         capture_output=True, text=True, timeout=300)
    assert "HLO_COST_OK" in out.stdout, out.stdout + out.stderr


def test_shape_parsing_units():
    from repro.launch.hlo_cost import _shape_info
    b, shapes = _shape_info("f32[2,3,4]{2,1,0}")
    assert b == 2 * 3 * 4 * 4 and shapes == [[2, 3, 4]]
    b, shapes = _shape_info("(bf16[8], s32[2,2])")
    assert b == 8 * 2 + 4 * 4
    b, _ = _shape_info("pred[10]")
    assert b == 10
