"""Parser hardening (ISSUE 3 satellite): malformed or truncated streams must
raise the typed ``StreamFormatError`` -- with a byte offset -- never a raw
``IndexError``/``struct.error``/``ValueError`` from the walk internals.

The fuzz corpus is the golden streams themselves: every truncation point of
a real stream (all three modes, D regimes, tails, the 0xFF prefix) plus
targeted corruptions of each header field and hand-built pathological
bodies.
"""
import struct

import numpy as np
import pytest

from conftest import GOLDEN_CASES
from repro.core.stream import (_HDR, MAGIC, VERSION, StreamFormatError,
                               StreamHeader, _pack_header, decode_stream,
                               parse_stream)
from test_golden_corpus import _golden_bytes


def _assert_typed_failure(data):
    """Parsing must fail, and fail with the typed error (which subclasses
    ValueError, so pre-hardening callers keep working)."""
    with pytest.raises(StreamFormatError) as ei:
        parse_stream(data)
    assert isinstance(ei.value, ValueError)
    assert "byte" in str(ei.value)  # offset is part of the message
    with pytest.raises(StreamFormatError):
        decode_stream(data)


@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_every_truncation_point_raises_typed(name):
    """A stream cut anywhere strictly inside must raise StreamFormatError:
    in the header, the tail, a decision byte or the value payload."""
    blob = _golden_bytes(name)
    # full sweep is ~1.4k parses per case; stride keeps it fast while still
    # crossing every region (header/tail boundary at 40, body, final bytes)
    cuts = set(range(0, 64)) | set(range(64, len(blob), 7)) \
        | set(range(len(blob) - 16, len(blob)))
    for cut in sorted(cuts):
        _assert_typed_failure(blob[:cut])


@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_golden_stream_still_parses_whole(name):
    parse_stream(_golden_bytes(name))  # the sweep above must not overfit


def test_corrupt_header_fields_raise_typed():
    blob = bytearray(_golden_bytes("std_D32"))
    for pos, bad, what in [
        (0, ord(b"X"), "magic"),
        (4, VERSION + 9, "version"),
        (5, 7, "mode byte"),
    ]:
        mutated = bytearray(blob)
        mutated[pos] = bad
        with pytest.raises(StreamFormatError, match="byte"):
            parse_stream(bytes(mutated))

    # degenerate geometry: block_size == 0 must be rejected, not divide the
    # layout math
    hdr = struct.unpack_from("<4sBBHBBBBddIH", blob, 0)
    zeroed = bytearray(blob)
    struct.pack_into("<H", zeroed, 6, 0)  # block_size field
    with pytest.raises(StreamFormatError):
        parse_stream(bytes(zeroed))
    assert hdr[0] == MAGIC


def test_tail_overrun_raises_typed():
    h = StreamHeader(0, 16, 4, 255, np.dtype(np.float64), None, 0,
                     np.zeros(3))
    blob = _pack_header(h)
    # claim a 1000-sample tail but provide 3
    forged = bytearray(blob)
    struct.pack_into("<H", forged, _HDR.size - 2, 1000)
    with pytest.raises(StreamFormatError, match="tail"):
        parse_stream(bytes(forged))


def test_single_dict_count_overrun_raises_typed():
    """A D==1 hit-count byte larger than the remaining block count is a
    corrupt stream, not an infinite/negative walk."""
    h = StreamHeader(0, 16, 1, 255, np.dtype(np.float64), None, 2,
                     np.zeros(0))
    body = np.arange(16, dtype=np.float64).tobytes() + bytes([200])
    # padding so the walk fails on the count, not the buffer end
    blob = _pack_header(h) + body + bytes(64)
    with pytest.raises(StreamFormatError, match="run overruns"):
        parse_stream(blob)


def test_hit_before_any_miss_raises_typed():
    """A decision byte naming an unfilled slot as a hit source is corrupt;
    the decoder must refuse rather than emit garbage."""
    h = StreamHeader(0, 16, 5, 255, np.dtype(np.float64), None, 1,
                     np.zeros(0))
    blob = _pack_header(h) + bytes([3])  # slot 3 'hit' with empty FIFO
    parse_stream(blob)  # structurally parseable ...
    with pytest.raises(StreamFormatError, match="before any miss"):
        decode_stream(blob)  # ... but not decodable


def test_random_garbage_never_leaks_raw_errors():
    """Deterministic byte fuzz: random buffers (some with a valid magic
    prefix) either parse or raise the typed error -- nothing else."""
    rng = np.random.default_rng(0)
    for trial in range(200):
        n = int(rng.integers(0, 120))
        data = rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
        if trial % 2:
            data = MAGIC + bytes([VERSION]) + data
        try:
            parse_stream(data)
        except StreamFormatError:
            pass
