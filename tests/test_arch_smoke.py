"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU; asserts shapes and finiteness (the FULL configs are exercised
only via the dry-run with ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.train import init_train_state, make_train_step


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "vlm":
        batch["memory"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model),
                                   cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, lr=1e-3, microbatches=2))
    batch = _batch(cfg)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.key(0), cfg)
    B = 2
    mem_len = (cfg.num_image_tokens if cfg.family == "vlm"
               else cfg.encoder_seq if cfg.family == "audio" else 0)
    cache = lm.init_cache(cfg, B, max_seq=32, memory_len=mem_len)
    logits, cache2 = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, cfg)
    )(params, cache, jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published numbers."""
    cfg = get_config(arch, smoke=False)
    expected = {
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "gemma3_27b": (62, 5376, 32, 16, 21504, 262144),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "llama_3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "granite_moe_1b_a400m":
        assert (cfg.num_experts, cfg.experts_per_token) == (32, 8)
    if arch == "mixtral_8x22b":
        assert (cfg.num_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window
    if arch == "gemma3_27b":
        assert cfg.local_global_ratio == 5
    if arch == "zamba2_1_2b":
        assert cfg.ssm_state == 64 and cfg.family == "hybrid"
    if arch == "rwkv6_3b":
        assert cfg.family == "ssm"
