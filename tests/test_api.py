"""Wire-typed public API: JSON round trips, strict validation, the
CodecConfig <-> IdealemCodec round trip, the unified error hierarchy, and
the curated ``repro`` facade (ISSUE 10)."""
import numpy as np
import pytest

from repro import api
from repro.errors import (ApiError, AutotuneCacheError, ERROR_CODES,
                          NotFoundError, OverloadedError, QuotaExceededError,
                          RateLimitedError, ReproError, StreamFormatError,
                          error_from_payload, error_payload)


# ------------------------------------------------------------- wire types
def test_compress_request_round_trip():
    req = api.CompressRequest("s0", np.arange(7, dtype=np.float64))
    back = api.CompressRequest.from_json(req.to_json())
    assert back.stream_id == "s0"
    np.testing.assert_array_equal(back.samples, req.samples)
    assert back.samples.dtype == np.float64


def test_compress_request_preserves_dtype():
    req = api.CompressRequest("s", np.arange(4, dtype=np.float16))
    back = api.CompressRequest.from_json(req.to_json())
    assert back.samples.dtype == np.float16


@pytest.mark.parametrize("doc", [
    None, [], {"stream_id": "s"},                           # missing samples
    {"stream_id": 3, "samples": {"dtype": "<f8", "b64": ""}},
    {"stream_id": "s", "samples": {"dtype": "<f8", "b64": "!!"}},
    {"stream_id": "s", "samples": {"dtype": "<f8", "b64": "AAAA"}},  # ragged
    {"stream_id": "s", "samples": {"dtype": "<f8", "b64": ""}, "x": 1},
])
def test_compress_request_rejects_malformed(doc):
    with pytest.raises(ApiError):
        api.CompressRequest.from_json(doc)


def test_compress_request_requires_1d():
    with pytest.raises(ApiError):
        api.CompressRequest("s", np.zeros((2, 2)))


def test_feed_result_round_trip():
    r = api.FeedResult("s", b"\x00\xff", blocks=3, hits=2, bytes_in=96,
                       bytes_out=5, final=True)
    back = api.FeedResult.from_json(r.to_json())
    assert (back.segment, back.blocks, back.hits, back.final) == \
        (b"\x00\xff", 3, 2, True)


def test_decode_range_request_round_trip_and_validation():
    req = api.DecodeRangeRequest("st", 2, 9, channel=1, request_id="r1")
    back = api.DecodeRangeRequest.from_json(req.to_json())
    assert (back.store_id, back.start_block, back.stop_block,
            back.channel, back.request_id) == ("st", 2, 9, 1, "r1")
    with pytest.raises(ApiError):
        api.DecodeRangeRequest("st", 5, 5)
    with pytest.raises(ApiError):
        api.DecodeRangeRequest("st", -1, 4)


def test_range_result_round_trip():
    r = api.RangeResult("r1", np.linspace(0, 1, 9))
    back = api.RangeResult.from_json(r.to_json())
    np.testing.assert_array_equal(back.values, r.values)


# ------------------------------------------------------------ codec config
def test_codec_config_to_json_holds_only_non_defaults():
    assert api.CodecConfig().to_json() == {}
    doc = api.CodecConfig(mode="delta", num_dict=7).to_json()
    assert doc == {"mode": "delta", "num_dict": 7}


def test_codec_config_json_round_trip():
    cfg = api.CodecConfig(mode="residual", block_size=16, num_dict=31,
                          alpha=0.05, rel_tol=0.5,
                          value_range=(0.0, 360.0), backend="numpy")
    assert api.CodecConfig.from_json(cfg.to_json()) == cfg
    assert api.CodecConfig.from_json(None) == api.CodecConfig()
    with pytest.raises(ApiError):
        api.CodecConfig.from_json({"no_such_knob": 1})
    with pytest.raises(ApiError):
        api.CodecConfig.from_json({"value_range": [1.0]})


def test_codec_config_is_hashable_cache_key():
    a = api.CodecConfig(mode="std", value_range=(0, 1))
    b = api.CodecConfig(mode="std", value_range=(0.0, 1.0))
    assert a == b and hash(a) == hash(b)


def test_idealem_codec_from_config_round_trip():
    from repro.core import IdealemCodec
    cfg = api.CodecConfig(mode="residual", block_size=16, num_dict=31,
                          alpha=0.05, rel_tol=0.5, backend="numpy")
    codec = IdealemCodec.from_config(cfg)
    assert codec.config == cfg
    assert IdealemCodec.from_config(cfg.to_json()).config == cfg
    # config-built codec encodes exactly like the kwargs-built one
    x = np.sin(np.linspace(0, 20, 640))
    assert codec.encode(x) == IdealemCodec(**cfg.kwargs()).encode(x)


def test_codec_config_survives_error_bound_resolution():
    from repro.core import IdealemCodec
    codec = IdealemCodec(mode="std", block_size=16, backend="numpy",
                         error_bound=0.25)
    again = IdealemCodec.from_config(codec.config)
    assert again.config == codec.config
    assert again.error_bound == codec.error_bound


# ------------------------------------------------------------------ errors
def test_error_hierarchy_roots_and_legacy_bases():
    # every typed error is a ReproError; re-parented classes keep their
    # historical stdlib bases so existing except clauses still catch them
    assert issubclass(StreamFormatError, ReproError)
    assert issubclass(StreamFormatError, ValueError)
    assert issubclass(AutotuneCacheError, ReproError)
    assert issubclass(NotFoundError, KeyError)
    assert issubclass(ApiError, ValueError)


def test_error_legacy_import_paths():
    from repro.core.stream import StreamFormatError as via_stream
    from repro.core.tuning import AutotuneCacheError as via_tuning
    assert via_stream is StreamFormatError
    assert via_tuning is AutotuneCacheError


def test_error_codes_and_statuses():
    assert QuotaExceededError("x").http_status == 429
    assert RateLimitedError("x").http_status == 429
    assert OverloadedError("x").http_status == 503
    assert ApiError("x").http_status == 400
    assert StreamFormatError("x").http_status == 400
    for code, cls in ERROR_CODES.items():
        assert cls("m").code == code


def test_error_payload_round_trip():
    exc = RateLimitedError("slow down", retry_after_s=1.5)
    doc = error_payload(exc)
    assert doc["error"]["code"] == "rate_limited"
    assert doc["error"]["retry_after_s"] == 1.5
    back = error_from_payload(doc)
    assert isinstance(back, RateLimitedError)
    assert back.retry_after_s == 1.5
    # unknown codes fall back to the root without losing the message
    odd = error_from_payload({"error": {"code": "???", "message": "m"}})
    assert isinstance(odd, ReproError)


def test_stream_format_error_offset_message():
    e = StreamFormatError("bad tag", offset=17)
    assert "17" in str(e)


# ------------------------------------------------------------------ facade
def test_repro_facade_exports_curated_names():
    import repro
    for name in ("CodecConfig", "CompressRequest", "FeedResult",
                 "DecodeRangeRequest", "RangeResult", "IdealemCodec",
                 "ReproError", "QuotaExceededError", "FlushPolicy",
                 "ServeFrontend", "FrontendClient", "TenantQuota",
                 "ControlLoop", "Container", "pack"):
        assert name in repro.__all__
        assert getattr(repro, name) is not None
    assert sorted(dir(repro)) == sorted(set(dir(repro)))
    with pytest.raises(AttributeError):
        repro.no_such_symbol


def test_facade_import_is_lazy():
    # `import repro` alone must not pull the device stack
    import subprocess
    import sys
    code = ("import sys; import repro; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
