"""CI perf gate (ISSUE 5): scripts/bench_gate.py must fail on an injected
synthetic regression -- the acceptance criterion -- and absorb the noise
sources it is deployed against (uniformly slower runners, per-bench
jitter, renamed/removed benches)."""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_gate", os.path.join(os.path.dirname(__file__), "..", "scripts",
                               "bench_gate.py"))
bench_gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_gate)

BASE = {"suiteA/row1": 100.0, "suiteA/row2": 250.0, "suiteB/row1": 40.0,
        "suiteB/row2": 900.0, "suiteC/row1": 10.0}


def _write(path, results, extra=None):
    doc = {"version": 1, "quick": True, "failed": [],
           "results": {k: {"us_per_call": v, "derived": ""}
                       for k, v in results.items()}}
    doc.update(extra or {})
    path.write_text(json.dumps(doc))
    return str(path)


@pytest.fixture
def files(tmp_path):
    def make(current, baseline=BASE, extra=None):
        return (_write(tmp_path / "current.json", current),
                _write(tmp_path / "baseline.json", baseline, extra))
    return make


def test_identical_results_pass(files):
    cur, base = files(dict(BASE))
    assert bench_gate.main([cur, base]) == 0


def test_injected_synthetic_regression_fails(files, capsys):
    """The acceptance criterion: one bench artificially 2x slower must
    exit non-zero (the other benches unchanged)."""
    slow = {**BASE, "suiteA/row2": BASE["suiteA/row2"] * 2.0}
    cur, base = files(slow)
    assert bench_gate.main([cur, base]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "suiteA/row2" in out


def test_uniformly_slower_machine_passes_normalized_fails_absolute(files):
    """A cold CI runner that is 3x slower across the board is machine
    noise, not a regression: normalized mode (the default) passes;
    --absolute (pinned-hardware trajectories) fails."""
    slow_all = {k: v * 3.0 for k, v in BASE.items()}
    cur, base = files(slow_all)
    assert bench_gate.main([cur, base]) == 0
    assert bench_gate.main([cur, base, "--absolute"]) == 1


def test_within_tolerance_passes(files):
    cur, base = files({**BASE, "suiteB/row1": BASE["suiteB/row1"] * 1.2})
    assert bench_gate.main([cur, base]) == 0  # 1.2x < default 1.25x


def test_per_bench_override_loosens_one_suite(files):
    slow = {**BASE, "suiteA/row2": BASE["suiteA/row2"] * 1.8}
    cur, base = files(slow)
    assert bench_gate.main([cur, base]) == 1
    # longest-prefix override: the jittery suite gets 100%
    assert bench_gate.main([cur, base, "--override", "suiteA/=1.0"]) == 0
    # but the override must not loosen OTHER suites
    slow2 = {**slow, "suiteB/row2": BASE["suiteB/row2"] * 1.8}
    cur2, base2 = files(slow2)
    assert bench_gate.main([cur2, base2, "--override", "suiteA/=1.0"]) == 1


def test_baseline_embedded_tolerances(files):
    slow = {**BASE, "suiteA/row2": BASE["suiteA/row2"] * 1.8}
    cur, base = files(slow, extra={"tolerances": {"suiteA/": 1.0}})
    assert bench_gate.main([cur, base]) == 0


def test_missing_bench_fails_unless_allowed(files, capsys):
    gone = {k: v for k, v in BASE.items() if k != "suiteC/row1"}
    cur, base = files(gone)
    assert bench_gate.main([cur, base]) == 1
    assert "MISSING" in capsys.readouterr().out
    assert bench_gate.main([cur, base, "--allow-missing"]) == 0


def test_new_bench_passes_and_is_reported(files, capsys):
    cur, base = files({**BASE, "suiteD/new": 5.0})
    assert bench_gate.main([cur, base]) == 0
    assert "suiteD/new" in capsys.readouterr().out


def test_few_shared_benches_fall_back_to_absolute(files, capsys):
    """Normalized mode is meaningless on 2 rows (the median IS the
    regression): the gate must fall back to absolute and still catch it."""
    cur, base = files({"suiteA/row1": 300.0, "suiteA/row2": 250.0},
                      baseline={"suiteA/row1": 100.0, "suiteA/row2": 250.0})
    assert bench_gate.main([cur, base]) == 1
    assert "falling back to absolute" in capsys.readouterr().out


def test_unreadable_input_is_usage_error(tmp_path, files):
    cur, base = files(dict(BASE))
    missing = str(tmp_path / "nope.json")
    assert bench_gate.main([missing, base]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert bench_gate.main([cur, str(bad)]) == 2


def test_run_py_baseline_refresh_preserves_tolerances(tmp_path):
    """Regenerating a committed baseline in place must carry over the
    hand-embedded per-bench tolerances, or the gate silently reverts to
    the default and starts flaking."""
    from benchmarks.run import carry_tolerances
    path = tmp_path / "base.json"
    path.write_text(json.dumps({"version": 1, "results": {},
                                "tolerances": {"suiteA/": 2.0}}))
    doc = carry_tolerances(str(path), {"version": 1, "results": {"x": {}}})
    assert doc["tolerances"] == {"suiteA/": 2.0}
    # fresh path (no existing file): no tolerances key invented
    doc = carry_tolerances(str(tmp_path / "new.json"), {"version": 1})
    assert "tolerances" not in doc


def test_run_py_rows_to_results_parses_and_skips_garbage():
    from benchmarks.run import rows_to_results
    rows = ["a/b,12.5,blocks=3;x=1", "bad row without commas",
            "c/d,7.0,note,with,commas"]
    res = rows_to_results(rows)
    assert res == {"a/b": {"us_per_call": 12.5, "derived": "blocks=3;x=1"},
                   "c/d": {"us_per_call": 7.0,
                           "derived": "note,with,commas"}}
