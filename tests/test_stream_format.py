"""Stream-format edge cases: all modes x D==1/D>=2 x f32/f64, the 0xFF
overwrite prefix, max_count continuation-byte runs, and byte-identity of the
vectorized serializer against the seed per-block loop."""
import numpy as np
import pytest

from repro.core import IdealemCodec
from repro.core.npref import encode_decisions_np
from repro.core.stream import (
    StreamHeader,
    _assemble_stream_py,
    _parse_stream_py,
    assemble_stream,
    parse_stream,
)


def _signal(mode, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if mode == "std":
        x = rng.normal(0.0, 1.0, size=n)
    else:
        t = np.arange(n, dtype=np.float64)
        x = np.mod(t * 0.7 + rng.normal(0, 0.05, size=n), 360.0)
    return x.astype(dtype)


def _codec(mode, num_dict, dtype, **kw):
    vr = (0.0, 360.0) if mode != "std" else None
    kw.setdefault("alpha", 0.05)
    kw.setdefault("rel_tol", 0.5)
    return IdealemCodec(mode=mode, block_size=16, num_dict=num_dict,
                        value_range=vr, backend="numpy", **kw)


# ------------------------------------------------- mode x D x dtype roundtrip
@pytest.mark.parametrize("mode", ["std", "residual", "delta"])
@pytest.mark.parametrize("num_dict", [1, 2, 255])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_roundtrip_mode_dict_dtype(mode, num_dict, dtype):
    c = _codec(mode, num_dict, dtype)
    x = _signal(mode, 16 * 60 + 7, dtype)
    blob = c.encode(x)
    y = c.decode(blob)
    assert len(y) == len(x)
    assert np.all(np.isfinite(y))
    header, events = parse_stream(blob)
    assert header.dtype == np.dtype(dtype)
    assert len(events) == 60
    np.testing.assert_allclose(np.asarray(y[-7:], dtype=dtype), x[-7:])
    # miss blocks reconstruct; res/delta re-anchor within dtype rounding
    B = c.block_size
    tol = 0 if mode == "std" else (1e-9 if dtype is np.float64 else 1e-3)
    for i, ev in enumerate(events):
        if ev["kind"] == "miss":
            np.testing.assert_allclose(y[i * B:(i + 1) * B],
                                       x[i * B:(i + 1) * B], atol=tol)


# ------------------------------------------------------- 0xFF overwrite path
@pytest.mark.parametrize("mode", ["std", "residual"])
def test_overwrite_prefix_roundtrip(mode):
    """A tiny dictionary on a many-source signal forces FIFO overwrites;
    every overwrite miss must carry the 0xFF prefix and survive parsing."""
    rng = np.random.default_rng(3)
    # blocks alternating between widely separated levels => constant misses
    blocks = np.concatenate([
        rng.normal(100.0 * (i % 7), 0.1, size=(1, 16)) for i in range(60)
    ])
    x = np.mod(np.abs(blocks.ravel()), 360.0)
    c = _codec(mode, 2, np.float64, alpha=0.01)
    blob = c.encode(x)
    _, events = parse_stream(blob)
    n_ovw = sum(1 for e in events if e["kind"] == "miss" and e["overwrite"])
    assert n_ovw > 10  # the pattern above must actually exercise the prefix
    # 0xFF count in the body matches (value bytes can also be 0xFF, so count
    # via the reference parser's event walk instead of raw byte scans)
    _, events_py = _parse_stream_py(blob)
    assert n_ovw == sum(1 for e in events_py
                        if e["kind"] == "miss" and e["overwrite"])
    y = c.decode(blob)
    assert len(y) == len(x)


# -------------------------------------------- max_count continuation streams
@pytest.mark.parametrize("mode", ["std", "delta"])
@pytest.mark.parametrize("n_hits", [0, 2, 3, 6, 7])
def test_single_dict_max_count_runs(mode, n_hits):
    """D==1 hit runs: a count byte equal to max_count means another count
    byte follows; k hits cost floor(k/c)+1 count bytes (paper footnotes 7-8).
    n_hits is chosen around c=3 to hit the ==c and multiple-of-c edges."""
    c = _codec(mode, 1, np.float64, max_count=3, alpha=0.01)
    B = c.block_size
    base_block = np.linspace(0.0, 50.0, B)
    x = np.tile(base_block, n_hits + 1)  # identical blocks: 1 miss + n hits
    blob = c.encode(x)
    _, events = parse_stream(blob)
    assert sum(1 for e in events if e["kind"] == "hit") == n_hits
    hdr_len = len(c.encode(np.zeros(0)))
    n_count_bytes = n_hits // 3 + 1
    if mode == "std":
        expected = hdr_len + B * 8 + n_count_bytes
    else:  # miss: base + B-1 deltas; each hit adds its base value
        expected = hdr_len + B * 8 + n_count_bytes + n_hits * 8
    assert len(blob) == expected
    y = c.decode(blob)
    assert len(y) == len(x)


def test_single_dict_long_run_byte_accounting():
    """Many continuation bytes: 1000 hits at c=255 -> 4 count bytes."""
    c = _codec("std", 1, np.float64, max_count=255, alpha=0.01)
    B = c.block_size
    x = np.tile(np.linspace(0.0, 50.0, B), 1001)
    blob = c.encode(x)
    hdr_len = len(c.encode(np.zeros(0)))
    assert len(blob) == hdr_len + B * 8 + (1000 // 255 + 1)


# ------------------------------------- vectorized vs seed-loop byte identity
@pytest.mark.parametrize("mode", ["std", "residual", "delta"])
@pytest.mark.parametrize("num_dict", [1, 2, 5, 255])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_assemble_matches_seed_loop(mode, num_dict, dtype):
    """The numpy offset/scatter serializer must be byte-identical to the seed
    per-block Python loop on real encoder decisions."""
    c = _codec(mode, num_dict, dtype, max_count=4)
    x = _signal(mode, 16 * 70 + 5, dtype, seed=11)
    nb = len(x) // 16
    blocks = x[: nb * 16].reshape(nb, 16)
    payload, bases = c._transform(blocks)
    is_hit, slot, ovw = encode_decisions_np(
        payload, num_dict=num_dict, d_crit=float(c.d_crit), rel_tol=0.5)
    header = StreamHeader(c.mode_id, 16, num_dict, c.max_count,
                          np.dtype(dtype), c.value_range, nb, x[nb * 16:])
    vec = assemble_stream(header, blocks, payload, bases, is_hit, slot, ovw)
    ref = _assemble_stream_py(header, blocks, payload, bases, is_hit, slot, ovw)
    assert vec == ref
    # and the vectorized parser agrees with the seed parser event-for-event
    h1, e1 = parse_stream(vec)
    h2, e2 = _parse_stream_py(vec)
    assert (h1.mode, h1.n_blocks, h1.num_dict) == (h2.mode, h2.n_blocks,
                                                   h2.num_dict)
    assert len(e1) == len(e2)
    for a, b in zip(e1, e2):
        assert a["kind"] == b["kind"] and a["slot"] == b["slot"]
        if a["kind"] == "miss":
            assert a["overwrite"] == b["overwrite"]
            np.testing.assert_array_equal(a["payload"], b["payload"])
        if mode != "std":
            assert a["base"] == b["base"]


def test_empty_stream_and_tail_only():
    c = _codec("std", 255, np.float64)
    assert len(c.decode(c.encode(np.zeros(0)))) == 0
    x = np.arange(5, dtype=np.float64)  # shorter than one block: tail only
    np.testing.assert_array_equal(c.decode(c.encode(x)), x)
