"""Scale-out encode acceptance: sharded output byte-identical to
single-device output (ISSUE 2).

Multi-device cells run ``repro.launch.shard_check`` in a subprocess so the
forced host device count precedes the jax import; masked-scan semantics
(the padding story that makes sharding and coalescing exact) are checked
in-process on the default single device.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest


def _run_check(devices: int, backend: str):
    env = dict(os.environ, PYTHONPATH="src", REPRO_SHARD_DEVICES=str(devices))
    env.pop("XLA_FLAGS", None)  # shard_check owns the flag
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check",
         "--backend", backend],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=os.getcwd())
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("devices,backend", [(2, "jax"), (4, "jax"),
                                             (2, "pallas")])
def test_sharded_encode_byte_identical(devices, backend):
    rec = _run_check(devices, backend)
    assert rec["status"] == "ok"
    assert rec["devices"] == devices
    assert len(rec["cases"]) == 6  # every mode x D regime


# ----------------------------------------------------- in-process (1 device)
def test_masked_scan_is_noop_on_invalid_blocks():
    import jax.numpy as jnp
    from repro.core.encoder import encode_decisions

    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.normal(size=(50, 16)), jnp.float32)
    kw = dict(num_dict=5, d_crit=0.45, rel_tol=0.5)
    ref = encode_decisions(blocks, **kw)

    # interleave garbage blocks masked out: real positions must decide
    # identically, masked positions must report all-zero decisions
    blk2 = jnp.zeros((100, 16), jnp.float32).at[::2].set(blocks)
    valid = np.zeros(100, dtype=bool)
    valid[::2] = True
    out = encode_decisions(blk2, valid=jnp.asarray(valid), **kw)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(ref[i]),
                                      np.asarray(out[i])[::2])
        assert not np.any(np.asarray(out[i])[1::2])


def test_sharded_single_device_matches_batched():
    import jax.numpy as jnp
    from repro.core.encoder import (encode_decisions_batched,
                                    encode_decisions_sharded)
    from repro.launch.encode_plan import make_encode_plan

    rng = np.random.default_rng(1)
    bc = jnp.asarray(rng.normal(size=(3, 40, 16)), jnp.float32)
    kw = dict(num_dict=7, d_crit=0.45, rel_tol=0.5)
    plan = make_encode_plan(3, block_size=16)
    ref = encode_decisions_batched(bc, **kw)
    out = encode_decisions_sharded(bc, mesh=plan.mesh,
                                   axis_name=plan.axis_name, **kw)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(ref[i]), np.asarray(out[i]))


def test_dsharded_single_device_matches_batched():
    """D-axis sharding (dictionary rows split over the mesh, per-step best
    match all-reduced) on a degenerate 1x1 mesh: decision-identical to the
    batched scan, including with the fused matcher (which downgrades to
    the composed kernel under D-sharding)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.encoder import (encode_decisions_batched,
                                    encode_decisions_dsharded)

    rng = np.random.default_rng(1)
    bc = jnp.asarray(rng.normal(size=(3, 40, 16)), jnp.float32)
    kw = dict(num_dict=7, d_crit=0.45, rel_tol=0.5)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("channels", "dict"))
    ref = encode_decisions_batched(bc, **kw)
    for matcher in (None, "fused"):
        out = encode_decisions_dsharded(bc, mesh=mesh, ch_axis="channels",
                                        dict_axis="dict",
                                        matcher=matcher, **kw)
        for i in range(3):
            np.testing.assert_array_equal(np.asarray(ref[i]),
                                          np.asarray(out[i]))


def test_encode_plan_shapes():
    from repro.launch.encode_plan import make_encode_plan, pad_channels

    plan = make_encode_plan(5, block_size=32)
    assert plan.channels == 5
    assert plan.padded_channels % plan.num_devices == 0
    assert plan.shard_channels * plan.num_devices == plan.padded_channels
    assert plan.block_quantum >= 1
    padded = pad_channels(plan, np.ones((5, 4)))
    assert padded.shape == (plan.padded_channels, 4)
    with pytest.raises(ValueError):
        make_encode_plan(0)


def test_coalescer_matches_per_stream_service():
    """Coalesced ragged traffic decodes exactly like the per-stream path."""
    from repro.core import IdealemCodec
    from repro.serve import FlushPolicy, StreamCoalescer

    B = 16
    kw = dict(mode="residual", block_size=B, num_dict=31, alpha=0.05,
              rel_tol=0.5)
    codec = IdealemCodec(**kw)
    rng = np.random.default_rng(3)
    signals = {f"s{i}": rng.normal(i, 1.0, size=B * 50 + 3 * i)
               for i in range(5)}

    co = StreamCoalescer(policy=FlushPolicy(max_batch_blocks=40),
                         capacity=2, **kw)  # forces one capacity growth
    segs = {sid: [] for sid in signals}
    for sid in signals:
        co.open_stream(sid)
    offs = {sid: 0 for sid in signals}
    steps = {sid: 29 + 17 * i for i, sid in enumerate(signals)}
    while any(offs[sid] < len(x) for sid, x in signals.items()):
        for sid, x in signals.items():
            if offs[sid] < len(x):
                res = co.submit(sid, x[offs[sid]:offs[sid] + steps[sid]])
                offs[sid] += steps[sid]
                if res:
                    for k, v in res.items():
                        segs[k].append(v)
    for sid in signals:
        segs[sid].append(co.close_stream(sid))
    for sid, x in signals.items():
        got = codec.decode(b"".join(segs[sid]))
        np.testing.assert_array_equal(got, codec.decode(codec.encode(x)))
    assert co.capacity == 8  # grew 2 -> 4 -> 8 for 5 streams
    assert co.stats()["blocks"] == sum(len(x) // B for x in signals.values())


def test_coalescer_slot_reuse_is_fresh():
    """A recycled slot must not leak the previous stream's dictionary."""
    from repro.core import IdealemCodec
    from repro.serve import StreamCoalescer

    kw = dict(mode="std", block_size=16, num_dict=7, alpha=0.05, rel_tol=0.5)
    codec = IdealemCodec(**kw)
    rng = np.random.default_rng(9)
    x = rng.normal(size=16 * 40)
    co = StreamCoalescer(capacity=1, **kw)
    for name in ("a", "b"):
        co.open_stream(name)
        co.submit(name, x)
        blob = co.close_stream(name)
        np.testing.assert_array_equal(codec.decode(blob),
                                      codec.decode(codec.encode(x)))
    with pytest.raises(KeyError):
        co.submit("a", x)
